"""Figs. 11-13 analogue: per-component latency breakdown of the chosen
schedules, emitted through the obs layer.

The simulator's Gantt spans are replayed into a Tracer
(``obs.report.replay_sim``) and summarized by the same plan-vs-actual
report the runtime uses, so benchmark and runtime accounting share one
code path: per-mode phase fractions come from the report's drift table,
bubble/busy fractions from its device utilization, and ``--trace-out``
writes the simulated timeline as a Chrome-trace artifact.
"""
from __future__ import annotations

import argparse
from types import SimpleNamespace

from benchmarks.common import emit, reasoning_profiles
from benchmarks.bench_exec_modes import grpo_graph
from repro.core import (
    Scheduler,
    SchedulerConfig,
    Simulator,
    collocated_schedule,
    disaggregated_schedule,
)
from repro.obs import MetricsRegistry, format_snapshot
from repro.obs.report import plan_vs_actual, replay_sim


def _placement(sched, devices):
    """Device slices per worker, mirroring Controller._place for the
    simple (cycle-free) schedules this benchmark builds."""
    from repro.core.scheduler import Async, Leaf, Pipelined, Temporal
    out = {}
    if isinstance(sched, Leaf):
        out[sched.worker] = devices[: sched.devices] or devices
        return out
    if isinstance(sched, Temporal):
        out.update(_placement(sched.s, devices))
        out.update(_placement(sched.t, devices))
        return out
    if isinstance(sched, (Pipelined, Async)):
        out.update(_placement(sched.s, devices[:sched.n_s]))
        out.update(_placement(sched.t, devices[sched.n_s:]))
        return out
    raise TypeError(type(sched))


def run(tail_factor: float = 4.9, trace_out: str | None = None) -> dict:
    profiles = reasoning_profiles(7.0, tail_factor=tail_factor)
    g = grpo_graph()
    n, M = 64, 512
    plans = {
        "collocated": collocated_schedule(g, profiles, n, M),
        "disaggregated": disaggregated_schedule(g, profiles, n, M),
    }
    sch = Scheduler(profiles, SchedulerConfig(
        total_batch=M, device_quantum=4, granularity_divisors=(1, 2, 4, 8, 16)))
    plans["auto"] = sch.schedule(g, n, M)

    reg = MetricsRegistry()
    reports = {}
    for mode, (t, sched) in plans.items():
        res = Simulator(profiles).run(sched, M)
        placement = _placement(sched, list(range(n)))
        tracer = replay_sim(res, placement=placement)
        plan = SimpleNamespace(schedule=sched, placement=placement,
                               members={})
        rep = plan_vs_actual(plan, profiles, tracer, M, sim=res)
        reports[mode] = rep
        reg.gauge(f"breakdown/{mode}/iter_s").set(res.makespan)
        reg.gauge(f"breakdown/{mode}/bubble_frac").set(rep.bubble_fraction())
        for row in rep.drift:
            reg.gauge(f"breakdown/{mode}/frac/{row.worker}").set(
                row.predicted_s / max(res.makespan, 1e-9))
        parts = ";".join(
            f"{row.worker}={row.predicted_s / res.makespan:.0%}"
            for row in sorted(rep.drift, key=lambda r: r.worker))
        emit(f"breakdown.{mode}", 0.0,
             f"iter={res.makespan:.1f}s;bubble={rep.bubble_fraction():.0%};"
             f"{parts}")
        if trace_out:
            path = f"{trace_out}.{mode}.trace.json"
            tracer.export(path)
            emit(f"breakdown.{mode}.trace", 0.0, path)
        # rollout wall-time inflation under disaggregation (paper Fig. 12:
        # 40/64 GPUs -> rollout only +14%)
        if mode == "disaggregated":
            roll_dis = res.busy_time("rollout")
            roll_col = Simulator(profiles).run(
                plans["collocated"][1], M).busy_time("rollout")
            emit("breakdown.fig12_rollout_inflation", 0.0,
                 f"{roll_dis / max(roll_col, 1e-9):.2f}x_(paper~1.14x)")

    for line in format_snapshot(reg.snapshot()):
        print(line)
    return reports


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tail-factor", type=float, default=4.9)
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="also export each mode's simulated timeline as "
                         "PREFIX.<mode>.trace.json")
    a = ap.parse_args()
    run(a.tail_factor, a.trace_out)
