"""Figs. 11-13 analogue: per-component latency breakdown of the chosen
schedules (Gantt spans from the event simulator)."""
from __future__ import annotations

from benchmarks.common import emit, reasoning_profiles
from benchmarks.bench_exec_modes import grpo_graph
from repro.core import (
    Scheduler,
    SchedulerConfig,
    Simulator,
    collocated_schedule,
    disaggregated_schedule,
)


def run(tail_factor: float = 4.9) -> None:
    profiles = reasoning_profiles(7.0, tail_factor=tail_factor)
    g = grpo_graph()
    n, M = 64, 512
    plans = {
        "collocated": collocated_schedule(g, profiles, n, M),
        "disaggregated": disaggregated_schedule(g, profiles, n, M),
    }
    sch = Scheduler(profiles, SchedulerConfig(
        total_batch=M, device_quantum=4, granularity_divisors=(1, 2, 4, 8, 16)))
    plans["auto"] = sch.schedule(g, n, M)

    for mode, (t, sched) in plans.items():
        res = Simulator(profiles).run(sched, M)
        bd = res.breakdown()
        total = res.makespan
        parts = ";".join(f"{k}={v / total:.0%}" for k, v in sorted(bd.items()))
        emit(f"breakdown.{mode}", 0.0, f"iter={total:.1f}s;{parts}")
        # rollout wall-time inflation under disaggregation (paper Fig. 12:
        # 40/64 GPUs -> rollout only +14%)
        if mode == "disaggregated":
            roll_dis = res.busy_time("rollout")
            roll_col = Simulator(profiles).run(
                plans["collocated"][1], M).busy_time("rollout")
            emit("breakdown.fig12_rollout_inflation", 0.0,
                 f"{roll_dis / max(roll_col, 1e-9):.2f}x_(paper~1.14x)")


if __name__ == "__main__":
    run()
