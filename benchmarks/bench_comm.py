"""§3.5 analogue: adaptive-communication microbenchmarks (REAL timings).

Measures the channel + router data plane: put/get latency, weighted
balancing overhead, structure-aware payload pack/unpack vs naive pickle,
and worker offload/onload bandwidth (the context-switch cost driver).
"""
from __future__ import annotations

import pickle
import time

import numpy as np

from benchmarks.common import emit, time_call
from repro.comm.primitives import Payload, Router
from repro.core import Channel, Worker


def run() -> None:
    # channel put/get
    ch = Channel.create(f"bench-{time.time_ns()}")
    item = {"x": np.ones((256, 256), np.float32)}

    def putget():
        for _ in range(100):
            ch.put(item)
        for _ in range(100):
            ch.get()

    us = time_call(putget, repeats=3)
    emit("comm.channel_putget", us / 200.0, "per_op")

    # router p2p with a 4 MB pytree payload
    r = Router()
    r.register("a", devices=[0])
    r.register("b", devices=[1])
    tree = {"w": np.ones((1024, 1024), np.float32),
            "meta": {"step": 3, "ids": np.arange(64)}}

    def sendrecv():
        r.send("a", "b", tree)
        r.recv("b", "a")

    us = time_call(sendrecv, repeats=5)
    mb = 4.0
    emit("comm.router_p2p_4MB", us, f"{mb / (us / 1e6):.0f}MB/s")

    # structure-aware payload vs pickle round-trip
    us_pack = time_call(lambda: Payload.pack(tree).unpack(), repeats=5)
    us_pickle = time_call(lambda: pickle.loads(pickle.dumps(tree)), repeats=5)
    emit("comm.payload_roundtrip", us_pack,
         f"pickle={us_pickle:.0f}us;speedup={us_pickle / max(us_pack, 1e-9):.1f}x")

    # offload/onload bandwidth (the context-switch primitive)
    import jax
    import jax.numpy as jnp
    w = Worker("bench/0", devices=(0,))
    w.register_state("params", {"w": jnp.ones((2048, 2048))})
    nbytes = w.state_bytes()

    def cycle():
        w.offload()
        w.onload()
        jax.block_until_ready(w.get_state("params")["w"])

    us = time_call(cycle, repeats=5)
    emit("comm.offload_onload_16MB", us,
         f"{nbytes * 2 / (us / 1e6) / 1e9:.2f}GB/s")
    w.shutdown()


if __name__ == "__main__":
    run()
