"""Deliverable (g): roofline table from the dry-run artifacts.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
produces the per-(arch × shape × mesh) roofline rows: the three terms in
seconds, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and HBM fit.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")
HBM = 16 * 1024**3  # v5e


def load_reports(mesh: Optional[str] = None, tag: str = "") -> List[Dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        base = os.path.basename(fn)[:-len(".json")]
        if tag:
            if not base.endswith(f"_{tag}"):
                continue
        elif "_opt" in base:
            continue  # hillclimb variants are reported separately
        with open(fn) as f:
            r = json.load(f)
        if mesh and r["mesh"] != mesh:
            continue
        out.append(r)
    return out


def table(reports: List[Dict]) -> str:
    hdr = (f"| {'arch':24s} | {'shape':11s} | {'mesh':7s} | C (ms) | M (ms) "
           f"| X (ms) | dominant | useful | HBM GiB | fits |")
    sep = "|" + "-" * (len(hdr) - 2) + "|"
    lines = [hdr, sep]
    for r in sorted(reports, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        rf = r["roofline"]
        peak = r["memory"]["peak_est_bytes"] / 2**30
        lines.append(
            f"| {r['arch']:24s} | {r['shape']:11s} | {r['mesh']:7s} "
            f"| {rf['compute_s'] * 1e3:9.2f} | {rf['memory_s'] * 1e3:9.2f} "
            f"| {rf['collective_s'] * 1e3:9.2f} | {rf['dominant']:9s} "
            f"| {rf['useful_flops_ratio']:6.3f} | {peak:7.2f} "
            f"| {'Y' if peak <= 16.0 else 'OVER'} |")
    return "\n".join(lines)


def run() -> None:
    for mesh in ("16x16", "2x16x16"):
        reports = load_reports(mesh)
        if not reports:
            continue
        doms = {}
        fits = 0
        for r in reports:
            doms[r["roofline"]["dominant"]] = doms.get(
                r["roofline"]["dominant"], 0) + 1
            fits += r["memory"]["peak_est_bytes"] <= HBM
        emit(f"roofline.{mesh}", 0.0,
             f"cases={len(reports)};fits={fits};dominant={doms}")
    print(table(load_reports()))


if __name__ == "__main__":
    run()
