"""Figs. 8 & 10 analogue: end-to-end throughput of the three execution
modes across model sizes and cluster scales (event-simulated at production
scale with profiles calibrated per benchmarks.common).

Paper claims reproduced here:
  * RLinf(auto) >= max(collocated, disaggregated) on every point —
    1.1x-1.58x over the veRL-style collocated baseline (Fig. 8);
  * disaggregated ~1.17-1.21x over collocated at 28k context (Fig. 10).
"""
from __future__ import annotations

import time
from typing import Dict

from benchmarks.common import emit, reasoning_profiles
from repro.core import (
    FlowGraph,
    Scheduler,
    SchedulerConfig,
    Simulator,
    collocated_schedule,
    disaggregated_schedule,
)

MODEL_SIZES = {"1.5B": 1.5, "7B": 7.0, "32B": 32.0}
CLUSTERS = (16, 32, 64, 128)
BATCH = 512
SEQ = 28672


def grpo_graph() -> FlowGraph:
    g = FlowGraph()
    for w in ("rollout", "inference", "training"):
        g.add_worker(w)
    g.add_edge("rollout", "inference")
    g.add_edge("inference", "training")
    return g


def run(tail_factor: float = 6.0) -> Dict:
    g = grpo_graph()
    results = {}
    for mname, mb in MODEL_SIZES.items():
        profiles = reasoning_profiles(mb, tail_factor=tail_factor, seq_len=SEQ)
        for n in CLUSTERS:
            cfg = SchedulerConfig(
                total_batch=BATCH, device_quantum=max(n // 16, 1),
                granularity_divisors=(1, 2, 4, 8, 16),
                device_memory=80e9)
            t0 = time.perf_counter()
            sch = Scheduler(profiles, cfg)
            t_auto, s_auto = sch.schedule(g, n, BATCH)
            sched_us = (time.perf_counter() - t0) * 1e6
            t_col, s_col = collocated_schedule(g, profiles, n, BATCH)
            t_dis, s_dis = disaggregated_schedule(g, profiles, n, BATCH)
            # validate with the event simulator
            sim = Simulator(profiles)
            t_auto_sim = sim.run(s_auto, BATCH).makespan
            tokens = BATCH * SEQ
            results[(mname, n)] = dict(
                auto=t_auto, col=t_col, dis=t_dis,
                speedup_col=t_col / t_auto, speedup_dis=t_dis / t_auto,
                dis_over_col=t_col / t_dis)
            emit(f"exec_modes.{mname}.n{n}", sched_us,
                 f"tput_auto={tokens / t_auto:.0f}tok/s"
                 f";x_vs_collocated={t_col / t_auto:.2f}"
                 f";x_vs_disagg={t_dis / t_auto:.2f}"
                 f";disagg_over_col={t_col / t_dis:.2f}"
                 f";sim_agree={abs(t_auto_sim - t_auto) / t_auto:.1%}")
    # paper-band checks (recorded, not asserted)
    sp = [r["speedup_col"] for r in results.values()]
    band = sum(1.05 <= s <= 2.2 for s in sp)
    emit("exec_modes.speedup_band_check", 0.0,
         f"{band}/{len(sp)}_points_in_1.05-2.2x;min={min(sp):.2f};max={max(sp):.2f}")
    d7 = results[("7B", 64)]["dis_over_col"]
    emit("exec_modes.fig10_disagg_over_col_7B", 0.0,
         f"{d7:.2f}x_(paper_1.17-1.21x)")
    return results


if __name__ == "__main__":
    run()
