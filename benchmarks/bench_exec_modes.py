"""Figs. 8 & 10 analogue: end-to-end throughput of the three execution
modes across model sizes and cluster scales (event-simulated at production
scale with profiles calibrated per benchmarks.common).

Paper claims reproduced here:
  * RLinf(auto) >= max(collocated, disaggregated) on every point —
    1.1x-1.58x over the veRL-style collocated baseline (Fig. 8);
  * disaggregated ~1.17-1.21x over collocated at 28k context (Fig. 10).

Plus the async off-policy extension (``run_async``): sync vs async-K
horizon throughput on the long-tail workload — the cross-iteration
overlap hides the generation tail behind training, so every K >= 1 curve
must sit strictly above the sync baseline.
"""
from __future__ import annotations

import time
from typing import Dict

from benchmarks.common import emit, reasoning_profiles
from repro.core import (
    Async,
    FlowGraph,
    Scheduler,
    SchedulerConfig,
    Simulator,
    collocated_schedule,
    disaggregated_schedule,
)

MODEL_SIZES = {"1.5B": 1.5, "7B": 7.0, "32B": 32.0}
CLUSTERS = (16, 32, 64, 128)
BATCH = 512
SEQ = 28672


def grpo_graph() -> FlowGraph:
    g = FlowGraph()
    for w in ("rollout", "inference", "training"):
        g.add_worker(w)
    g.add_edge("rollout", "inference")
    g.add_edge("inference", "training")
    return g


def run(tail_factor: float = 6.0) -> Dict:
    g = grpo_graph()
    results = {}
    for mname, mb in MODEL_SIZES.items():
        profiles = reasoning_profiles(mb, tail_factor=tail_factor, seq_len=SEQ)
        for n in CLUSTERS:
            cfg = SchedulerConfig(
                total_batch=BATCH, device_quantum=max(n // 16, 1),
                granularity_divisors=(1, 2, 4, 8, 16),
                device_memory=80e9)
            t0 = time.perf_counter()
            sch = Scheduler(profiles, cfg)
            t_auto, s_auto = sch.schedule(g, n, BATCH)
            sched_us = (time.perf_counter() - t0) * 1e6
            t_col, s_col = collocated_schedule(g, profiles, n, BATCH)
            t_dis, s_dis = disaggregated_schedule(g, profiles, n, BATCH)
            # validate with the event simulator
            sim = Simulator(profiles)
            t_auto_sim = sim.run(s_auto, BATCH).makespan
            tokens = BATCH * SEQ
            results[(mname, n)] = dict(
                auto=t_auto, col=t_col, dis=t_dis,
                speedup_col=t_col / t_auto, speedup_dis=t_dis / t_auto,
                dis_over_col=t_col / t_dis)
            emit(f"exec_modes.{mname}.n{n}", sched_us,
                 f"tput_auto={tokens / t_auto:.0f}tok/s"
                 f";x_vs_collocated={t_col / t_auto:.2f}"
                 f";x_vs_disagg={t_dis / t_auto:.2f}"
                 f";disagg_over_col={t_col / t_dis:.2f}"
                 f";sim_agree={abs(t_auto_sim - t_auto) / t_auto:.1%}")
    # paper-band checks (recorded, not asserted)
    sp = [r["speedup_col"] for r in results.values()]
    band = sum(1.05 <= s <= 2.2 for s in sp)
    emit("exec_modes.speedup_band_check", 0.0,
         f"{band}/{len(sp)}_points_in_1.05-2.2x;min={min(sp):.2f};max={max(sp):.2f}")
    d7 = results[("7B", 64)]["dis_over_col"]
    emit("exec_modes.fig10_disagg_over_col_7B", 0.0,
         f"{d7:.2f}x_(paper_1.17-1.21x)")
    return results


ASYNC_DEPTHS = (1, 2, 4)
ASYNC_ITERS = 16


def run_async(tail_factor: float = 6.0, iterations: int = ASYNC_ITERS
              ) -> Dict:
    """Sync vs async-K end-to-end horizon throughput (long-tail workload).

    The sync baseline is the best Algorithm-1 plan run back-to-back for
    ``iterations`` iterations; each async-K point lets generation run up
    to K parameter versions ahead (bounded staleness), which hides the
    long-tail stall of the rollout stage behind training.  Both sides are
    replayed by the event simulator so the comparison shares one cost
    semantics."""
    g = grpo_graph()
    results = {}
    for mname, mb in MODEL_SIZES.items():
        profiles = reasoning_profiles(mb, tail_factor=tail_factor,
                                      seq_len=SEQ)
        for n in (32, 64):
            cfg = SchedulerConfig(
                total_batch=BATCH, device_quantum=max(n // 16, 1),
                granularity_divisors=(1, 2, 4, 8, 16),
                device_memory=80e9)
            sch = Scheduler(profiles, cfg)
            t_sync, s_sync = sch.schedule(g, n, BATCH)
            sim = Simulator(profiles)
            sync_span = sim.run_iterations(s_sync, BATCH,
                                           iterations).makespan
            tokens = BATCH * SEQ * iterations
            tput_sync = tokens / sync_span
            row = {"sync": tput_sync}
            for K in ASYNC_DEPTHS:
                _, s_k = sch.schedule_async(g, n, BATCH,
                                            iterations=iterations,
                                            depths=(K,))
                if not isinstance(s_k, Async):
                    # freshness tax made K unattractive: the scheduler
                    # fell back to sync — record parity, exclude from the
                    # strictly-above check (the scheduler was RIGHT to
                    # refuse the overlap here)
                    row[f"async{K}"] = tput_sync
                    continue
                span_k = sim.run_iterations(s_k, BATCH,
                                            iterations).makespan
                row[f"async{K}"] = tokens / span_k
                row[f"async{K}_realized"] = True
            results[(mname, n)] = row
            derived = ";".join(
                f"x_async{K}={row[f'async{K}'] / tput_sync:.2f}"
                for K in ASYNC_DEPTHS)
            emit(f"exec_modes_async.{mname}.n{n}", 0.0,
                 f"tput_sync={tput_sync:.0f}tok/s;{derived}")
    realized = [r[f"async{K}"] / r["sync"]
                for r in results.values() for K in ASYNC_DEPTHS
                if r.get(f"async{K}_realized")]
    n_parity = sum(1 for r in results.values() for K in ASYNC_DEPTHS
                   if not r.get(f"async{K}_realized"))
    worst = min(realized) if realized else float("nan")
    ok = bool(realized) and worst > 1.0
    emit("exec_modes_async.gain_check", 0.0,
         f"min_asyncK_over_sync={worst:.3f}"
         f";{'PASS' if ok else 'FAIL'}_strictly_above_sync"
         f";parity_fallbacks={n_parity}")
    return results


# ---------------------------------------------------------------------------
# BENCH_modes.json: mode wall times + MEASURED switch / weight-sync costs
# ---------------------------------------------------------------------------
def _measure_real_modes(iterations: int = 2) -> Dict:
    """Run a real tiny-model GRPO workload once per execution mode and
    collect what the binding runtime *measured*: wall time, per-worker
    context-switch costs (ContextSwitcher feedback into the CostModels),
    and the resharding-backed weight-sync cost/bytes."""
    from repro.configs import get_config
    from repro.rl import GRPOConfig, GRPORunner
    from repro.train import TrainHParams
    from repro.train.optimizer import AdamWConfig

    cfg = get_config("yi-9b").reduced().replace(
        vocab_size=32, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128)
    out: Dict[str, Dict] = {}
    for mode in ("collocated", "disaggregated", "auto"):
        rl = GRPOConfig(batch_size=8, group_size=4, iterations=iterations,
                        max_new_tokens=4, mode=mode, seed=0,
                        profile_batches=(4, 8))
        runner = GRPORunner(cfg, rl,
                            TrainHParams(optimizer=AdamWConfig(lr=1e-3)))
        t0 = time.perf_counter()
        runner.run(verbose=False)
        wall = time.perf_counter() - t0
        prof = runner.controller.profiles
        out[mode] = {
            "wall_seconds": wall,
            "plan": type(runner.plan.schedule).__name__,
            "context_switch_measured": {
                w: dict(v) for w, v in
                runner.controller.switch_stats.items()},
            "onoffload_cost_model": {
                name: {"onload": cm.onload_time, "offload": cm.offload_time}
                for name, cm in prof.items()},
            "weight_sync": {
                "seconds_total": runner.sync_stats["seconds"],
                "bytes": runner.sync_stats["bytes"],
                "syncs": runner.sync_stats["syncs"],
                "sync_time_cost_model": prof["rollout"].sync_time,
            },
        }
        emit(f"exec_modes_real.{mode}", wall * 1e6,
             f"plan={out[mode]['plan']}"
             f";sync_s={runner.sync_stats['seconds']:.4f}"
             f";sync_bytes={runner.sync_stats['bytes']:.0f}")
    return out


def run_modes_json(out_path: str = "BENCH_modes.json", *,
                   fast: bool = True, tail_factor: float = 6.0) -> Dict:
    """Satellite deliverable: one JSON artifact recording (a) simulated
    collocated / disaggregated / auto wall times at representative sweep
    points — the CI smoke asserts auto <= both fixed modes — and (b)
    measured context-switch and weight-sync costs from a real tiny-model
    run in each mode (the binding runtime's cost feedback)."""
    import json

    g = grpo_graph()
    simulated: Dict[str, Dict[str, float]] = {}
    ok = True
    points = [("7B", 64)] if fast else [(m, n) for m in MODEL_SIZES
                                        for n in (32, 64)]
    for mname, n in points:
        profiles = reasoning_profiles(MODEL_SIZES[mname],
                                      tail_factor=tail_factor, seq_len=SEQ)
        cfg = SchedulerConfig(
            total_batch=BATCH, device_quantum=max(n // 16, 1),
            granularity_divisors=(1, 2, 4, 8, 16), device_memory=80e9)
        sch = Scheduler(profiles, cfg)
        t_auto, s_auto = sch.schedule(g, n, BATCH)
        t_col, _ = collocated_schedule(g, profiles, n, BATCH)
        t_dis, _ = disaggregated_schedule(g, profiles, n, BATCH)
        sim = Simulator(profiles)
        simulated[f"{mname}.n{n}"] = {
            "auto": t_auto, "collocated": t_col, "disaggregated": t_dis,
            "auto_simulated": sim.run(s_auto, BATCH).makespan,
        }
        ok = ok and t_auto <= t_col + 1e-9 and t_auto <= t_dis + 1e-9
    data = {
        "simulated": simulated,
        "measured": _measure_real_modes(iterations=1 if fast else 3),
        "auto_le_fixed": bool(ok),
    }
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
    emit("exec_modes.bench_modes_json", 0.0,
         f"{'PASS' if ok else 'FAIL'}_auto_le_fixed;out={out_path}")
    return data


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write BENCH_modes.json-style artifact and exit")
    p.add_argument("--fast", action="store_true",
                   help="single sweep point + 1 real iteration")
    args = p.parse_args()
    if args.json:
        run_modes_json(args.json, fast=args.fast)
    else:
        run()
        run_async()
