"""Benchmark harness entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus the roofline table).

  E5 bench_longtail    — Fig. 2  (response-length dynamicity, tail factor)
  E1 bench_exec_modes  — Fig. 8/10 (3 modes × model sizes × cluster scales)
                         + sync vs async-K off-policy horizon curves
  E2 bench_embodied    — Fig. 9  (ManiSkill/LIBERO placement flip)
  E3 bench_breakdown   — Fig. 11-13 (component latency breakdown)
  E4 bench_scheduler   — Alg. 1 (optimality + runtime)
  E6 bench_comm        — §3.5  (channels/router/offload, real timings)
  E7 roofline_table    — deliverable (g) from the dry-run artifacts

Run:  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    t0 = time.time()
    print("name,us_per_call,derived")

    from benchmarks import bench_longtail
    tail = bench_longtail.run()

    from benchmarks import bench_exec_modes
    bench_exec_modes.run(tail_factor=tail)
    bench_exec_modes.run_async(tail_factor=tail)

    from benchmarks import bench_embodied
    bench_embodied.run()

    from benchmarks import bench_breakdown
    bench_breakdown.run(tail_factor=tail)

    from benchmarks import bench_scheduler
    bench_scheduler.run()

    from benchmarks import bench_comm
    bench_comm.run()

    from benchmarks import roofline_table
    roofline_table.run()

    print(f"\n# benchmarks completed in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
