"""Fig. 9 analogue: embodied RL under different placement strategies.

Two environment profiles:
  * ManiSkill-like (GPU-parallel sim, cost scales with envs): hybrid
    placement should win (paper: 1.61x-1.88x over the RL4VLA
    disaggregated baseline);
  * LIBERO-like (CPU-bound sim, cost flat per step): collocated should
    win (paper: 1.25x-2.13x over hybrid).

Two layers of evidence:
  * ``run()`` — scheduler-level (simulated) walls at production scale,
    as before;
  * ``run_measured()`` — REAL walls: the EmbodiedPPORunner executes the
    collapsed sim↔generation cycle under each forced realization
    (collocated / hybrid) and under auto, on this host, with the env
    profile realized as actual per-step latencies.  ``--json`` writes
    ``BENCH_embodied.json``; CI asserts auto ≤ best fixed mode (with a
    small timing tolerance) on BOTH env profiles.

The paper's qualitative claim — no single mode is universally optimal and
the auto scheduler tracks the per-workload best — is checked explicitly.
"""
from __future__ import annotations

import json
from typing import Dict

from benchmarks.common import embodied_profiles, emit
from repro.core import (
    FlowGraph,
    Scheduler,
    SchedulerConfig,
    collocated_schedule,
    disaggregated_schedule,
)

BATCH = 256  # environments

# measured-wall tolerance: auto runs the same realization it picked, so
# its wall matches that fixed mode up to host timing noise
MEASURE_TOL = 1.10


def embodied_graph() -> FlowGraph:
    g = FlowGraph()
    for w in ("simulator", "rollout", "training"):
        g.add_worker(w)
    g.add_edge("simulator", "rollout")
    g.add_edge("rollout", "simulator")  # sim<->gen cycle
    g.add_edge("rollout", "training")
    return g


def run() -> Dict:
    g = embodied_graph()
    results = {}
    for env in ("maniskill", "libero"):
        profiles = embodied_profiles(env)
        for n in (8, 16, 32):
            cfg = SchedulerConfig(total_batch=BATCH, device_quantum=2,
                                  granularity_divisors=(1, 2, 4, 8))
            sch = Scheduler(profiles, cfg)
            t_auto, s_auto = sch.schedule(g, n, BATCH)
            t_col, _ = collocated_schedule(g, profiles, n, BATCH)
            t_dis, _ = disaggregated_schedule(g, profiles, n, BATCH)
            best_fixed = min(t_col, t_dis)
            best_name = "collocated" if t_col <= t_dis else "disaggregated"
            results[(env, n)] = dict(auto=t_auto, col=t_col, dis=t_dis)
            emit(f"embodied.{env}.n{n}", 0.0,
                 f"batches_per_s={BATCH / t_auto:.2f}"
                 f";x_vs_col={t_col / t_auto:.2f}"
                 f";x_vs_dis={t_dis / t_auto:.2f}"
                 f";best_fixed={best_name}"
                 f";auto_matches_best={t_auto <= best_fixed + 1e-9}")
    # the cross-env claim (paper Fig. 9): ManiSkill profits from the hybrid
    # schedule (sim || gen pipelined, training swapped in), LIBERO is
    # CPU-sim-bound so collocation is already near-optimal — i.e. no fixed
    # mode is universally best and auto tracks the per-workload optimum.
    man = results[("maniskill", 16)]
    lib = results[("libero", 16)]
    emit("embodied.mode_flip_check", 0.0,
         f"maniskill_hybrid_gain={man['col'] / man['auto']:.2f}x_(paper_1.61-1.88x)"
         f";libero_auto_over_col={lib['col'] / lib['auto']:.2f}x_(~1_collocated_best)")
    return results


# ---------------------------------------------------------------------------
# Measured walls: the executable cycle under each realization
# ---------------------------------------------------------------------------
# Env-profile latencies realized on the actual VecReachEnv / act path:
#   maniskill — sim + generation costs both scale with the number of
#   envs stepped (GPU-parallel sim, VLA-scale policy), so the hybrid
#   cycle hides one behind the other;
#   libero — the sim's cost is FLAT per step call (CPU physics), so
#   chunking the envs doubles sim occupancy and collocation wins.
ENV_LATENCIES = {
    "maniskill": dict(step_latency=1e-3, latency_per_env=1.2e-3,
                      act_latency=0.0, act_latency_per_env=1.0e-3),
    "libero": dict(step_latency=6e-2, latency_per_env=0.0,
                   act_latency=0.0, act_latency_per_env=2.5e-4),
}


def _measure_mode(env: str, mode: str, *, envs: int, horizon: int,
                  iterations: int) -> Dict:
    from repro.rl import EmbodiedPPOConfig, EmbodiedPPORunner

    rl = EmbodiedPPOConfig(
        num_envs=envs, horizon=horizon, iterations=iterations, mode=mode,
        seed=0, profile_batches=(max(envs // 2, 1), envs),
        **ENV_LATENCIES[env])
    runner = EmbodiedPPORunner(rl)
    runner.profile()
    runner.plan_execution()
    walls = []
    for it in range(iterations):
        runner.run_iteration(it)
        # execute-only wall (excludes weight-sync/jit-compile jitter of
        # the surrounding bookkeeping)
        walls.append(runner.controller.last_time)
    realization = (runner.controller.last_cycle_log[-1][1]
                   if runner.controller.last_cycle_log else "?")
    # first iteration compiles the (possibly chunked) act path — skip it
    wall = min(walls[1:]) if len(walls) > 1 else walls[0]
    return {"wall_seconds": wall, "realization": realization,
            "all_walls": walls}


def run_measured(*, fast: bool = True) -> Dict:
    envs = 32 if fast else 64
    horizon = 6 if fast else 12
    # min-of-several after the compile iteration: host load spikes (CI
    # runners are shared) must not masquerade as a mode difference
    iterations = 4 if fast else 5
    out: Dict[str, Dict] = {}
    ok_all = True
    for env in ("maniskill", "libero"):
        row: Dict[str, Dict] = {}
        for mode in ("collocated", "hybrid", "auto"):
            row[mode] = _measure_mode(env, mode, envs=envs,
                                      horizon=horizon,
                                      iterations=iterations)
        walls = {m: row[m]["wall_seconds"]
                 for m in ("collocated", "hybrid")}
        best_name = min(walls, key=walls.get)
        auto_w = row["auto"]["wall_seconds"]
        ok = auto_w <= walls[best_name] * MEASURE_TOL
        ok_all = ok_all and ok
        out[env] = {
            **row,
            "best_fixed": best_name,
            "auto_realization": row["auto"]["realization"],
            "auto_le_fixed": bool(ok),
        }
        emit(f"embodied_measured.{env}", 0.0,
             f"col={walls['collocated']:.3f}s;hyb={walls['hybrid']:.3f}s"
             f";auto={auto_w:.3f}s;best_fixed={best_name}"
             f";auto_picked={row['auto']['realization']}"
             f";auto_le_fixed={ok}")
    out["auto_le_fixed"] = bool(ok_all)
    return out


def run_embodied_json(out_path: str = "BENCH_embodied.json", *,
                      fast: bool = True) -> Dict:
    """Satellite deliverable: simulated scheduler-level walls at scale
    PLUS measured collocated/hybrid/auto cycle walls for both env
    profiles; CI asserts auto ≤ best fixed mode on both."""
    simulated = {
        f"{env}.n{n}": row
        for (env, n), row in run().items()}
    measured = run_measured(fast=fast)
    data = {
        "simulated": simulated,
        "measured": measured,
        "auto_le_fixed": measured["auto_le_fixed"],
        "measure_tolerance": MEASURE_TOL,
    }
    with open(out_path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
    emit("embodied.bench_embodied_json", 0.0,
         f"{'PASS' if data['auto_le_fixed'] else 'FAIL'}_auto_le_fixed"
         f";out={out_path}")
    return data


if __name__ == "__main__":
    import argparse

    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write BENCH_embodied.json-style artifact")
    p.add_argument("--fast", action="store_true",
                   help="small envs/horizon for the measured part")
    args = p.parse_args()
    if args.json:
        run_embodied_json(args.json, fast=args.fast)
    else:
        run()
        run_measured()
