"""Fig. 9 analogue: embodied RL under different placement strategies.

Two environment profiles:
  * ManiSkill-like (GPU-parallel sim): hybrid placement should win
    (paper: 1.61x-1.88x over the RL4VLA disaggregated baseline);
  * LIBERO-like (CPU-bound sim): collocated should win
    (paper: 1.25x-2.13x over hybrid).

The paper's qualitative claim — no single mode is universally optimal and
the auto scheduler tracks the per-workload best — is checked explicitly.
"""
from __future__ import annotations

from typing import Dict

from benchmarks.common import embodied_profiles, emit
from repro.core import (
    FlowGraph,
    Scheduler,
    SchedulerConfig,
    collocated_schedule,
    disaggregated_schedule,
)

BATCH = 256  # environments


def embodied_graph() -> FlowGraph:
    g = FlowGraph()
    for w in ("simulator", "rollout", "training"):
        g.add_worker(w)
    g.add_edge("simulator", "rollout")
    g.add_edge("rollout", "simulator")  # sim<->gen cycle
    g.add_edge("rollout", "training")
    return g


def run() -> Dict:
    g = embodied_graph()
    results = {}
    for env in ("maniskill", "libero"):
        profiles = embodied_profiles(env)
        for n in (8, 16, 32):
            cfg = SchedulerConfig(total_batch=BATCH, device_quantum=2,
                                  granularity_divisors=(1, 2, 4, 8))
            sch = Scheduler(profiles, cfg)
            t_auto, s_auto = sch.schedule(g, n, BATCH)
            t_col, _ = collocated_schedule(g, profiles, n, BATCH)
            t_dis, _ = disaggregated_schedule(g, profiles, n, BATCH)
            best_fixed = min(t_col, t_dis)
            best_name = "collocated" if t_col <= t_dis else "disaggregated"
            results[(env, n)] = dict(auto=t_auto, col=t_col, dis=t_dis)
            emit(f"embodied.{env}.n{n}", 0.0,
                 f"batches_per_s={BATCH / t_auto:.2f}"
                 f";x_vs_col={t_col / t_auto:.2f}"
                 f";x_vs_dis={t_dis / t_auto:.2f}"
                 f";best_fixed={best_name}"
                 f";auto_matches_best={t_auto <= best_fixed + 1e-9}")
    # the cross-env claim (paper Fig. 9): ManiSkill profits from the hybrid
    # schedule (sim || gen pipelined, training swapped in), LIBERO is
    # CPU-sim-bound so collocation is already near-optimal — i.e. no fixed
    # mode is universally best and auto tracks the per-workload optimum.
    man = results[("maniskill", 16)]
    lib = results[("libero", 16)]
    emit("embodied.mode_flip_check", 0.0,
         f"maniskill_hybrid_gain={man['col'] / man['auto']:.2f}x_(paper_1.61-1.88x)"
         f";libero_auto_over_col={lib['col'] / lib['auto']:.2f}x_(~1_collocated_best)")
    return results


if __name__ == "__main__":
    run()
