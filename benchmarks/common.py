"""Shared benchmark utilities: timing, CSV output, paper-calibrated profiles."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.profiler import CostModel

CSV_ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    CSV_ROWS.append(row)
    print(row)


def time_call(fn: Callable, *, warmup: int = 1, repeats: int = 3) -> float:
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6  # us


# ---------------------------------------------------------------------------
# Response-length model calibrated to the paper's Fig. 2: a lognormal whose
# CDF matches "number of unfinished responses shrinks to <5% quickly, then a
# small set of long-tail responses stalls the stage".
# ---------------------------------------------------------------------------
def sample_response_lengths(n: int, *, median: float = 4096.0,
                            sigma: float = 0.9, max_len: float = 28672.0,
                            seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    ls = rng.lognormal(mean=np.log(median), sigma=sigma, size=n)
    return np.clip(ls, 64, max_len)


def tail_factor_from_lengths(lengths: np.ndarray) -> float:
    """Generation-stage tail factor: the slowest response (= stage length)
    over the mean (= useful utilization)."""
    return float(lengths.max() / lengths.mean())


# ---------------------------------------------------------------------------
# Reasoning-RL worker profiles per model size, shaped after Figs. 2/3/11/12:
#   rollout:   decode-bound, scales with devices, long-tailed
#   inference: prefill-only recompute, ~25% of rollout compute
#   training:  fwd+bwd+opt, ~1/3 of generation wall time (paper §2.2),
#              heavy memory, expensive on/offload
# Constants are in "seconds per sample per device" units chosen so the 7B /
# 64-GPU / 28k-ctx point lands in the paper's measured bands (Fig. 10-12);
# scaling in model size is linear in parameters (decode/prefill FLOPs).
# ---------------------------------------------------------------------------
def reasoning_profiles(model_b: float, *, tail_factor: float = 4.9,
                       seq_len: int = 28672) -> Dict[str, CostModel]:
    """Calibrated (benchmarks/bench_exec_modes sweep) so that the 7B /
    64-GPU / 28k point reproduces the paper's measured relations:
      * component shares ≈ Fig. 11 (rollout-dominant, training ~2nd),
      * collocated mode pays multi-second on/offload swaps per phase
        (the veRL behaviour §2.2 critiques),
      * disaggregated / collocated ≈ 1.17-1.21x (Fig. 10 band).
    The tail_factor defaults to the Fig.-2-calibrated value derived in
    bench_longtail."""
    ctx = seq_len / 28672.0
    m = model_b
    return {
        "rollout": CostModel(
            "rollout",
            base_time=0.3, slope_time=0.012 * m * ctx,
            base_mem=2e9 * m, mem_per_item=3e6 * m * ctx,
            onload_time=0.09 * m, offload_time=0.075 * m,
            tail_factor=tail_factor),
        "inference": CostModel(
            "inference",
            base_time=0.2, slope_time=0.006 * m * ctx,
            base_mem=2e9 * m, mem_per_item=1e6 * m * ctx,
            onload_time=0.075 * m, offload_time=0.06 * m),
        "training": CostModel(
            "training",
            base_time=0.3, slope_time=0.012 * m * ctx,
            base_mem=16e9 * m, mem_per_item=2e6 * m * ctx,
            onload_time=0.30 * m, offload_time=0.225 * m),
    }


def embodied_profiles(kind: str) -> Dict[str, CostModel]:
    """kind='maniskill' (GPU-parallel sim, hybrid should win) or
    'libero' (CPU-bound sim dominates, collocated should win)."""
    if kind == "maniskill":
        return {
            "simulator": CostModel("simulator", base_time=2.0,
                                   slope_time=0.004, scalable=False,
                                   max_useful_devices=8,
                                   base_mem=2e9, mem_per_item=60e6,
                                   onload_time=0.3, offload_time=0.2),
            "rollout": CostModel("rollout", base_time=0.5, slope_time=0.05,
                                 base_mem=16e9, mem_per_item=40e6,
                                 onload_time=0.8, offload_time=0.6,
                                 tail_factor=2.0),
            "training": CostModel("training", base_time=0.5,
                                  slope_time=0.017,
                                  base_mem=30e9, mem_per_item=20e6,
                                  onload_time=1.2, offload_time=0.9),
        }
    if kind == "libero":
        return {
            # CPU-bound sim: does not free GPU time when disaggregated, so
            # giving everything to (cheap) GPU stages buys little
            "simulator": CostModel("simulator", base_time=18.0,
                                   slope_time=0.002, scalable=False,
                                   max_useful_devices=1,
                                   base_mem=5e8, mem_per_item=5e6,
                                   onload_time=0.05, offload_time=0.05),
            "rollout": CostModel("rollout", base_time=0.4, slope_time=0.012,
                                 base_mem=16e9, mem_per_item=30e6,
                                 onload_time=0.8, offload_time=0.6,
                                 tail_factor=1.5),
            "training": CostModel("training", base_time=0.4,
                                  slope_time=0.008,
                                  base_mem=30e9, mem_per_item=15e6,
                                  onload_time=1.2, offload_time=0.9),
        }
    raise ValueError(kind)
