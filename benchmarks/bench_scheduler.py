"""Algorithm 1 quality + cost: optimality vs exhaustive search on random
workflow DAGs, runtime scaling with graph size, memoization hit rate."""
from __future__ import annotations

import itertools
import random
import time

from benchmarks.common import emit
from repro.core import FlowGraph, Scheduler, SchedulerConfig
from repro.core.profiler import CostModel
from repro.core.scheduler import Leaf, Pipelined, Temporal


def random_chain_dag(k: int, seed: int) -> FlowGraph:
    rng = random.Random(seed)
    g = FlowGraph()
    names = [f"w{i}" for i in range(k)]
    for n in names:
        g.add_worker(n)
    for i in range(1, k):
        g.add_edge(names[rng.randrange(i)], names[i])
    return g


def random_profiles(k: int, seed: int):
    rng = random.Random(seed + 99)
    return {
        f"w{i}": CostModel(
            f"w{i}", base_time=rng.uniform(0.05, 0.5),
            slope_time=rng.uniform(0.001, 0.05),
            onload_time=rng.uniform(0.0, 1.0),
            offload_time=rng.uniform(0.0, 1.0),
            tail_factor=rng.choice([1.0, 1.0, 4.0]))
        for i in range(k)
    }


def exhaustive(sch: Scheduler, g: FlowGraph, n: int, M: int) -> float:
    """The Scheduler IS exhaustive over its space; as an external check we
    re-run with a fresh memo and compare against a randomized-restart
    local search over the same candidate space."""
    best = float("inf")
    rng = random.Random(0)
    # random sampling of schedules within the same space
    for _ in range(300):
        t = _random_schedule_time(sch, g, n, M, rng)
        best = min(best, t)
    return best


def _random_schedule_time(sch, g, n, M, rng) -> float:
    nodes = g.nodes
    if len(nodes) == 1:
        return sch._leaf(nodes[0], n, M)[0]
    cuts = list(g.st_cuts())
    s_set, t_set = rng.choice(cuts)
    gs, gt = g.subgraph(s_set), g.subgraph(t_set)
    if rng.random() < 0.5:
        return (_random_schedule_time(sch, gs, n, M, rng)
                + _random_schedule_time(sch, gt, n, M, rng)
                + sch._switch_cost(gs, gt))
    splits = sch._device_splits(n) or [max(n // 2, 1)]
    n_s = rng.choice(splits) if n > 1 else n
    m = rng.choice(sch._granularities(M))
    ts = _random_schedule_time(sch, gs, n_s, m, rng)
    tt = _random_schedule_time(sch, gt, n - n_s, m, rng)
    return ts + tt + (M // m - 1) * max(ts, tt)


def run() -> None:
    wins, ties = 0, 0
    for k in (3, 4, 5):
        for seed in range(3):
            g = random_chain_dag(k, seed)
            profiles = random_profiles(k, seed)
            cfg = SchedulerConfig(total_batch=128, device_quantum=4,
                                  granularity_divisors=(1, 2, 4, 8))
            sch = Scheduler(profiles, cfg)
            t0 = time.perf_counter()
            t_opt, _ = sch.schedule(g, 32, 128)
            dt = (time.perf_counter() - t0) * 1e6
            sch2 = Scheduler(profiles, cfg)
            sch2._members = {}
            t_rand = exhaustive(sch2, g.condense()[0], 32, 128)
            ok = t_opt <= t_rand + 1e-9
            wins += ok
            ties += abs(t_opt - t_rand) < 1e-9
            emit(f"scheduler.dag{k}.seed{seed}", dt,
                 f"alg1={t_opt:.3f}s;best_of_300_random={t_rand:.3f}s;optimal={ok}")
    emit("scheduler.optimality", 0.0, f"alg1_never_beaten={wins}/9;exact_ties={ties}/9")


if __name__ == "__main__":
    run()
