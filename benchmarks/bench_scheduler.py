"""Algorithm 1 quality + cost: optimality vs exhaustive search on random
workflow DAGs, runtime scaling with graph size, memoization hit rate."""
from __future__ import annotations

import itertools
import random
import time

from benchmarks.common import emit
from repro.core import FlowGraph, Scheduler, SchedulerConfig
from repro.core.profiler import CostModel, paper_like_profiles
from repro.core.scheduler import Leaf, Pipelined, Temporal


def random_chain_dag(k: int, seed: int) -> FlowGraph:
    rng = random.Random(seed)
    g = FlowGraph()
    names = [f"w{i}" for i in range(k)]
    for n in names:
        g.add_worker(n)
    for i in range(1, k):
        g.add_edge(names[rng.randrange(i)], names[i])
    return g


def random_profiles(k: int, seed: int):
    rng = random.Random(seed + 99)
    return {
        f"w{i}": CostModel(
            f"w{i}", base_time=rng.uniform(0.05, 0.5),
            slope_time=rng.uniform(0.001, 0.05),
            onload_time=rng.uniform(0.0, 1.0),
            offload_time=rng.uniform(0.0, 1.0),
            tail_factor=rng.choice([1.0, 1.0, 4.0]))
        for i in range(k)
    }


def exhaustive(sch: Scheduler, g: FlowGraph, n: int, M: int) -> float:
    """The Scheduler IS exhaustive over its space; as an external check we
    re-run with a fresh memo and compare against a randomized-restart
    local search over the same candidate space."""
    best = float("inf")
    rng = random.Random(0)
    # random sampling of schedules within the same space
    for _ in range(300):
        t = _random_schedule_time(sch, g, n, M, rng)
        best = min(best, t)
    return best


def _random_schedule_time(sch, g, n, M, rng) -> float:
    nodes = g.nodes
    if len(nodes) == 1:
        return sch._leaf(nodes[0], n, M)[0]
    cuts = list(g.st_cuts())
    s_set, t_set = rng.choice(cuts)
    gs, gt = g.subgraph(s_set), g.subgraph(t_set)
    if rng.random() < 0.5:
        return (_random_schedule_time(sch, gs, n, M, rng)
                + _random_schedule_time(sch, gt, n, M, rng)
                + sch._switch_cost(gs, gt))
    splits = sch._device_splits(n) or [max(n // 2, 1)]
    n_s = rng.choice(splits) if n > 1 else n
    m = rng.choice(sch._granularities(M))
    ts = _random_schedule_time(sch, gs, n_s, m, rng)
    tt = _random_schedule_time(sch, gt, n - n_s, m, rng)
    return ts + tt + (M // m - 1) * max(ts, tt)


def grpo_graph() -> FlowGraph:
    g = FlowGraph()
    for w in ("rollout", "inference", "training"):
        g.add_worker(w)
    g.add_edge("rollout", "inference")
    g.add_edge("inference", "training")
    return g


def scale() -> dict:
    """Scale-out planning cost: flat Algorithm 1 at 64 devices vs the
    hierarchical (host-grouped) planner at 256-1024.  The hierarchical
    walls must stay sub-second — this is what makes re-planning after a
    host failure cheap enough to sit on the recovery path — and CI
    enforces hier@512 < flat@64."""
    profiles = paper_like_profiles()
    g = grpo_graph()
    base = dict(total_batch=2048, device_quantum=1,
                granularity_divisors=(1, 2, 4, 8, 16, 32))
    out: dict = {}

    t0 = time.perf_counter()
    est_flat, _ = Scheduler(profiles, SchedulerConfig(
        **base, hierarchical=False)).schedule(g, 64, 2048)
    out["flat_64_wall_s"] = time.perf_counter() - t0
    emit("scheduler.scale.flat64", out["flat_64_wall_s"] * 1e6,
         f"est={est_flat:.3f}s")

    # estimate-quality check at a size both planners can handle: the
    # coarse inter-host splits should cost only a small estimate penalty
    est_hier64, _ = Scheduler(profiles, SchedulerConfig(
        **base, hierarchical=True, host_group_size=8)).schedule(g, 64, 2048)
    out["est_ratio_64"] = est_hier64 / est_flat
    emit("scheduler.scale.est_quality", 0.0,
         f"hier/flat_est_ratio@64={out['est_ratio_64']:.4f}")

    for n in (256, 512, 1024):
        sch = Scheduler(profiles, SchedulerConfig(
            **base, hierarchical=True, host_group_size=8))
        t0 = time.perf_counter()
        est, _ = sch.schedule(g, n, 2048)
        wall = time.perf_counter() - t0
        out[f"hier_{n}_wall_s"] = wall
        emit(f"scheduler.scale.hier{n}", wall * 1e6,
             f"est={est:.3f}s;cuts={sch.evaluated_cuts}")
    return out


def run() -> None:
    wins, ties = 0, 0
    for k in (3, 4, 5):
        for seed in range(3):
            g = random_chain_dag(k, seed)
            profiles = random_profiles(k, seed)
            cfg = SchedulerConfig(total_batch=128, device_quantum=4,
                                  granularity_divisors=(1, 2, 4, 8))
            sch = Scheduler(profiles, cfg)
            t0 = time.perf_counter()
            t_opt, _ = sch.schedule(g, 32, 128)
            dt = (time.perf_counter() - t0) * 1e6
            sch2 = Scheduler(profiles, cfg)
            sch2._members = {}
            t_rand = exhaustive(sch2, g.condense()[0], 32, 128)
            ok = t_opt <= t_rand + 1e-9
            wins += ok
            ties += abs(t_opt - t_rand) < 1e-9
            emit(f"scheduler.dag{k}.seed{seed}", dt,
                 f"alg1={t_opt:.3f}s;best_of_300_random={t_rand:.3f}s;optimal={ok}")
    emit("scheduler.optimality", 0.0, f"alg1_never_beaten={wins}/9;exact_ties={ties}/9")


if __name__ == "__main__":
    import json
    import sys

    if "--scale" in sys.argv or "--json" in sys.argv:
        stats = scale()
    else:
        run()
        stats = scale()
    if "--json" in sys.argv:
        i = sys.argv.index("--json")
        if i + 1 < len(sys.argv):
            with open(sys.argv[i + 1], "w") as f:
                json.dump(stats, f, indent=2)
        else:
            print(json.dumps(stats))
