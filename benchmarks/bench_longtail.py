"""Fig. 2 analogue: response-length dynamicity and the long-tail stall.

Two parts:
 (a) REAL measurement — generate with the CPU engine (EOS-terminated
     sampling) and record the response-length distribution;
 (b) production-scale model — lognormal lengths calibrated per §Fig. 2
     ("unfinished responses shrink to <5% quickly"), from which we derive
     the generation tail factor used by every other benchmark.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    emit,
    sample_response_lengths,
    tail_factor_from_lengths,
    time_call,
)


def real_engine_lengths() -> np.ndarray:
    import jax

    from repro.configs import get_config
    from repro.models import init_model
    from repro.serve import Engine
    from repro.train.data import PromptDataset

    cfg = get_config("stablelm-12b").reduced().replace(
        vocab_size=32, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128)
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, max_new_tokens=24, temperature=1.2)
    ds = PromptDataset(32, prompt_len=6, seed=0)
    b = ds.next_batch()

    res = [None]

    def gen():
        res[0] = eng.generate(params, np.asarray(b["prompt_tokens"]),
                              key=jax.random.PRNGKey(1))

    us = time_call(gen, warmup=1, repeats=2)
    lens = np.asarray(res[0].lengths) - 6
    emit("longtail.engine_generate_batch32", us,
         f"mean_len={lens.mean():.1f};p100={lens.max()}")
    return lens


def run() -> float:
    lens = real_engine_lengths()

    # production-scale length model (Fig. 2 CDF shape)
    L = sample_response_lengths(512, seed=0)
    tf = tail_factor_from_lengths(L)
    # unfinished-over-time curve: fraction of responses still running when
    # x% of the stage has elapsed (stage length = max length)
    t_grid = np.linspace(0, 1, 21)
    unfinished = [(L > t * L.max()).mean() for t in t_grid]
    t5 = float(t_grid[np.searchsorted(-np.array(unfinished), -0.05)])
    emit("longtail.model_tail_factor", 0.0,
         f"tail_factor={tf:.2f};unfinished<5%_at={t5:.2f}of_stage")
    # collocated idle fraction: devices run at mean/max utilization during
    # the tail
    idle = 1.0 - L.mean() / L.max()
    emit("longtail.collocated_idle_fraction", 0.0, f"idle={idle:.2f}")
    return tf


if __name__ == "__main__":
    run()
