"""Fig. 2 analogue: response-length dynamicity and the long-tail stall.

Three parts:
 (a) REAL measurement — generate with the CPU engine (EOS-terminated
     sampling) and record the response-length distribution;
 (b) production-scale model — lognormal lengths calibrated per §Fig. 2
     ("unfinished responses shrink to <5% quickly"), from which we derive
     the generation tail factor used by every other benchmark;
 (c) static vs continuous batching — the same skewed workload served by
     the legacy fixed-shape Engine (padded to the longest response) and
     by the paged continuous-batching PagedEngine; the throughput ratio
     and the engine-MEASURED tail factor land in ``BENCH_serve.json``
     (the repo's serving-perf trajectory, refreshed by the CI smoke step).

Run:  PYTHONPATH=src python -m benchmarks.bench_longtail [--fast]
          [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import (
    emit,
    sample_response_lengths,
    tail_factor_from_lengths,
    time_call,
)


def real_engine_lengths() -> np.ndarray:
    import jax

    from repro.configs import get_config
    from repro.models import init_model
    from repro.serve import Engine
    from repro.train.data import PromptDataset

    cfg = get_config("stablelm-12b").reduced().replace(
        vocab_size=32, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128)
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, max_new_tokens=24, temperature=1.2)
    ds = PromptDataset(32, prompt_len=6, seed=0)
    b = ds.next_batch()

    res = [None]

    def gen():
        res[0] = eng.generate(params, np.asarray(b["prompt_tokens"]),
                              key=jax.random.PRNGKey(1))

    us = time_call(gen, warmup=1, repeats=2)
    lens = np.asarray(res[0].lengths) - 6
    emit("longtail.engine_generate_batch32", us,
         f"mean_len={lens.mean():.1f};p100={lens.max()}")
    return lens


def _skewed_budgets(n: int, *, slots: int, max_new: int,
                    seed: int = 0) -> np.ndarray:
    """Per-request generation budgets with the Fig. 2 shape: most
    responses are short, ~1/slots run to the cap.  Stragglers are spread
    so every static batch of ``slots`` contains one — the paper's point
    that the long tail is present throughout the stage, not clustered."""
    rng = np.random.default_rng(seed)
    ls = rng.lognormal(np.log(max_new / 8.0), 0.7, size=n)
    budgets = np.clip(np.round(ls), 2, max_new // 3).astype(int)
    # one straggler per static batch, arriving at the head of its group:
    # under continuous batching the long response overlaps the shorts
    # that arrive behind it instead of draining alone at the end
    budgets[0::slots] = max_new
    return budgets


def continuous_vs_static(*, fast: bool = False, out: str | None = None):
    """Serve one skewed workload through both engines (deliverable c).

    Static = legacy fixed-shape Engine: every batch decodes
    ``max(budgets)`` steps regardless of how early requests finish.
    Continuous = PagedEngine: finished requests free their pages and the
    admission queue backfills the decode batch each step.  Useful work is
    identical (sum of budgets), so throughput ratio == stall removed.
    """
    import jax

    from repro.configs import get_config
    from repro.core.profiler import engine_cost_model
    from repro.models import init_model
    from repro.serve import Engine, PagedEngine
    from repro.train.data import PromptDataset

    # enough requests that the admission queue keeps every slot busy
    # through the stragglers' tail (smaller N under-fills the last steps)
    n_requests = 48 if fast else 96
    slots = 8
    prompt_len = 6
    max_new = 32 if fast else 48
    page_size = 4

    # big enough that a decode step is compute- (not dispatch-) bound on
    # CPU — the regime where batching policy, not Python overhead, decides
    cfg = get_config("yi-9b").reduced().replace(
        vocab_size=256, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=1024, max_seq_len=max(128, prompt_len + max_new))
    params = init_model(jax.random.PRNGKey(0), cfg)
    budgets = _skewed_budgets(n_requests, slots=slots, max_new=max_new,
                              seed=1)
    ds = PromptDataset(n_requests, prompt_len=prompt_len, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    useful_tokens = int(budgets.sum())

    # -- static baseline: fixed-shape scan padded to the longest response
    # (eos=-1: lengths are budget-driven so both engines do the same
    # useful work and the comparison isolates the batching policy)
    static_eng = Engine(cfg, max_new_tokens=int(budgets.max()),
                        temperature=1.0, eos_token=-1)
    warm = static_eng.generate(params, prompts[:slots],
                               key=jax.random.PRNGKey(9))
    warm.tokens.block_until_ready()

    def time_static() -> float:
        t0 = time.perf_counter()
        for i in range(0, n_requests, slots):
            static_eng.generate(
                params, prompts[i:i + slots],
                key=jax.random.PRNGKey(i)).tokens.block_until_ready()
        return time.perf_counter() - t0

    # -- continuous: paged engine, per-request budgets, slot backfill
    # prefix sharing OFF: the repeated timing passes re-serve the SAME
    # prompts, so the radix cache would skip most prefill on passes 2+
    # and the ratio would no longer measure the batching policy alone
    # (the sharing win has its own gate: serve_batch.py --shared-prefix)
    paged_eng = PagedEngine(
        cfg, max_batch=slots, page_size=page_size,
        max_seq_len=prompt_len + max_new, max_new_tokens=max_new,
        temperature=1.0, eos_token=-1, prefix_sharing=False,
        num_pages=slots * -(-(prompt_len + max_new) // page_size) + 1)
    paged_eng.set_params(params)
    paged_eng.submit(prompts[0], max_new_tokens=2, seed=123)  # warm-up
    paged_eng.run()
    paged_eng.pop_request_records()

    steps_per_pass = [0]

    def time_continuous() -> float:
        s0 = paged_eng.decode_steps
        t0 = time.perf_counter()
        for i in range(n_requests):
            paged_eng.submit(prompts[i], max_new_tokens=int(budgets[i]),
                             seed=i)
        paged_eng.run()
        steps_per_pass[0] = paged_eng.decode_steps - s0
        return time.perf_counter() - t0

    # alternate repeats and keep the min per engine: the container's CPU
    # allocation is bursty, and back-to-back phases would otherwise be
    # measured under different machine conditions
    repeats = 3
    t_static, t_cont = float("inf"), float("inf")
    for _ in range(repeats):
        t_static = min(t_static, time_static())
        t_cont = min(t_cont, time_continuous())

    tok_s_static = useful_tokens / t_static
    tok_s_cont = useful_tokens / t_cont
    speedup = t_static / t_cont
    cm = engine_cost_model("rollout", paged_eng.pop_request_records(),
                           layout=paged_eng.layout.name)
    emit("longtail.static_batching_us_per_req", t_static * 1e6 / n_requests,
         f"tok_s={tok_s_static:.0f}")
    emit("longtail.continuous_batching_us_per_req", t_cont * 1e6 / n_requests,
         f"tok_s={tok_s_cont:.0f};speedup={speedup:.2f}x")
    emit("longtail.measured_tail_factor", 0.0,
         f"tail_factor={cm.tail_factor:.2f}")

    result = {
        "workload": {
            "n_requests": n_requests, "slots": slots,
            "prompt_len": prompt_len, "max_new": max_new,
            "page_size": page_size, "useful_tokens": useful_tokens,
            "budget_p50": float(np.percentile(budgets, 50)),
            "budget_max": int(budgets.max()), "fast_mode": fast,
        },
        "static": {"wall_s": t_static, "tok_per_s": tok_s_static},
        "continuous": {
            "wall_s": t_cont, "tok_per_s": tok_s_cont,
            "decode_steps": steps_per_pass[0],
            "peak_active": paged_eng.scheduler.stats.peak_active,
        },
        "repeats": repeats,
        "speedup": speedup,
        "measured_tail_factor": cm.tail_factor,
    }
    if out:
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"# wrote {out}")
    return result


def run(*, fast: bool = False, out: str | None = None) -> float:
    lens = real_engine_lengths()

    # production-scale length model (Fig. 2 CDF shape)
    L = sample_response_lengths(512, seed=0)
    tf = tail_factor_from_lengths(L)
    # unfinished-over-time curve: fraction of responses still running when
    # x% of the stage has elapsed (stage length = max length)
    t_grid = np.linspace(0, 1, 21)
    unfinished = [(L > t * L.max()).mean() for t in t_grid]
    t5 = float(t_grid[np.searchsorted(-np.array(unfinished), -0.05)])
    emit("longtail.model_tail_factor", 0.0,
         f"tail_factor={tf:.2f};unfinished<5%_at={t5:.2f}of_stage")
    # collocated idle fraction: devices run at mean/max utilization during
    # the tail
    idle = 1.0 - L.mean() / L.max()
    emit("longtail.collocated_idle_fraction", 0.0, f"idle={idle:.2f}")
    # the serving comparison is the expensive part; only run it when a
    # record was asked for (benchmarks/run.py just needs the tail factor)
    if out:
        continuous_vs_static(fast=fast, out=out)
    return tf


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small workload for the CI smoke step")
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="where to write the static-vs-continuous record")
    args = ap.parse_args()
    run(fast=args.fast, out=args.out)
