"""End-to-end reasoning-RL driver: train a ~100M-param model with GRPO for
a few hundred steps on verifiable synthetic math, through the full M2Flow
runtime (profile → schedule → pipelined execution).

This is the repo's "train a ~100M model for a few hundred steps" driver
(deliverable b).  The reward is the paper's rule-based ±5; accuracy on the
task should climb well above the ~8% random baseline.

Run:  PYTHONPATH=src python examples/reasoning_grpo.py [--steps 200] [--small]
"""
import argparse
import json
import sys
import time

from repro.configs import get_config
from repro.rl import GRPOConfig, GRPORunner
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainHParams


def build_cfg(small: bool):
    base = get_config("yi-9b")
    if small:
        # CI-sized: ~1M params
        return base.reduced().replace(
            vocab_size=32, d_model=128, num_heads=4, num_kv_heads=2,
            d_ff=256, num_layers=2)
    # ~100M-param same-family model (vocab from the synthetic task)
    return base.replace(
        name="yi-100m", num_layers=8, d_model=768, num_heads=12,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32,
        max_seq_len=64)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--group", type=int, default=8)
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "collocated", "disaggregated"])
    ap.add_argument("--max-operand", type=int, default=3)
    ap.add_argument("--small", action="store_true",
                    help="~1M params, fast smoke")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)

    cfg = build_cfg(args.small)
    hp = TrainHParams(optimizer=AdamWConfig(lr=1e-3, warmup_steps=10,
                                            clip_norm=1.0),
                      clip_eps_low=0.2, clip_eps_high=0.28,
                      entropy_coef=0.02)
    rl = GRPOConfig(batch_size=args.batch, group_size=args.group,
                    iterations=args.steps, max_new_tokens=3,
                    temperature=1.0, mode=args.mode, seed=0)
    runner = GRPORunner(cfg, rl, hp)
    runner.data.max_operand = args.max_operand  # answer-size curriculum
    runner.data.add_only = True
    runner.profile()
    runner.plan_execution()
    print(runner.plan.pretty())

    t0 = time.time()
    window = []
    for it in range(args.steps):
        st = runner.run_iteration(it)
        window.append(st.accuracy)
        if len(window) > 20:
            window.pop(0)
        if it % 10 == 0 or it == args.steps - 1:
            print(f"iter {it:4d} wall={st.wall_time:5.2f}s "
                  f"reward={st.mean_reward:+6.2f} "
                  f"acc(20)={sum(window)/len(window):5.2f} "
                  f"kl={st.metrics.get('approx_kl', 0.0):+.4f}")
    total = time.time() - t0
    final_acc = sum(window) / len(window)
    print(f"\ndone: {args.steps} iterations in {total:.1f}s; "
          f"final acc(20)={final_acc:.2f}; "
          f"throughput={runner.throughput():.1f} tok/s")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"final_acc": final_acc,
                       "throughput": runner.throughput(),
                       "stats": [vars(s) for s in runner.stats]}, f,
                      default=str)
    return 0


if __name__ == "__main__":
    sys.exit(main())
