"""Async off-policy GRPO: bounded-staleness cross-iteration pipelining.

Sync GRPO barriers every iteration: the trainer idles while generation's
long tail finishes, and generation idles while the trainer updates.  With
``async_depth = K >= 1`` the rollout side keeps producing batches under
parameters up to K versions stale while the trainer runs concurrently;
the AsyncQueue enforces the staleness bound and every stale sample is
damped per token by a truncated importance ratio
(``repro.rl.advantage.staleness_importance_weights``), which reduces to
exactly 1.0 at K = 0.

This script trains the same tiny model sync (K=0) and async (K=1, K=2)
and prints wall-clock throughput plus final accuracy.  NOTE: on a single
shared CPU the producer and trainer contend for the same compute, so do
not expect a wall-clock win here — this example demonstrates the
*correctness* properties (staleness never exceeds K, learning survives
off-policy data).  The throughput win at cluster scale, where the two
sides own disjoint devices, is measured by
``benchmarks/bench_exec_modes.run_async`` (async-K strictly above sync).

Run:  PYTHONPATH=src python examples/async_grpo.py [--iters 30]
"""
import argparse
import sys

import numpy as np

from repro.configs import get_config
from repro.rl import GRPOConfig, GRPORunner
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainHParams


def make_runner(async_depth: int, iters: int) -> GRPORunner:
    cfg = get_config("yi-9b").reduced().replace(
        vocab_size=32, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256)
    hp = TrainHParams(optimizer=AdamWConfig(lr=1e-3, clip_norm=1.0),
                      entropy_coef=0.02)
    rl = GRPOConfig(batch_size=32, group_size=8, iterations=iters,
                    max_new_tokens=3, mode="collocated", seed=0,
                    profile_batches=(8,), async_depth=async_depth)
    runner = GRPORunner(cfg, rl, hp)
    runner.data.max_operand = 3
    runner.data.add_only = True
    return runner


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=30)
    args = ap.parse_args(argv)

    results = {}
    for K in (0, 1, 2):
        runner = make_runner(K, args.iters)
        stats = runner.run(verbose=False)
        acc = float(np.mean([s.accuracy for s in stats[-10:]]))
        results[K] = (runner.throughput(), acc)
        stale = (runner._driver.queue.max_observed_staleness
                 if K > 0 else 0)
        print(f"[K={K}] throughput={results[K][0]:8.1f} tok/s  "
              f"acc(last10)={acc:5.2f}  max_staleness={stale}")

    base = results[0][0]
    for K in (1, 2):
        print(f"async K={K} vs sync: {results[K][0] / base:.2f}x "
              f"wall-clock throughput (single shared CPU — see "
              f"bench_exec_modes.run_async for the at-scale curves)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
