"""Embodied RL driver: PPO-style training of a token policy against the
vectorized CPU simulator, with the cyclic sim↔generation workflow that
drives the paper's hybrid scheduling (Fig. 1 bottom-left, Fig. 9).

The whole loop lives in :class:`repro.rl.EmbodiedPPORunner`: the
simulator↔policy cycle is a collapsed node in the workflow graph, the
scheduler records a realization (collocated alternation or hybrid
fine-grained pipelining) on the plan, and the ExecutionFlowManager runs
it as a real closed loop — this script is just configuration.

Success rate on the reach task should climb far above the random policy.

Run:  PYTHONPATH=src python examples/embodied_ppo.py [--iters 60]
      [--mode auto|collocated|hybrid] [--checkpoint-dir ck --every 10]
"""
import argparse
import sys

from repro.rl import EmbodiedPPOConfig, EmbodiedPPORunner


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--envs", type=int, default=64)
    ap.add_argument("--horizon", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "collocated", "hybrid"],
                    help="cycle realization (auto = Algorithm 1 picks)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="periodic trainer checkpoints; rerunning with "
                         "the same dir resumes from the last save")
    ap.add_argument("--every", type=int, default=10,
                    help="checkpoint period (iterations)")
    args = ap.parse_args(argv)

    rl = EmbodiedPPOConfig(
        num_envs=args.envs, horizon=args.horizon, iterations=args.iters,
        lr=args.lr, mode=args.mode,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.every if args.checkpoint_dir else 0)
    runner = EmbodiedPPORunner(rl)
    print("M2Flow plan for the embodied workflow "
          "(cycle collapsed into one node):")
    runner.run(verbose=True)

    curve = runner.success_curve()
    if not curve:  # checkpoint already covered every iteration
        print("\ncheckpoint already covers all requested iterations; "
              "raise --iters to continue training")
        return 0
    first = sum(curve[:10]) / min(len(curve), 10)
    final = sum(curve[-10:]) / len(curve[-10:])
    print(f"\nsuccess rate: first10={first:.2f} -> last10={final:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
