"""Embodied RL driver: PPO-style training of a token policy against the
vectorized CPU simulator, with the cyclic sim↔generation workflow that
drives the paper's hybrid scheduling (Fig. 1 bottom-left, Fig. 9).

The simulator↔policy loop forms a CYCLE in the workflow graph; the
scheduler collapses it into a single node (Algorithm 1 line 2) and then
chooses hybrid/temporal placement for {cycle, advantage, train}.

The policy is a small decoder-only LM over discretized observations:
prompt = [BOS, obs-token ×4] → one action token (9 discrete actions).
Success rate on the reach task should climb far above the random policy.

Run:  PYTHONPATH=src python examples/embodied_ppo.py [--iters 60]
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Cluster, Controller, FlowGraph, SchedulerConfig
from repro.core.profiler import CostModel
from repro.models import forward, init_model
from repro.models.layers import token_logprobs
from repro.rl.advantage import gae_advantages, whiten
from repro.rl.env import EnvConfig, VecReachEnv
from repro.train.optimizer import AdamWConfig, init_adamw
from repro.train.trainer import TrainHParams, make_train_step

# token layout
PAD, BOS = 0, 1
OBS_BASE, OBS_BINS, OBS_DIM = 2, 6, 4
ACT_BASE, NUM_ACTIONS = OBS_BASE + OBS_BINS * OBS_DIM, 9
VOCAB = ACT_BASE + NUM_ACTIONS  # 35
SEQ = 1 + OBS_DIM + 1  # BOS + obs + action


def obs_to_tokens(obs: np.ndarray) -> np.ndarray:
    """(N, 4) float obs -> (N, 5) int tokens [BOS, d0..d3]."""
    clipped = np.clip((obs + 1.5) / 3.0, 0.0, 0.999)
    bins = (clipped * OBS_BINS).astype(np.int32)
    toks = OBS_BASE + np.arange(OBS_DIM)[None, :] * OBS_BINS + bins
    return np.concatenate(
        [np.full((obs.shape[0], 1), BOS, np.int32), toks.astype(np.int32)],
        axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--envs", type=int, default=64)
    ap.add_argument("--horizon", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args(argv)

    cfg = get_config("stablelm-12b").reduced().replace(
        name="stablelm-policy", vocab_size=VOCAB, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, max_seq_len=SEQ)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    opt = init_adamw(params)
    hp = TrainHParams(optimizer=AdamWConfig(lr=args.lr, clip_norm=1.0),
                      clip_eps_low=0.2, clip_eps_high=0.2)
    train_step = jax.jit(make_train_step(cfg, hp))

    @jax.jit
    def act(params, prompt, key):
        logits, _ = forward(params, cfg, prompt)
        last = logits[:, -1].astype(jnp.float32)
        mask = (jnp.arange(last.shape[-1]) >= ACT_BASE) & (
            jnp.arange(last.shape[-1]) < ACT_BASE + NUM_ACTIONS)
        last = jnp.where(mask, last, -1e30)
        tok = jax.random.categorical(key, last, axis=-1)
        lp = token_logprobs(last, tok)
        return tok.astype(jnp.int32), lp

    env = VecReachEnv(EnvConfig(num_envs=args.envs,
                                max_steps=args.horizon), seed=0)

    # ---- workflow graph with the sim<->policy cycle; the controller plans
    # the hybrid schedule exactly as for any workflow ----
    g = FlowGraph()
    for w in ("simulator", "policy_gen", "advantage", "train"):
        g.add_worker(w)
    g.add_edge("simulator", "policy_gen")
    g.add_edge("policy_gen", "simulator")  # the cycle
    g.add_edge("policy_gen", "advantage")
    g.add_edge("advantage", "train")
    profiles = {
        "simulator": CostModel("simulator", base_time=0.2, slope_time=1e-4,
                               scalable=False, max_useful_devices=4),
        "policy_gen": CostModel("policy_gen", base_time=0.05,
                                slope_time=2e-3, onload_time=0.2,
                                offload_time=0.2),
        "advantage": CostModel("advantage", base_time=0.01, slope_time=1e-5),
        "train": CostModel("train", base_time=0.1, slope_time=1e-3,
                           onload_time=0.4, offload_time=0.3),
    }
    ctl = Controller(Cluster(num_nodes=1, devices_per_node=8),
                     profiles=profiles,
                     scheduler_cfg=SchedulerConfig(
                         total_batch=args.envs,
                         granularity_divisors=(1, 2, 4), device_quantum=2))
    plan = ctl.plan(g, total_batch=args.envs, mode="auto")
    print("M2Flow plan for the embodied workflow "
          "(cycle collapsed into one node):")
    print(plan.pretty())

    succ_hist = []
    for it in range(args.iters):
        t0 = time.time()
        # ---- rollout the cycle for `horizon` steps ----
        toks = np.zeros((args.horizon, args.envs, SEQ), np.int32)
        lps = np.zeros((args.horizon, args.envs), np.float32)
        rews = np.zeros((args.horizon, args.envs), np.float32)
        dones = np.zeros((args.horizon, args.envs), np.float32)
        successes = 0
        obs = env.observe()
        for t in range(args.horizon):
            prompt = obs_to_tokens(obs)
            key, sub = jax.random.split(key)
            a_tok, lp = act(params, jnp.asarray(prompt), sub)
            a_tok, lp = np.asarray(a_tok), np.asarray(lp)
            obs, r, d, info = env.step(a_tok - ACT_BASE)
            toks[t, :, :SEQ - 1] = prompt
            toks[t, :, SEQ - 1] = a_tok
            lps[t] = lp
            rews[t] = r
            dones[t] = d
            successes += int(info["success"].sum())

        # ---- advantages: whitened discounted returns (critic-free PPO) ----
        values = np.zeros((args.horizon + 1, args.envs), np.float32)
        adv, _ = gae_advantages(rews, values, dones, gamma=0.95, lam=1.0)
        adv = whiten(adv)

        # ---- PPO update over all (env, step) transitions ----
        B = args.horizon * args.envs
        tokens = toks.reshape(B, SEQ)
        old_lp = np.zeros((B, SEQ), np.float32)
        old_lp[:, SEQ - 1] = lps.reshape(B)
        advantages = np.zeros((B, SEQ), np.float32)
        advantages[:, SEQ - 1] = adv.reshape(B)
        mask = np.zeros((B, SEQ), np.float32)
        mask[:, SEQ - 1] = 1.0
        params, opt, metrics = train_step(params, opt, {
            "tokens": jnp.asarray(tokens),
            "old_logprobs": jnp.asarray(old_lp),
            "advantages": jnp.asarray(advantages),
            "loss_mask": jnp.asarray(mask)})
        rate = successes / args.envs
        succ_hist.append(rate)
        if it % 5 == 0 or it == args.iters - 1:
            w = succ_hist[-10:]
            print(f"iter {it:3d} wall={time.time() - t0:5.2f}s "
                  f"success/env={rate:5.2f} avg10={sum(w)/len(w):5.2f} "
                  f"reward={rews.sum(0).mean():+6.2f}")
    final = sum(succ_hist[-10:]) / len(succ_hist[-10:])
    first = sum(succ_hist[:10]) / min(len(succ_hist), 10)
    print(f"\nsuccess rate: first10={first:.2f} -> last10={final:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
