"""Deep-Research workflow (paper Fig. 1 bottom-right): RL with a search
tool in the loop — the fourth and last of the paper's scenarios.

A synthetic "web": facts map a topic token to an answer digit.  Facts
are RESAMPLED EVERY ITERATION, so memorizing topic→answer is impossible —
the only way to beat chance (10%) is to (1) QUERY the topic shown in the
prompt (the tool returns that topic's current fact) and then (2) COPY
the observed fact as the answer.  Reward is the rule-based ±5.

The policy↔tool loop is a CYCLE in the workflow graph; M2Flow collapses
it and schedules {cycle, reward, train} exactly as in the embodied case.

Run:  PYTHONPATH=src python examples/deep_research.py [--iters 80]
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Cluster, Controller, FlowGraph, SchedulerConfig
from repro.core.profiler import CostModel
from repro.models import forward, init_model
from repro.models.layers import token_logprobs
from repro.rl.advantage import grpo_advantages, broadcast_to_tokens
from repro.train.optimizer import AdamWConfig, init_adamw
from repro.train.trainer import TrainHParams, make_train_step

# token layout: PAD 0, BOS 1, digits 2..11, topics 12..19, QUERY=20+topic
PAD, BOS, D0 = 0, 1, 2
N_TOPICS, TOPIC0 = 8, 12
QUERY0 = TOPIC0 + N_TOPICS  # query actions 20..27
VOCAB = QUERY0 + N_TOPICS  # 28
SEQ = 8  # [BOS, topic, query, fact, ans, EOSish pad...]


def build_graph() -> FlowGraph:
    """Workflow graph: policy <-> tool cycle + reward + train.  Module
    level so flowlint can lint the example's graph without running it."""
    g = FlowGraph()
    for w in ("policy_gen", "search_tool", "reward", "train"):
        g.add_worker(w)
    g.add_edge("policy_gen", "search_tool")
    g.add_edge("search_tool", "policy_gen")  # the tool loop
    g.add_edge("policy_gen", "reward")
    g.add_edge("reward", "train")
    return g


def cycle_specs(steps: int = 2, chunks: int = 2):
    """CycleSpec for the collapsed policy↔tool loop (2 steps per sample:
    query, then answer)."""
    from repro.core.flowgraph import cycle_node_name
    from repro.core.pipeline import CycleSpec
    name = cycle_node_name(("policy_gen", "search_tool"))
    return {name: CycleSpec(order=("policy_gen", "search_tool"),
                            steps=steps, chunks=chunks)}


def cost_models():
    return {
        "policy_gen": CostModel("policy_gen", base_time=0.05,
                                slope_time=2e-3, onload_time=0.2,
                                offload_time=0.2),
        "search_tool": CostModel("search_tool", base_time=0.08,
                                 slope_time=1e-4, scalable=False,
                                 max_useful_devices=2),
        "reward": CostModel("reward", base_time=0.01, slope_time=1e-5),
        "train": CostModel("train", base_time=0.1, slope_time=1e-3,
                           onload_time=0.4, offload_time=0.3),
    }


class SearchToolWorker:
    """The search server: topic -> fact token (its current answer digit).
    refresh() re-randomizes the corpus — the anti-memorization device."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.refresh()

    def refresh(self) -> None:
        self.facts = self.rng.integers(0, 10, N_TOPICS)

    def search(self, query_topic: np.ndarray) -> np.ndarray:
        """query actions (B,) in [0, N_TOPICS) -> fact tokens (B,)."""
        return (D0 + self.facts[query_topic]).astype(np.int32)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=80)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--group", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args(argv)

    cfg = get_config("codeqwen1.5-7b").reduced().replace(
        name="dr-policy", vocab_size=VOCAB, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, max_seq_len=SEQ)
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    opt = init_adamw(params)
    hp = TrainHParams(optimizer=AdamWConfig(lr=args.lr, clip_norm=1.0),
                      entropy_coef=0.02)
    train_step = jax.jit(make_train_step(cfg, hp))
    tool = SearchToolWorker(seed=0)

    @jax.jit
    def act(params, toks, lo, hi, key):
        logits, _ = forward(params, cfg, toks)
        last = logits[:, -1].astype(jnp.float32)
        ar = jnp.arange(last.shape[-1])
        last = jnp.where((ar >= lo) & (ar < hi), last, -1e30)
        tok = jax.random.categorical(key, last, axis=-1)
        return tok.astype(jnp.int32), token_logprobs(last, tok)

    # ---- workflow graph: policy <-> tool cycle + reward + train ----
    g = build_graph()
    profiles = cost_models()
    ctl = Controller(Cluster(num_nodes=1, devices_per_node=8),
                     profiles=profiles,
                     scheduler_cfg=SchedulerConfig(
                         total_batch=args.batch,
                         granularity_divisors=(1, 2, 4), device_quantum=2))
    plan = ctl.plan(g, total_batch=args.batch, mode="auto")
    print("M2Flow plan for the deep-research workflow:")
    print(plan.pretty())

    rng = np.random.default_rng(1)
    accs = []
    B = args.batch
    for it in range(args.iters):
        t0 = time.time()
        tool.refresh()  # new facts every iteration: querying is mandatory
        n_q = B // args.group
        topics = np.repeat(rng.integers(0, N_TOPICS, n_q), args.group)
        answers = tool.facts[topics]  # ground truth digits

        toks = np.full((B, SEQ), PAD, np.int32)
        toks[:, 0] = BOS
        toks[:, 1] = TOPIC0 + topics
        lps = np.zeros((B, SEQ), np.float32)
        mask = np.zeros((B, SEQ), np.float32)

        # step 1: policy chooses a QUERY action (which topic to search)
        key, k1 = jax.random.split(key)
        q_tok, q_lp = act(params, jnp.asarray(toks[:, :2]),
                          QUERY0, QUERY0 + N_TOPICS, k1)
        q_tok, q_lp = np.asarray(q_tok), np.asarray(q_lp)
        toks[:, 2] = q_tok
        lps[:, 2] = q_lp
        mask[:, 2] = 1.0
        # the tool returns the queried topic's fact
        fact = tool.search(q_tok - QUERY0)
        toks[:, 3] = fact  # observation (not a policy action)

        # step 2: policy answers with a digit
        key, k2 = jax.random.split(key)
        a_tok, a_lp = act(params, jnp.asarray(toks[:, :4]), D0, D0 + 10, k2)
        a_tok, a_lp = np.asarray(a_tok), np.asarray(a_lp)
        toks[:, 4] = a_tok
        lps[:, 4] = a_lp
        mask[:, 4] = 1.0

        rewards = np.where(a_tok - D0 == answers, 5.0, -5.0).astype(np.float32)
        adv = broadcast_to_tokens(grpo_advantages(rewards, args.group), mask)
        params, opt, metrics = train_step(params, opt, {
            "tokens": jnp.asarray(toks),
            "old_logprobs": jnp.asarray(lps),
            "advantages": jnp.asarray(adv),
            "loss_mask": jnp.asarray(mask)})
        acc = float((rewards > 0).mean())
        accs.append(acc)
        if it % 10 == 0 or it == args.iters - 1:
            qacc = float((q_tok - QUERY0 == topics).mean())
            print(f"iter {it:3d} wall={time.time() - t0:5.2f}s "
                  f"answer_acc={acc:4.2f} query_acc={qacc:4.2f} "
                  f"avg10={np.mean(accs[-10:]):4.2f}")
    first, last = np.mean(accs[:10]), np.mean(accs[-10:])
    print(f"\nanswer accuracy: first10={first:.2f} -> last10={last:.2f} "
          f"(chance=0.1)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
