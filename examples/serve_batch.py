"""Batched serving example: static vs continuous batching side by side.

Serves a small model over batched "requests" (synthetic math prompts)
through either engine:

  * ``--engine static``  — the legacy fixed-shape engine (every request
    padded to the longest response; the Fig. 2 long-tail stall);
  * ``--engine paged``   — the continuous-batching engine (paged
    KV-cache, per-step join/evict, per-request budgets);
  * ``--engine both``    — run the same workload through both and report
    the speedup (the bench_longtail comparison, interactively).

By default each request gets a skewed generation budget (most short, a
few stragglers at the max — the Fig. 2 long-tail shape); the static
engine must pad every request to the longest budget, the paged engine
retires each request at its own budget and backfills the slot.  Pass
``--uniform`` to give every request the same budget and watch the
speedup vanish (continuous batching only wins when lengths vary).

Reports per-batch latency, useful tokens/s, and the response-length CDF.

``--shared-prefix`` switches to the GRPO-group workload instead: every
group is ``--group-size`` requests with an IDENTICAL prompt (the shape a
GRPO rollout tier serves every iteration).  The same workload runs with
prefix sharing ON and OFF; with sharing on, each group's prompt KV
prefills once and the other members adopt the pages through the radix
cache, so the throughput ratio measures exactly what the prefix cache
buys.  ``--json PATH`` merges the result into an existing
BENCH_serve.json (the bench-serve-smoke CI gate asserts the ratio).

``--arch`` serves any covered architecture from the config zoo through
its cache layout — e.g. ``--arch mamba2-370m`` runs the same skewed
workload through the constant-size state cache (no page growth during
decode), ``--arch granite-moe-3b-a800m`` through the expert-parallel
MoE decode path.  With ``--json PATH`` the static-vs-paged comparison
is merged under the ``arch_serve.<arch>`` key of BENCH_serve.json (the
arch-serve-smoke CI gate asserts the speedup).

Run:  PYTHONPATH=src python examples/serve_batch.py [--requests 64]
          [--engine both] [--uniform] [--arch mamba2-370m]
      PYTHONPATH=src python examples/serve_batch.py --shared-prefix
          [--groups 4] [--group-size 8] [--prompt-len 64]
"""
import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_model
from repro.serve import Engine, PagedEngine
from repro.train.data import PromptDataset


def make_setup(args):
    # sized so a decode step is compute-bound on CPU (the regime where
    # the batching policy, not Python dispatch, decides throughput)
    kw = dict(vocab_size=256, max_seq_len=max(128, 8 + args.max_new))
    if args.arch == "codeqwen1.5-7b":
        kw.update(d_model=256, num_heads=4, num_kv_heads=2,
                  head_dim=64, d_ff=1024)
    cfg = get_config(args.arch).reduced().replace(**kw)
    params = init_model(jax.random.PRNGKey(0), cfg)
    data = PromptDataset(args.requests, prompt_len=8, seed=1)
    prompts = np.asarray(data.next_batch()["prompt_tokens"])
    return cfg, params, prompts


def make_budgets(args):
    """Per-request generation budgets.  Default: the Fig. 2 long-tail
    shape (most responses short, one straggler at the cap per static
    batch); ``--uniform``: everyone gets the same budget, the regime
    where continuous batching has nothing to reclaim."""
    if args.uniform:
        return np.full(args.requests, args.max_new, dtype=int)
    rng = np.random.default_rng(0)
    ls = rng.lognormal(np.log(args.max_new / 8.0), 0.7, size=args.requests)
    budgets = np.clip(np.round(ls), 2, args.max_new // 3).astype(int)
    budgets[0::args.batch] = args.max_new  # one straggler per static batch
    return budgets


def run_static(cfg, params, prompts, budgets, args):
    # a fixed-shape scan cannot stop per request: every batch pads to the
    # longest budget in the workload (eos=-1 so lengths are budget-driven
    # and the two engines do identical useful work)
    pad_to = int(budgets.max())
    eng = Engine(cfg, max_new_tokens=pad_to, temperature=0.8, eos_token=-1)
    eng.generate(params, jax.numpy.asarray(prompts[:args.batch]),
                 key=jax.random.PRNGKey(9)).tokens.block_until_ready()
    total_useful = 0
    t_start = time.time()
    for i in range(0, args.requests, args.batch):
        chunk = prompts[i:i + args.batch]
        t0 = time.time()
        eng.generate(params, jax.numpy.asarray(chunk),
                     key=jax.random.PRNGKey(i)).tokens.block_until_ready()
        dt = time.time() - t0
        b = budgets[i:i + args.batch]
        total_useful += int(b.sum()) + chunk.size
        print(f"static batch {i // args.batch}: {dt*1e3:7.1f} ms  "
              f"padded_to={pad_to} useful_mean={b.mean():5.1f}")
    return time.time() - t_start, total_useful


def run_paged(cfg, params, prompts, budgets, args):
    eng = PagedEngine(cfg, max_batch=args.batch, page_size=8,
                      max_new_tokens=int(budgets.max()), temperature=0.8,
                      eos_token=-1)
    eng.set_params(params)
    eng.submit(prompts[0], max_new_tokens=2, seed=123)  # warm-up/compile
    eng.run()
    t_start = time.time()
    reqs = [eng.submit(prompts[i], max_new_tokens=int(budgets[i]), seed=i)
            for i in range(args.requests)]
    eng.run()
    wall = time.time() - t_start
    total_tokens = sum(r.total_len for r in reqs)
    print(f"paged: {args.requests} requests, {eng.decode_steps} engine "
          f"steps, peak batch {eng.scheduler.stats.peak_active}, "
          f"layout {eng.layout.name}")
    return wall, total_tokens, eng.layout.name


def report(name, wall, total_tokens, n):
    print(f"[{name}] served {n} requests in {wall:.2f}s "
          f"({total_tokens / wall:.0f} useful tok/s)\n")


def run_shared_prefix(args):
    """GRPO-group workload: groups of identical prompts, prefix sharing
    on vs off.  Long prompts + short generations so prompt prefill
    dominates — the component sharing removes."""
    cfg = get_config("codeqwen1.5-7b").reduced().replace(
        vocab_size=256, d_model=256, num_heads=4, num_kv_heads=2,
        head_dim=64, d_ff=1024,
        max_seq_len=max(128, args.prompt_len + args.max_new))
    params = init_model(jax.random.PRNGKey(0), cfg)
    data = PromptDataset(args.groups, prompt_len=args.prompt_len, seed=1)
    uniq = np.asarray(data.next_batch()["prompt_tokens"])
    prompts = np.repeat(uniq, args.group_size, axis=0)
    n = len(prompts)

    def make_engine(sharing):
        eng = PagedEngine(cfg, max_batch=args.batch, page_size=8,
                          max_new_tokens=args.max_new, temperature=0.8,
                          eos_token=-1, prefix_sharing=sharing)
        eng.set_params(params)
        eng.submit(prompts[0], max_new_tokens=2, seed=999)  # compile
        eng.run()
        eng.release_prefix_cache()  # warm-up prompt must not hit later
        eng.allocator.pages_allocated_total = 0
        return eng

    def timed_pass(eng, rep):
        t0 = time.time()
        for i in range(n):
            eng.submit(prompts[i], seed=1000 * rep + i)
        eng.run()
        dt = time.time() - t0
        eng.release_prefix_cache()  # each pass starts cache-cold
        return dt

    on_eng, off_eng = make_engine(True), make_engine(False)
    # alternate repeats so bursty CPU allocation hits both modes alike
    wall_on, wall_off = float("inf"), float("inf")
    for rep in range(args.repeats):
        wall_on = min(wall_on, timed_pass(on_eng, rep))
        wall_off = min(wall_off, timed_pass(off_eng, rep))

    useful = n * (args.prompt_len + args.max_new)
    ratio = wall_off / wall_on
    hits = on_eng.scheduler.stats.prefix_hit_tokens
    print(f"workload: {args.groups} groups x {args.group_size} identical "
          f"prompts ({args.prompt_len} tokens), {args.max_new} new each")
    print(f"pages allocated  shared={on_eng.allocator.pages_allocated_total}"
          f"  private={off_eng.allocator.pages_allocated_total}"
          f"  (prompt tokens skipped via cache: {hits})")
    report("sharing on ", wall_on, useful, n)
    report("sharing off", wall_off, useful, n)
    print(f"shared-prefix speedup: {ratio:.2f}x")

    result = {
        "workload": {
            "groups": args.groups, "group_size": args.group_size,
            "prompt_len": args.prompt_len, "max_new": args.max_new,
            "slots": args.batch, "repeats": args.repeats,
        },
        "sharing_on": {
            "wall_s": wall_on, "tok_per_s": useful / wall_on,
            "pages_allocated": on_eng.allocator.pages_allocated_total,
            "prefix_hit_tokens": hits,
        },
        "sharing_off": {
            "wall_s": wall_off, "tok_per_s": useful / wall_off,
            "pages_allocated": off_eng.allocator.pages_allocated_total,
        },
        "speedup": ratio,
    }
    if args.json:
        try:
            with open(args.json) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
        merged["shared_prefix"] = result
        with open(args.json, "w") as f:
            json.dump(merged, f, indent=2)
        print(f"# merged shared_prefix into {args.json}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=None,
                    help="generation budget cap (default: 48; 8 under "
                         "--shared-prefix, where prompt prefill should "
                         "dominate)")
    ap.add_argument("--arch", default="codeqwen1.5-7b",
                    help="config-zoo architecture to serve (any arch a "
                         "cache layout covers: dense, MoE, SSM, hybrid)")
    ap.add_argument("--engine", choices=("static", "paged", "both"),
                    default="both")
    ap.add_argument("--uniform", action="store_true",
                    help="same budget for every request (no long tail)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="GRPO-group workload: identical prompts per "
                         "group, prefix sharing on vs off")
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="merge the result (shared_prefix, or "
                         "arch_serve.<arch> for the engine comparison) "
                         "into this BENCH_serve.json")
    args = ap.parse_args(argv)
    if args.max_new is None:
        args.max_new = 8 if args.shared_prefix else 48
    if args.shared_prefix:
        return run_shared_prefix(args)
    cfg, params, prompts = make_setup(args)
    budgets = make_budgets(args)

    print("response-length CDF (the Fig. 2 long-tail view):")
    for q in (50, 90, 95, 99, 100):
        print(f"  p{q:<3d} = {np.percentile(budgets, q):5.1f} tokens")
    print()

    walls, toks, layout = {}, {}, None
    if args.engine in ("static", "both"):
        wall, tok = run_static(cfg, params, prompts, budgets, args)
        report("static", wall, tok, args.requests)
        walls["static"], toks["static"] = wall, tok
    if args.engine in ("paged", "both"):
        wall, tok, layout = run_paged(cfg, params, prompts, budgets, args)
        report("paged", wall, tok, args.requests)
        walls["paged"], toks["paged"] = wall, tok
    if len(walls) == 2:
        speedup = walls["static"] / walls["paged"]
        print(f"continuous-batching speedup: {speedup:.2f}x")
        if args.json:
            result = {
                "arch": args.arch, "layout": layout,
                "workload": {
                    "requests": args.requests, "slots": args.batch,
                    "max_new": args.max_new, "uniform": args.uniform,
                },
                "static": {"wall_s": walls["static"],
                           "tok_per_s": toks["static"] / walls["static"]},
                "paged": {"wall_s": walls["paged"],
                          "tok_per_s": toks["paged"] / walls["paged"]},
                "speedup": speedup,
            }
            try:
                with open(args.json) as f:
                    merged = json.load(f)
            except (OSError, ValueError):
                merged = {}
            merged.setdefault("arch_serve", {})[args.arch] = result
            with open(args.json, "w") as f:
                json.dump(merged, f, indent=2)
            print(f"# merged arch_serve[{args.arch!r}] into {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
