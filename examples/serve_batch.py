"""Batched serving example: continuous batched decode with the KV-cache
engine — the rollout-worker compute path in isolation (deliverable b).

Serves a small model over batched "requests" (synthetic math prompts),
reporting per-batch latency, tokens/s, and the response-length CDF —
the long-tail distribution the paper measures in Fig. 2.

Run:  PYTHONPATH=src python examples/serve_batch.py [--requests 128]
"""
import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_model
from repro.serve import Engine
from repro.train.data import PromptDataset

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config("codeqwen1.5-7b").reduced().replace(
        vocab_size=32, d_model=128, num_heads=4, num_kv_heads=4, d_ff=256)
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, max_new_tokens=args.max_new, temperature=0.8)
    data = PromptDataset(args.batch, prompt_len=8, seed=1)

    lengths, lat = [], []
    total_tokens = 0
    t_start = time.time()
    for i in range(args.requests // args.batch):
        batch = data.next_batch()
        t0 = time.time()
        res = eng.generate(params, np.asarray(batch["prompt_tokens"]),
                           key=jax.random.PRNGKey(i))
        dt = time.time() - t0
        lat.append(dt)
        new = np.asarray(res.lengths) - batch["prompt_tokens"].shape[1]
        lengths.extend(new.tolist())
        total_tokens += int(np.asarray(res.lengths).sum())
        print(f"batch {i}: {dt*1e3:7.1f} ms  "
              f"mean_new={new.mean():5.1f} max_new={new.max()}")

    wall = time.time() - t_start
    ls = np.array(lengths)
    print(f"\nserved {args.requests} requests in {wall:.2f}s "
          f"({total_tokens / wall:.0f} tok/s)")
    print("response-length CDF (the Fig. 2 long-tail view):")
    for q in (50, 90, 95, 99, 100):
        print(f"  p{q:<3d} = {np.percentile(ls, q):5.1f} tokens")
    return 0


if __name__ == "__main__":
    sys.exit(main())
