"""Quickstart: program an RL workflow imperatively, let M2Flow schedule it.

Mirrors the paper's Fig. 5 programming model: worker definitions live in
``repro.rl.workers``; this runner composes them in <30 lines and compares
the three execution modes on the same logical workflow — no code changes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys

from repro.configs import get_config
from repro.rl import GRPOConfig, GRPORunner
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainHParams


def main():
    # a tiny same-family variant of one of the assigned archs
    cfg = get_config("yi-9b").reduced().replace(
        vocab_size=32, d_model=128, num_heads=4, num_kv_heads=2, d_ff=256)
    hp = TrainHParams(optimizer=AdamWConfig(lr=1e-3))

    results = {}
    for mode in ("collocated", "disaggregated", "auto"):
        rl = GRPOConfig(batch_size=16, group_size=4, iterations=5,
                        max_new_tokens=6, mode=mode, seed=0)
        runner = GRPORunner(cfg, rl, hp)
        runner.run(verbose=False)
        results[mode] = runner.throughput()
        print(f"[{mode:>13s}] throughput = {results[mode]:8.1f} tok/s   "
              f"plan: {type(runner.plan.schedule).__name__}")

    best = max(results, key=results.get)
    print(f"\nM2Flow-selected mode ('auto') vs fixed modes: "
          f"auto={results['auto']:.0f} tok/s, best fixed={best}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
