#!/usr/bin/env python
"""flowlint CLI — static analysis over every workflow/example graph.

Runs the M2Flow transformation for each lint target (the three workflow
families in every planning mode, plus every example graph), then lints
graph + plan + implied channel topology, and finally sweeps the Pallas
kernel registry and the RNG keying schemes at the config-zoo shapes.

Exit status is 1 if any finding at or above ``--fail-on`` (default:
warning) survives — the contract the ``flowlint-smoke`` CI job enforces.

Run:  PYTHONPATH=src python tools/flowlint.py [-v] [--target NAME ...]
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--target", action="append", default=None,
                    metavar="NAME",
                    help="lint only targets whose name contains NAME "
                         "(repeatable; default: all)")
    ap.add_argument("--fail-on", choices=("info", "warning", "error"),
                    default="warning",
                    help="exit nonzero on any finding at or above this "
                         "severity (default: warning)")
    ap.add_argument("--no-kernels", action="store_true",
                    help="skip the kernel/RNG pass (Pass 3)")
    ap.add_argument("--list", action="store_true",
                    help="list lint targets and exit")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print per-target results even when clean")
    args = ap.parse_args(argv)

    from repro.analysis import (
        analyze_target,
        check_kernels,
        check_rng,
        filter_findings,
        format_findings,
    )
    from repro.analysis.targets import all_targets

    targets = all_targets()
    if args.target:
        targets = [t for t in targets
                   if any(pat in t.name for pat in args.target)]
        if not targets:
            print(f"flowlint: no target matches {args.target}",
                  file=sys.stderr)
            return 2
    if args.list:
        for t in targets:
            print(t.name)
        return 0

    t0 = time.perf_counter()
    all_findings = []
    for t in targets:
        findings = analyze_target(t)
        all_findings.extend(findings)
        if findings or args.verbose:
            print(format_findings(
                findings, header=f"== {t.name} ({len(t.graph.nodes)} "
                                 f"nodes) =="))
    if not args.no_kernels:
        findings = check_kernels() + check_rng()
        all_findings.extend(findings)
        if findings or args.verbose:
            print(format_findings(findings, header="== kernels + rng =="))

    gating = filter_findings(all_findings, args.fail_on)
    dt = time.perf_counter() - t0
    n_k = "skipped" if args.no_kernels else "swept"
    print(f"flowlint: {len(targets)} target(s), kernels {n_k}: "
          f"{len(all_findings)} finding(s), {len(gating)} at or above "
          f"{args.fail_on!r} [{dt:.2f}s]")
    return 1 if gating else 0


if __name__ == "__main__":
    sys.exit(main())
