#!/usr/bin/env python
"""Docs link-check: every intra-repo markdown link and every
backtick-quoted repo path referenced in docs/README must resolve.

Checked files:  README.md, docs/*.md
Checked refs:   [text](relative/path)  markdown links (non-http)
                `path/to/file.py`      backtick paths that look repo-like
                `pkg.mod.attr`         dotted repro.* module paths

Exits non-zero listing every dangling reference.
"""
from __future__ import annotations

import importlib
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)#]+)(?:#[^)]*)?\)")
# backtick path-ish tokens: contain a '/' and end in a known suffix
BT_PATH = re.compile(r"`([\w./-]+/[\w./-]+\.(?:py|md|yml|yaml|json))`")
# dotted repro module references like repro.core.scheduler or
# repro.rl.advantage.staleness_importance_weights
BT_MOD = re.compile(r"`(repro(?:\.\w+)+)`")


def check_file(md: Path, errors: list) -> None:
    text = md.read_text()
    base = md.parent
    for m in MD_LINK.finditer(text):
        target = m.group(1).strip()
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if not (base / target).exists() and not (ROOT / target).exists():
            errors.append(f"{md.relative_to(ROOT)}: dangling link {target}")
    for m in BT_PATH.finditer(text):
        target = m.group(1)
        if not (ROOT / target).exists():
            errors.append(f"{md.relative_to(ROOT)}: missing path {target}")
    for m in BT_MOD.finditer(text):
        dotted = m.group(1)
        parts = dotted.split(".")
        # try longest importable prefix; the tail may be an attribute
        for cut in range(len(parts), 0, -1):
            mod = ".".join(parts[:cut])
            try:
                obj = importlib.import_module(mod)
            except ImportError:
                continue
            ok = True
            for attr in parts[cut:]:
                if not hasattr(obj, attr):
                    ok = False
                    break
                obj = getattr(obj, attr)
            if ok:
                break
        else:
            ok = False
        if not ok:
            errors.append(f"{md.relative_to(ROOT)}: unresolvable "
                          f"module ref {dotted}")


def main() -> int:
    sys.path.insert(0, str(ROOT / "src"))
    files = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    errors: list = []
    for md in files:
        if md.exists():
            check_file(md, errors)
    if errors:
        print("\n".join(errors))
        print(f"\n{len(errors)} dangling doc reference(s)")
        return 1
    print(f"docs link-check OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
