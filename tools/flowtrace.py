#!/usr/bin/env python
"""flowtrace CLI — run a workflow family under tracing, emit artifacts.

For each selected family (grpo / rlhf / embodied) this builds a tiny
reduced-config runner on a dry-run cluster (topology from
``REPRO_DRYRUN_HOSTS`` / ``REPRO_DRYRUN_DEVICES``, default 2x4),
profiles and plans it UNTRACED (so the artifact shows the executed run,
not the profiler's calibration churn), then arms the global tracer for
the training loop and writes:

  * ``<out>.<family>.trace.json``  — Chrome-trace/Perfetto timeline
  * ``<out>.<family>.report.json`` — plan-vs-actual report (wall ratio,
    per-device busy/bubble + gap attribution, drift table)

plus the text report and the metrics snapshot on stdout.  ``--check``
turns report anomalies into exit status 1 (the trace-smoke CI gate);
``--overhead`` measures the tracing tax on a toy executor workload.

Run:  PYTHONPATH=src python tools/flowtrace.py --family grpo --out OUT
"""
from __future__ import annotations

import argparse
import json
import sys
import time

FAMILIES = ("grpo", "rlhf", "embodied")


# ---------------------------------------------------------------------------
# tiny reduced-config runners (mirror tests/test_faults.py's e2e builders)
# ---------------------------------------------------------------------------
def _tiny_model(name):
    from repro.configs import get_config
    return get_config(name).reduced().replace(
        vocab_size=32, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128)


def build_runner(family: str, iterations: int, cluster):
    if family == "grpo":
        from repro.rl import GRPOConfig, GRPORunner
        from repro.train import TrainHParams
        from repro.train.optimizer import AdamWConfig
        rl = GRPOConfig(batch_size=8, group_size=4, iterations=iterations,
                        max_new_tokens=4, mode="auto", seed=0,
                        profile_batches=(4, 8))
        return GRPORunner(_tiny_model("yi-9b"), rl,
                          TrainHParams(optimizer=AdamWConfig(lr=1e-3)),
                          cluster=cluster)
    if family == "rlhf":
        from repro.rl import PPOConfig, RLHFRunner
        return RLHFRunner(
            _tiny_model("stablelm-12b"),
            PPOConfig(batch_size=8, iterations=iterations, max_new_tokens=3,
                      seed=0, profile_batches=(4, 8)),
            cluster=cluster)
    if family == "embodied":
        from repro.rl import EmbodiedPPOConfig, EmbodiedPPORunner
        rl = EmbodiedPPOConfig(num_envs=8, horizon=4, iterations=iterations,
                               mode="collocated", seed=0, max_steps=8,
                               profile_batches=(4, 8))
        return EmbodiedPPORunner(rl, cluster=cluster)
    raise ValueError(family)


# ---------------------------------------------------------------------------
def trace_family(family: str, iterations: int, out_prefix: str,
                 verbose: bool) -> dict:
    """Profile + plan untraced, run the loop traced, write artifacts.
    Returns the report's JSON dict (with artifact paths added)."""
    from repro.comm.primitives import reset_router
    from repro.launch.cluster import cluster_from_env
    from repro.obs import default_registry, format_snapshot, tracing
    from repro.obs.report import plan_vs_actual, report_to_json_file

    reset_router()
    default_registry().clear()
    cluster = cluster_from_env(default_hosts=2, default_devices=4)
    runner = build_runner(family, iterations, cluster)
    runner.profile()
    runner.plan_execution()
    if verbose:
        print(runner.plan.pretty())
    # one untraced warmup iteration: the first call at the training
    # shapes pays JIT compilation, which would drown the schedule in the
    # artifact and skew the drift table by orders of magnitude
    runner.run_iteration(0)

    with tracing() as tr:
        runner.run_loop(verbose=False)

    report = plan_vs_actual(runner.plan, runner.controller.profiles, tr,
                            runner.batch_size, iterations=iterations)
    trace_path = f"{out_prefix}.{family}.trace.json"
    report_path = f"{out_prefix}.{family}.report.json"
    tr.export(trace_path)
    report_to_json_file(report, report_path)

    print(f"\n=== {family} ===")
    print(report.format())
    snap = default_registry().snapshot()
    if snap:
        print("\n-- metrics snapshot --")
        for line in format_snapshot(snap):
            print(line)
    print(f"\ntrace  -> {trace_path}\nreport -> {report_path}")
    d = report.to_json()
    d["family"] = family
    d["trace_path"] = trace_path
    d["report_path"] = report_path
    # serve-tier counters (prefix-cache hits, chunked-prefill deferrals,
    # radix cache size) ride along so the summary tells the rollout
    # throughput story without opening the trace
    d["serve_metrics"] = {
        name: fields for name, fields in sorted(snap.items())
        if name.split("/")[0] in ("serve", "engine")}
    return d


def check_report(d: dict, *, max_bubble: float,
                 ratio_lo: float, ratio_hi: float) -> list:
    """Anomaly checks for the CI gate.  The dry-run cluster's toy tasks
    are wall-clock noisy, so the wall-ratio band is wide — the gate
    catches broken accounting (ratio off by orders of magnitude, bubble
    fraction near 1.0), not modest drift."""
    problems = []
    r = d["wall_ratio"]
    if not (ratio_lo <= r <= ratio_hi):
        problems.append(
            f"{d['family']}: wall ratio {r:.3f} outside "
            f"[{ratio_lo}, {ratio_hi}]")
    b = d["bubble_fraction"]
    if b > max_bubble:
        problems.append(
            f"{d['family']}: bubble fraction {b:.3f} > {max_bubble}")
    if d["measured_wall_s"] <= 0:
        problems.append(f"{d['family']}: no measured wall (empty trace?)")
    if not d["drift"]:
        problems.append(f"{d['family']}: empty drift table")
    return problems


# ---------------------------------------------------------------------------
def measure_overhead(repeat: int = 5) -> dict:
    """Tracing tax on a toy executor workload: the same Pipelined
    schedule run with tracing off and on; returns min-of-N walls and the
    ratio.  Sleep-dominated tasks so the measurement reflects
    per-invocation instrumentation cost, not task jitter."""
    import numpy as np

    from repro.core.pipeline import ExecutionFlowManager
    from repro.core.scheduler import Leaf, Pipelined
    from repro.obs import tracing

    class W:
        devices = (0,)
        offloaded = False

    def task(w, chunk):
        time.sleep(0.001)
        return chunk

    workers = {"a": W(), "b": W()}
    fns = {"a": task, "b": task}
    sched = Pipelined(Leaf("a", 1, 4), Leaf("b", 1, 4), granularity=4,
                      n_s=1, n_t=1)
    batch = {"x": np.zeros((32, 4), np.float32)}

    def run_once():
        mgr = ExecutionFlowManager(workers, fns)
        t0 = time.perf_counter()
        mgr.run(sched, batch)
        return time.perf_counter() - t0

    run_once()  # warm both paths (thread spawn, allocator)
    off = min(run_once() for _ in range(repeat))
    with tracing():
        run_once()
        on = min(run_once() for _ in range(repeat))
    return {"off_s": off, "on_s": on, "overhead": on / off - 1.0}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--family", action="append", default=None,
                    choices=FAMILIES + ("all",),
                    help="workflow family to trace (repeatable; "
                         "default: all)")
    ap.add_argument("--out", default="FLOWTRACE", metavar="PREFIX",
                    help="artifact path prefix (default: FLOWTRACE)")
    ap.add_argument("--iterations", type=int, default=2,
                    help="training iterations to run traced (default: 2)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on report anomalies (CI gate)")
    ap.add_argument("--max-bubble", type=float, default=0.95,
                    help="anomaly bound on device-weighted bubble "
                         "fraction (default: 0.95)")
    ap.add_argument("--ratio-band", type=float, nargs=2,
                    default=(0.1, 10.0), metavar=("LO", "HI"),
                    help="anomaly band for measured/predicted wall "
                         "ratio (default: 0.1 10)")
    ap.add_argument("--overhead", action="store_true",
                    help="also measure the tracing tax on a toy "
                         "executor workload")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print the execution plan per family")
    args = ap.parse_args(argv)

    fams = args.family or ["all"]
    if "all" in fams:
        fams = list(FAMILIES)

    t0 = time.perf_counter()
    reports = []
    for fam in fams:
        reports.append(trace_family(fam, args.iterations, args.out,
                                    args.verbose))

    problems = []
    if args.check:
        lo, hi = args.ratio_band
        for d in reports:
            problems.extend(check_report(d, max_bubble=args.max_bubble,
                                         ratio_lo=lo, ratio_hi=hi))

    if args.overhead:
        oh = measure_overhead()
        print(f"\ntracing overhead (toy pipeline, min of 5): "
              f"off {oh['off_s'] * 1e3:.2f}ms  on {oh['on_s'] * 1e3:.2f}ms  "
              f"(+{100 * oh['overhead']:.1f}%)")

    summary_path = f"{args.out}.summary.json"
    with open(summary_path, "w") as f:
        json.dump({"families": reports, "problems": problems}, f,
                  indent=2, sort_keys=True)
        f.write("\n")
    dt = time.perf_counter() - t0
    print(f"\nflowtrace: {len(reports)} family(ies) in {dt:.1f}s "
          f"-> {summary_path}")
    if problems:
        for p in problems:
            print(f"ANOMALY: {p}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
