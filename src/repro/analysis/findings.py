"""Finding model shared by every flowlint pass.

A finding is one defect (or suspicion) located in a transformation
artifact — a workflow graph, an execution plan, a channel topology, or a
kernel invocation.  Findings carry a stable code (``P…`` plan, ``C…``
concurrency, ``K…`` kernel, ``R…`` RNG), a severity, and a fix hint, so
the CLI/CI gate and the executor's strict mode can filter uniformly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, List, Optional, Sequence

SEVERITIES = ("info", "warning", "error")


def severity_rank(severity: str) -> int:
    try:
        return SEVERITIES.index(severity)
    except ValueError:
        raise ValueError(
            f"unknown severity {severity!r}; expected one of {SEVERITIES}")


@dataclass(frozen=True)
class Finding:
    code: str        # stable defect-class id, e.g. "P203"
    severity: str    # "info" | "warning" | "error"
    subject: str     # node / channel / lock / kernel the finding is about
    message: str     # what is wrong
    hint: str = ""   # how to fix it
    pass_name: str = ""  # "plan" | "concurrency" | "kernel" | "rng"

    def __post_init__(self):
        severity_rank(self.severity)  # validate eagerly

    def format(self) -> str:
        loc = f" [{self.subject}]" if self.subject else ""
        out = f"{self.severity.upper():7s} {self.code}{loc}: {self.message}"
        if self.hint:
            out += f"\n        hint: {self.hint}"
        return out


def filter_findings(findings: Iterable[Finding],
                    min_severity: str = "info") -> List[Finding]:
    floor = severity_rank(min_severity)
    return [f for f in findings if severity_rank(f.severity) >= floor]


def max_severity(findings: Sequence[Finding]) -> Optional[str]:
    if not findings:
        return None
    return max(findings, key=lambda f: severity_rank(f.severity)).severity


def format_findings(findings: Sequence[Finding],
                    header: str = "") -> str:
    lines = []
    if header:
        lines.append(header)
    if not findings:
        lines.append("clean: no findings")
    for f in findings:
        lines.append(f.format())
    return "\n".join(lines)


class FlowLintError(RuntimeError):
    """Raised by strict mode when a plan fails static analysis — the run
    is rejected BEFORE any worker executes or any device is rebound."""

    def __init__(self, findings: Sequence[Finding],
                 context: str = "execution plan rejected"):
        self.findings = list(findings)
        super().__init__(
            format_findings(self.findings,
                            header=f"flowlint: {context} "
                                   f"({len(self.findings)} finding(s))"))
