"""flowlint Pass 2 — concurrency analysis over the channel topology.

The executor realizes a plan as threads blocked on channels and device
locks: Pipelined sides hand chunks over a per-run Channel, hybrid cycle
leaves double-buffer env chunks through a ring of channels, Async plans
gate a producer on an AsyncQueue's staleness bound, and workers
time-sharing devices serialize through DeviceLock priority ranks.  Each
of those is a place a configuration bug becomes a deadlock that only
manifests at fleet scale.

This pass builds a declarative :class:`ChannelTopology` from the plan
(:func:`build_topology` mirrors the wiring in ``core.pipeline``), then
checks it without running anything:

  * rings nobody primes (every member blocks on its first ``get``);
  * bounded-capacity cycles that cannot hold the in-flight items;
  * AsyncQueue configurations that can never admit a put;
  * DeviceLock priority ranks contradicting the data-dependency order;
  * lock-order inversions across workers acquiring multiple locks;
  * blocking ``get``s that a WorkerFailure cannot interrupt.

:class:`LockOrderRecorder` is the runtime half: armed (in tests) via
``repro.core.channel.set_lock_observer``, it records every DeviceLock
wait/grant and validates the static model against what actually
interleaved.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

import networkx as nx

from repro.analysis.findings import Finding
from repro.core.flowgraph import FlowGraph
from repro.core.scheduler import Async, Leaf, Pipelined, Temporal, leaves

PASS = "concurrency"


def _f(code: str, severity: str, subject: str, message: str,
       hint: str = "") -> Finding:
    return Finding(code, severity, subject, message, hint, PASS)


# ---------------------------------------------------------------------------
# Channel-topology IR
# ---------------------------------------------------------------------------
@dataclass
class ChannelDecl:
    """One channel as the analyzer sees it.  ``capacity`` follows
    ``core.channel.Channel`` semantics: 0 = unbounded.  ``primed`` is the
    number of items seeded before the consumer loop starts (the hybrid
    ring's chunk seeding).  ``closed_on_failure`` records whether every
    producer's failure path closes the channel (the property that makes a
    timeout-less ``get`` interruptible)."""
    name: str
    kind: str = "fifo"  # "fifo" | "async"
    capacity: int = 0
    primed: int = 0
    closed_on_failure: bool = True
    # async-queue fields (kind == "async")
    staleness_bound: int = 0
    # producer of item i waits until the consumer published version
    # >= i - gate_offset (AsyncPipelineDriver's staleness gate)
    gate_offset: int = 0
    stale_policy: str = "strict"


@dataclass
class PortDecl:
    """A worker endpoint on a channel.  ``timeout=None`` blocks forever."""
    worker: str
    channel: str
    kind: str  # "put" | "get"
    timeout: Optional[float] = None


@dataclass
class LockSite:
    """The ordered DeviceLock acquisitions of one worker."""
    worker: str
    locks: Tuple[str, ...]


@dataclass
class ChannelTopology:
    channels: Dict[str, ChannelDecl] = field(default_factory=dict)
    ports: List[PortDecl] = field(default_factory=list)
    # DeviceLock priority ranks (data-dependency order: producers lower)
    ranks: Dict[str, int] = field(default_factory=dict)
    lock_sites: List[LockSite] = field(default_factory=list)
    # worker -> device set, for "who shares devices" queries
    devices: Dict[str, Set[int]] = field(default_factory=dict)
    # channel edges (producer -> consumer) for rank checks
    edges: List[Tuple[str, str]] = field(default_factory=list)

    def add_channel(self, decl: ChannelDecl) -> ChannelDecl:
        self.channels[decl.name] = decl
        return decl

    def put(self, worker: str, channel: str,
            timeout: Optional[float] = None) -> None:
        self.ports.append(PortDecl(worker, channel, "put", timeout))

    def get(self, worker: str, channel: str,
            timeout: Optional[float] = None) -> None:
        self.ports.append(PortDecl(worker, channel, "get", timeout))


# ---------------------------------------------------------------------------
# Builder: plan -> topology (mirrors core.pipeline's wiring)
# ---------------------------------------------------------------------------
def build_topology(graph: Optional[FlowGraph], plan: Any,
                   cycle_specs: Optional[Dict[str, Any]] = None
                   ) -> ChannelTopology:
    topo = ChannelTopology()
    members: Dict[str, Tuple[str, ...]] = dict(
        getattr(plan, "members", None) or {})
    placement: Dict[str, List[int]] = dict(plan.placement or {})
    for w, devs in placement.items():
        topo.devices[w] = set(devs)
    specs = cycle_specs or {}

    # DeviceLock priority ranks follow the (condensed) graph's
    # topological order — producers acquire before consumers; cycle
    # members share their collapsed node's rank.
    if graph is not None:
        dag, g_members = graph.condense()
        for i, node in enumerate(nx.topological_sort(dag.g)):
            for w in g_members.get(node, (node,)):
                topo.ranks[w] = i
        for a, b in graph.edges():
            topo.edges.append((a, b))

    counter = itertools.count()

    def expand(name: str) -> Tuple[str, ...]:
        return members.get(name, (name,))

    def side_workers(node) -> List[str]:
        out: List[str] = []
        for lf in leaves(node):
            out.extend(expand(lf.worker))
        return out

    def walk(node):
        if isinstance(node, Leaf):
            ms = members.get(node.worker, ())
            spec = specs.get(node.worker)
            if (node.cycle_mode == "hybrid" and len(ms) >= 2
                    and spec is not None):
                # hybrid double-buffer ring (pipeline._run_cycle_hybrid):
                # one channel per member, member j gets from ring[j] and
                # puts to ring[(j+1) % k]; the executor primes ring[0]
                # with one carry per env chunk before the loop starts,
                # and close_all() on any member failure unblocks getters.
                order = tuple(spec.order)
                k = len(order)
                chunks = max(getattr(node, "cycle_chunks", None)
                             or getattr(spec, "chunks", 2), 1)
                rings = [topo.add_channel(ChannelDecl(
                    f"ring:{node.worker}:{j}", capacity=0,
                    primed=chunks if j == 0 else 0,
                    closed_on_failure=True)) for j in range(k)]
                for j, m in enumerate(order):
                    topo.get(m, rings[j].name)
                    topo.put(m, rings[(j + 1) % k].name)
            return
        if isinstance(node, Temporal):
            # both sides time-share devices: one DeviceLock, acquired in
            # rank order — no channel between them (direct hand-off)
            lock = f"devlock:{next(counter)}"
            for w in side_workers(node.s) + side_workers(node.t):
                topo.lock_sites.append(LockSite(w, (lock,)))
        elif isinstance(node, Pipelined):
            # per-run hand-off channel (pipeline Pipelined branch):
            # producer thread closes it in `finally`, so the consumer's
            # timeout-less get is interruptible
            ch = topo.add_channel(ChannelDecl(
                f"pipe:{next(counter)}", capacity=0,
                closed_on_failure=True))
            for w in side_workers(node.s):
                topo.put(w, ch.name)
            for w in side_workers(node.t):
                topo.get(w, ch.name)
        elif isinstance(node, Async):
            depth = max(int(node.depth), 0)
            ch = topo.add_channel(ChannelDecl(
                f"async:{next(counter)}", kind="async",
                capacity=max(depth, 1), staleness_bound=depth,
                gate_offset=depth, closed_on_failure=True))
            for w in side_workers(node.s):
                topo.put(w, ch.name)
            for w in side_workers(node.t):
                topo.get(w, ch.name)
        walk(node.s)
        walk(node.t)

    walk(plan.schedule)
    return topo


# ---------------------------------------------------------------------------
# Checks
# ---------------------------------------------------------------------------
def check_topology(topo: ChannelTopology) -> List[Finding]:
    out: List[Finding] = []
    out.extend(_check_channel_cycles(topo))
    out.extend(_check_async_queues(topo))
    out.extend(_check_orphan_channels(topo))
    out.extend(_check_rank_order(topo))
    out.extend(_check_lock_order(topo))
    out.extend(_check_uninterruptible_gets(topo))
    return out


def _channel_graph(topo: ChannelTopology) -> nx.DiGraph:
    """Bipartite digraph channel -> consumer -> produced channel."""
    g = nx.DiGraph()
    for name in topo.channels:
        g.add_node(("ch", name))
    for p in topo.ports:
        if p.channel not in topo.channels:
            continue
        g.add_node(("w", p.worker))
        if p.kind == "get":
            g.add_edge(("ch", p.channel), ("w", p.worker))
        else:
            g.add_edge(("w", p.worker), ("ch", p.channel))
    return g


def _check_channel_cycles(topo: ChannelTopology) -> List[Finding]:
    out: List[Finding] = []
    g = _channel_graph(topo)
    for comp in nx.strongly_connected_components(g):
        chans = [topo.channels[n[1]] for n in comp if n[0] == "ch"]
        workers = sorted(n[1] for n in comp if n[0] == "w")
        if not chans or not workers:
            continue
        label = "+".join(c.name for c in chans)
        primed = sum(c.primed for c in chans)
        # C101 — a ring nobody primes: every member's first action is a
        # blocking get on an empty channel; the loop never starts.
        if primed == 0:
            out.append(_f(
                "C101", "error", label,
                f"channel cycle through {workers} has no primed items — "
                f"every member blocks on its first get (startup "
                f"deadlock)",
                "seed the ring before starting the member loops (the "
                "hybrid executor primes ring[0] with one carry per env "
                "chunk)"))
            continue
        # C102 — bounded ring that cannot hold the in-flight items: once
        # the buffers and the members' hands are full, every put blocks
        # while every get upstream is starved.
        if all(c.capacity > 0 for c in chans):
            slots = sum(c.capacity for c in chans) + len(workers)
            if slots < primed:
                out.append(_f(
                    "C102", "error", label,
                    f"bounded cycle holds at most {slots} item(s) "
                    f"(capacities + one in hand per member) but "
                    f"{primed} are primed — the double-buffer ring "
                    f"deadlocks on put",
                    "make at least one ring channel unbounded "
                    "(capacity=0) or prime no more items than the "
                    "cycle can hold"))
    return out


def _check_async_queues(topo: ChannelTopology) -> List[Finding]:
    out: List[Finding] = []
    for c in topo.channels.values():
        if c.kind != "async":
            continue
        # C103 — configurations under which no put is ever admitted: the
        # producer livelocks before the first item reaches the trainer.
        if c.staleness_bound < 0:
            out.append(_f(
                "C103", "error", c.name,
                f"negative staleness bound {c.staleness_bound}",
                "K must be >= 0 (0 = fully synchronous)"))
        if c.capacity < 1:
            out.append(_f(
                "C103", "error", c.name,
                f"async queue capacity {c.capacity} < 1 — even the "
                f"K=0 hand-off needs one slot, so no put is ever "
                f"admitted",
                "capacity must be max(K, 1) (what AsyncQueue.put "
                "enforces)"))
        if c.gate_offset < 0:
            out.append(_f(
                "C103", "error", c.name,
                f"staleness gate offset {c.gate_offset} < 0: the "
                f"producer's first put waits for consumer version "
                f"{-c.gate_offset}, which the consumer can only reach "
                f"by consuming items that were never produced "
                f"(producer livelock)",
                "gate item i on wait_for_version(i - K) with K >= 0"))
        elif c.gate_offset > c.staleness_bound:
            out.append(_f(
                "C104", "warning", c.name,
                f"gate offset {c.gate_offset} exceeds the staleness "
                f"bound {c.staleness_bound}: the gate admits samples "
                f"the strict get then rejects (StalenessExceeded at "
                f"steady state)",
                "keep the producer gate at the queue's own bound K"))
    return out


def _check_orphan_channels(topo: ChannelTopology) -> List[Finding]:
    out: List[Finding] = []
    for c in topo.channels.values():
        getters = [p.worker for p in topo.ports
                   if p.channel == c.name and p.kind == "get"]
        putters = [p.worker for p in topo.ports
                   if p.channel == c.name and p.kind == "put"]
        if getters and not putters and c.primed == 0:
            # C105 — a getter on a channel nothing ever feeds: exactly
            # the orphaned-channel hang Channel.reset_all now closes.
            out.append(_f(
                "C105", "error", c.name,
                f"channel has consumer(s) {sorted(set(getters))} but no "
                f"producer and no primed items — gets block forever",
                "wire a producer or drop the consumer port"))
    return out


def _check_rank_order(topo: ChannelTopology) -> List[Finding]:
    out: List[Finding] = []
    if not topo.ranks:
        return out
    for src, dst in topo.edges:
        rs, rd = topo.ranks.get(src), topo.ranks.get(dst)
        if rs is None or rd is None or src == dst:
            continue
        shared = topo.devices.get(src, set()) & topo.devices.get(dst, set())
        if rs > rd and shared:
            # C106 — priority inversion on a shared-device edge: the
            # DeviceLock grants by rank, so the consumer would grab the
            # devices before its producer released them — the deadlock
            # the data-dependency ordering exists to rule out.
            out.append(_f(
                "C106", "error", f"{src}->{dst}",
                f"DeviceLock rank of producer {src!r} ({rs}) is higher "
                f"than consumer {dst!r} ({rd}) although they share "
                f"device(s) {sorted(shared)}",
                "derive lock priorities from the workflow graph's "
                "topological order (producers acquire first)"))
    return out


def _check_lock_order(topo: ChannelTopology) -> List[Finding]:
    """C107 — classic lock-order inversion: the union of every worker's
    acquisition sequence must be acyclic, or two workers holding one
    lock each can wait on the other's forever."""
    out: List[Finding] = []
    g = nx.DiGraph()
    holders: Dict[Tuple[str, str], List[str]] = {}
    for site in topo.lock_sites:
        for a, b in zip(site.locks, site.locks[1:]):
            g.add_edge(a, b)
            holders.setdefault((a, b), []).append(site.worker)
    try:
        cyc = nx.find_cycle(g)
    except nx.NetworkXNoCycle:
        return out
    locks = [a for a, _ in cyc]
    ws: Set[str] = set()
    for a, b in cyc:
        ws.update(holders.get((a, b), ()))
    out.append(_f(
        "C107", "error", "->".join(locks + [locks[0]]),
        f"lock-order inversion: workers {sorted(ws)} acquire "
        f"{sorted(set(locks))} in conflicting orders",
        "impose one global acquisition order (e.g. the schedule's "
        "stage order) on every worker touching multiple device locks"))
    return out


def _check_uninterruptible_gets(topo: ChannelTopology) -> List[Finding]:
    out: List[Finding] = []
    for p in topo.ports:
        if p.kind != "get" or p.timeout is not None:
            continue
        c = topo.channels.get(p.channel)
        if c is not None and not c.closed_on_failure:
            # C108 — a blocking get that WorkerFailure recovery cannot
            # interrupt: the producer's failure path never closes the
            # channel, so recovery's teardown joins a thread that is
            # parked forever.
            out.append(_f(
                "C108", "warning", f"{p.worker}@{p.channel}",
                f"timeout-less get on {p.channel!r}, whose producers do "
                f"not close it on failure — WorkerFailure recovery "
                f"cannot interrupt this thread",
                "close the channel in the producer's failure path "
                "(finally:) or give the get a timeout"))
    return out


# ---------------------------------------------------------------------------
# Runtime hygiene: LockOrderRecorder (armed in tests)
# ---------------------------------------------------------------------------
class LockOrderRecorder:
    """Records DeviceLock wait/grant events and validates Pass 2's model
    against the real interleaving.

    Arm it through :func:`repro.core.channel.set_lock_observer`; every
    ``DeviceLock.acquire`` then reports when a worker starts waiting and
    when it is granted the lock.  :meth:`violations` replays the event
    log: a grant to worker ``w`` while a strictly lower-rank worker is
    still waiting on the same lock contradicts the data-dependency
    acquisition priority (exactly what Pass 2's C106 predicts
    statically)."""

    def __init__(self):
        self.events: List[Tuple[str, str, str, int]] = []
        import threading
        self._lock = threading.Lock()

    # -- observer interface (called by DeviceLock) -------------------------
    def record(self, kind: str, lock: str, worker: str,
               rank: int = 0) -> None:
        with self._lock:
            self.events.append((kind, lock, worker, rank))

    def clear(self) -> None:
        with self._lock:
            self.events = []

    # -- analysis ----------------------------------------------------------
    def grants(self, lock: Optional[str] = None) -> List[str]:
        """Workers in grant order (optionally for one lock)."""
        return [w for k, l, w, _ in self.events
                if k == "grant" and (lock is None or l == lock)]

    def violations(self, ranks: Optional[Dict[str, int]] = None
                   ) -> List[str]:
        """Grant events contradicting the priority model.  ``ranks``
        overrides the recorded ranks (pass the static model's ranks to
        validate the configuration against the graph order)."""
        out: List[str] = []
        waiting: Dict[str, Dict[str, int]] = {}
        for kind, lock, worker, rank in self.events:
            r = ranks.get(worker, rank) if ranks is not None else rank
            lw = waiting.setdefault(lock, {})
            if kind == "wait":
                lw[worker] = r
            elif kind == "leave":  # timed-out waiter withdrew
                lw.pop(worker, None)
            elif kind == "grant":
                lw.pop(worker, None)
                lower = [(w2, r2) for w2, r2 in lw.items() if r2 < r]
                if lower:
                    out.append(
                        f"{lock}: granted to {worker!r} (rank {r}) while "
                        f"lower-rank {sorted(lower)} still waiting")
        return out
