"""flowlint Pass 3 — Pallas kernel and RNG-determinism lint.

The kernel wrappers in ``kernels/ops.py`` enforce their invariants with
runtime asserts — which on the 500k-token config means discovering a bad
``block_q`` half an hour into a run.  This pass re-derives each wrapper's
shape math as a declarative :class:`KernelInvocation` (grid, operand
shapes, BlockSpec block shapes and index maps, declared divisibility
constraints) and evaluates it at the config-zoo shapes
(``configs/shapes.py``) in microseconds:

  * K101 — degenerate grid (a dimension of zero or negative extent);
  * K102 — a declared divisibility constraint fails (the runtime assert);
  * K103 — a block shape exceeding its operand dimension;
  * K104 — an index map addressing out of bounds at some grid corner
    (page tables modeled at their worst-case entry);
  * K105 — a page table too short to cover the declared context length;
  * K106 — GQA head counts that do not divide (``H % KV != 0``);
  * K107 — a public kernel entry in ``ops.py`` with no lint spec at all.

The RNG half checks the determinism contract PR 5's closed loop relies
on: per-(round, step, env) ``fold_in`` keying must be injective over its
coordinate domain.  Nested fold chains are injective by construction;
any *combined* keying (e.g. folding ``step + env_id``) is enumerated
over the bounded domain and collisions are reported as R101.
"""
from __future__ import annotations

import inspect
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.analysis.findings import Finding
from repro.configs.shapes import SHAPES

PASS = "kernel"


def _f(code: str, severity: str, subject: str, message: str,
       hint: str = "", pass_name: str = PASS) -> Finding:
    return Finding(code, severity, subject, message, hint, pass_name)


# ---------------------------------------------------------------------------
# Kernel invocation IR
# ---------------------------------------------------------------------------
@dataclass
class BlockMap:
    """One operand's BlockSpec as the analyzer sees it."""
    name: str
    operand_shape: Tuple[int, ...]
    block_shape: Tuple[int, ...]
    # grid ids -> block indices (the BlockSpec index_map, re-expressed)
    index_map: Callable[..., Tuple[int, ...]]


@dataclass
class Divisibility:
    """A declared constraint the wrapper asserts at runtime."""
    label: str
    value: int
    divisor: int
    code: str = "K102"  # K106 for the GQA head-count constraint


@dataclass
class KernelInvocation:
    kernel: str      # entry name in kernels/ops.py
    shape_name: str  # config-zoo shape this was evaluated at
    grid: Tuple[int, ...]
    operands: List[BlockMap] = field(default_factory=list)
    constraints: List[Divisibility] = field(default_factory=list)
    # (label, covered, needed): covered < needed -> K105
    coverage: Optional[Tuple[str, int, int]] = None

    @property
    def subject(self) -> str:
        return f"{self.kernel}@{self.shape_name}"


# ---------------------------------------------------------------------------
# Spec builders — each mirrors the shape math of one ops.py wrapper
# ---------------------------------------------------------------------------
def flash_invocation(shape_name: str, *, B: int, H: int, S: int, D: int,
                     KV: int, block_q: int = 128, block_k: int = 128,
                     clamp: bool = True) -> KernelInvocation:
    """Mirrors ``flash_attention_bhsd``: blocks clamp to ``min(block, S)``
    then S must divide by both; K/V are addressed at ``h // (H // KV)``."""
    if clamp:
        block_q, block_k = min(block_q, S), min(block_k, S)
    group = max(H // KV, 1) if KV > 0 else 1
    nq = max(S // block_q, 1) if block_q > 0 else 0
    nk = max(S // block_k, 1) if block_k > 0 else 0
    return KernelInvocation(
        kernel="flash_attention", shape_name=shape_name,
        grid=(B, H, nq, nk),
        operands=[
            BlockMap("q", (B, H, S, D), (1, 1, block_q, D),
                     lambda b, h, qi, ki: (b, h, qi, 0)),
            BlockMap("k", (B, KV, S, D), (1, 1, block_k, D),
                     lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            BlockMap("v", (B, KV, S, D), (1, 1, block_k, D),
                     lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            BlockMap("o", (B, H, S, D), (1, 1, block_q, D),
                     lambda b, h, qi, ki: (b, h, qi, 0)),
        ],
        constraints=[
            Divisibility("H % num_kv_heads", H, KV, code="K106"),
            Divisibility("S % block_q", S, block_q),
            Divisibility("S % block_k", S, block_k),
        ])


def paged_invocation(shape_name: str, *, B: int, H: int, D: int, P: int,
                     page: int, KV: int, nb: int, max_context: int,
                     table_max: Optional[int] = None) -> KernelInvocation:
    """Mirrors ``paged_attention_bhd``.  ``table_max`` models the largest
    page id a block table can hold (defaults to the pool's last page,
    P - 1 — the allocator's worst case)."""
    G = max(H // KV, 1) if KV > 0 else 1
    tmax = (P - 1) if table_max is None else table_max
    return KernelInvocation(
        kernel="paged_attention", shape_name=shape_name,
        grid=(B, KV, nb),
        operands=[
            BlockMap("q", (B, KV, G, D), (1, 1, G, D),
                     lambda b, kv, j: (b, kv, 0, 0)),
            BlockMap("k_pages", (P, page, KV, D), (1, page, 1, D),
                     lambda b, kv, j, t=tmax: (t, 0, kv, 0)),
            BlockMap("v_pages", (P, page, KV, D), (1, page, 1, D),
                     lambda b, kv, j, t=tmax: (t, 0, kv, 0)),
            BlockMap("o", (B, KV, G, D), (1, 1, G, D),
                     lambda b, kv, j: (b, kv, 0, 0)),
        ],
        constraints=[
            Divisibility("H % num_kv_heads", H, KV, code="K106"),
        ],
        coverage=("block_table pages * page_size vs max context",
                  nb * page, max_context))


def ssd_invocation(shape_name: str, *, B: int, L: int, H: int, P: int,
                   N: int, chunk: int) -> KernelInvocation:
    """Mirrors ``ssd_scan`` -> ``ssd_scan_bhcsp``: L splits into
    L // chunk chunks carried sequentially."""
    nc = max(L // chunk, 1) if chunk > 0 else 0
    return KernelInvocation(
        kernel="ssd_scan", shape_name=shape_name,
        grid=(B, H, nc),
        operands=[
            BlockMap("x", (B, H, nc, chunk, P), (1, 1, 1, chunk, P),
                     lambda b, h, ci: (b, h, ci, 0, 0)),
            BlockMap("dt", (B, H, nc, chunk), (1, 1, 1, chunk),
                     lambda b, h, ci: (b, h, ci, 0)),
            BlockMap("Bm", (B, nc, chunk, N), (1, 1, chunk, N),
                     lambda b, h, ci: (b, ci, 0, 0)),
            BlockMap("Cm", (B, nc, chunk, N), (1, 1, chunk, N),
                     lambda b, h, ci: (b, ci, 0, 0)),
            BlockMap("y", (B, H, nc, chunk, P), (1, 1, 1, chunk, P),
                     lambda b, h, ci: (b, h, ci, 0, 0)),
        ],
        constraints=[Divisibility("L % chunk", L, chunk)])


def gmm_invocation(shape_name: str, *, E: int, C: int, D: int, F: int,
                   block_c: int = 128, block_d: int = 512,
                   block_f: int = 128, clamp: bool = True
                   ) -> KernelInvocation:
    """Mirrors ``grouped_matmul``: per-expert (C, D) @ (D, F) tiles."""
    if clamp:
        block_c, block_d = min(block_c, C), min(block_d, D)
        block_f = min(block_f, F)
    nc = max(C // block_c, 1) if block_c > 0 else 0
    nd = max(D // block_d, 1) if block_d > 0 else 0
    nf = max(F // block_f, 1) if block_f > 0 else 0
    return KernelInvocation(
        kernel="grouped_matmul", shape_name=shape_name,
        grid=(E, nc, nf, nd),
        operands=[
            BlockMap("buf", (E, C, D), (1, block_c, block_d),
                     lambda e, ci, fi, di: (e, ci, di)),
            BlockMap("w", (E, D, F), (1, block_d, block_f),
                     lambda e, ci, fi, di: (e, di, fi)),
            BlockMap("out", (E, C, F), (1, block_c, block_f),
                     lambda e, ci, fi, di: (e, ci, fi)),
        ],
        constraints=[
            Divisibility("C % block_c", C, block_c),
            Divisibility("D % block_d", D, block_d),
            Divisibility("F % block_f", F, block_f),
        ])


def ssm_update_invocation(shape_name: str, *, B: int, H: int, P: int,
                          N: int) -> KernelInvocation:
    """Mirrors ``ssm_state_update`` -> ``ssm_state_update_bh``: grid
    (B, H), one full (P, N) state tile per program (the state cache's
    constant-size decode step — no blocking, no divisibility)."""
    return KernelInvocation(
        kernel="ssm_state_update", shape_name=shape_name,
        grid=(B, H),
        operands=[
            BlockMap("state", (B, H, P, N), (1, 1, P, N),
                     lambda b, h: (b, h, 0, 0)),
            BlockMap("x", (B, H, P), (1, 1, P),
                     lambda b, h: (b, h, 0)),
            BlockMap("dt", (B, H), (1, 1), lambda b, h: (b, h)),
            BlockMap("A", (B, H), (1, 1), lambda b, h: (b, h)),
            BlockMap("Bm", (B, N), (1, N), lambda b, h: (b, 0)),
            BlockMap("Cm", (B, N), (1, N), lambda b, h: (b, 0)),
            BlockMap("D", (B, H), (1, 1), lambda b, h: (b, h)),
            BlockMap("y", (B, H, P), (1, 1, P),
                     lambda b, h: (b, h, 0)),
            BlockMap("new_state", (B, H, P, N), (1, 1, P, N),
                     lambda b, h: (b, h, 0, 0)),
        ])


def _decode_capacity(num_tokens: int) -> int:
    """Keep in sync with ``kernels.moe_gmm.decode_capacity``: top-k
    indices are distinct per token, so one expert receives at most T
    assignments; pad to a 128 multiple above 128 for MXU tiling."""
    if num_tokens <= 128:
        return max(num_tokens, 1)
    return -(-num_tokens // 128) * 128


def moe_decode_invocation(shape_name: str, *, T: int, E: int, d: int,
                          f: int) -> List[KernelInvocation]:
    """Mirrors ``moe_decode`` -> ``moe_decode_gmm``: tokens gather into
    an (E, C, d) buffer with C = decode_capacity(T), then grouped GEMMs
    — gate/up at (E, C, d) @ (E, d, f) and down at (E, C, f) @ (E, f, d)
    — each with ``grouped_matmul``'s clamped tile sizes."""
    up = gmm_invocation(shape_name, E=E, C=_decode_capacity(T), D=d, F=f)
    down = gmm_invocation(shape_name, E=E, C=_decode_capacity(T), D=f, F=d)
    for inv in (up, down):
        inv.kernel = "moe_decode"
    return [up, down]


def sampling_invocation(shape_name: str, *, B: int, V: int
                        ) -> KernelInvocation:
    """Mirrors ``fused_sample`` -> ``fused_sample_bv``: grid (B,), one
    (1, V) logits/gumbel row per program, (1, 1) token/logprob outs."""
    return KernelInvocation(
        kernel="fused_sample", shape_name=shape_name,
        grid=(B,),
        operands=[
            BlockMap("logits", (B, V), (1, V), lambda b: (b, 0)),
            BlockMap("gumbel", (B, V), (1, V), lambda b: (b, 0)),
            BlockMap("token", (B, 1), (1, 1), lambda b: (b, 0)),
            BlockMap("lp", (B, 1), (1, 1), lambda b: (b, 0)),
        ])


# ---------------------------------------------------------------------------
# Checks
# ---------------------------------------------------------------------------
def check_invocation(inv: KernelInvocation) -> List[Finding]:
    out: List[Finding] = []
    subject = inv.subject

    for i, n in enumerate(inv.grid):
        if n <= 0:
            out.append(_f(
                "K101", "error", subject,
                f"grid dimension {i} has extent {n}",
                "every grid axis needs at least one program instance"))
    for c in inv.constraints:
        if c.divisor <= 0 or c.value % c.divisor:
            if c.code == "K106":
                msg = (f"GQA requires {c.label} == 0, got "
                       f"{c.value} % {c.divisor}")
                hint = ("query heads must be an integer multiple of KV "
                        "heads — the K/V index map computes h // (H//KV)")
            else:
                msg = (f"{c.label} != 0 ({c.value} % {c.divisor}) — the "
                       f"wrapper's runtime assert would fire")
                hint = ("pick a block/chunk size dividing the operand "
                        "dimension at this config-zoo shape")
            out.append(_f(c.code, "error", subject, msg, hint))
    for op in inv.operands:
        for d, (blk, dim) in enumerate(zip(op.block_shape,
                                           op.operand_shape)):
            if blk > dim:
                out.append(_f(
                    "K103", "error", f"{subject}:{op.name}",
                    f"block shape {op.block_shape} exceeds operand "
                    f"shape {op.operand_shape} in dim {d} "
                    f"({blk} > {dim})",
                    "clamp the block to min(block, dim) like the "
                    "wrappers do"))
    if not any(f.code in ("K101", "K103") for f in out):
        out.extend(_check_index_maps(inv))
    if inv.coverage is not None:
        label, covered, needed = inv.coverage
        if covered < needed:
            out.append(_f(
                "K105", "error", subject,
                f"{label}: {covered} < {needed} — decode steps past "
                f"position {covered} address past the block table",
                "size the table at ceil(max_seq_len / page_size) pages "
                "(PagedEngine.max_blocks does this)"))
    return out


def _check_index_maps(inv: KernelInvocation) -> List[Finding]:
    """Evaluate each index map at every grid corner and check the block
    it selects stays inside the operand.  Corner evaluation is exact
    here because every index map in the repo is monotone in each grid
    id (affine, floor-div, or a table lookup modeled at its max)."""
    out: List[Finding] = []
    corners = list(itertools.product(*([0, n - 1] if n > 1 else [0]
                                       for n in inv.grid)))
    for op in inv.operands:
        for ids in corners:
            idx = op.index_map(*ids)
            oob = next((
                (d, i * blk, i * blk + blk)
                for d, (i, blk, dim) in enumerate(zip(idx, op.block_shape,
                                                      op.operand_shape))
                if i * blk < 0 or i * blk + blk > dim), None)
            if oob is None:
                continue
            d, lo, hi = oob
            out.append(_f(
                "K104", "error", f"{inv.subject}:{op.name}",
                f"index map at grid point {ids} selects "
                f"[{lo}:{hi}) in dim {d} of operand shape "
                f"{op.operand_shape} (out of bounds)",
                "the index map must keep idx*block + block "
                "within the operand at every grid point"))
            break  # first offending corner per operand is enough
    return out


def default_invocations() -> List[KernelInvocation]:
    """The clean registry: every ops.py kernel at every config-zoo shape
    it serves, with representative 7B-class model dimensions (heads and
    widths match the qwen-family configs; SSD dims match mamba2)."""
    H, KV, D = 28, 4, 128            # dense/GQA attention dims
    ssd_H, ssd_P, ssd_N = 24, 64, 128  # mamba2 heads / head_dim / state
    page = 16                        # PagedEngine default page_size
    vocab = 151_936                  # qwen-family padded vocab width
    out: List[KernelInvocation] = []
    for name, sc in SHAPES.items():
        S, B = sc.seq_len, sc.global_batch
        if sc.phase == "decode":
            nb = -(-S // page)
            out.append(paged_invocation(
                name, B=B, H=H, D=D, P=B * nb + 1, page=page, KV=KV,
                nb=nb, max_context=S))
            # the fused sampler runs back-to-back with paged attention
            # on every decode step, same batch extent
            out.append(sampling_invocation(name, B=B, V=vocab))
            # per-arch decode paths through the state / MoE cache
            # layouts: constant-size SSD state update (mamba2 dims) and
            # the expert-parallel exact MoE FFN (granite-moe dims:
            # 40 experts, d_model 1536, expert d_ff 512, T = B tokens)
            out.append(ssm_update_invocation(
                name, B=B, H=ssd_H, P=ssd_P, N=ssd_N))
            out.extend(moe_decode_invocation(
                name, T=B, E=40, d=1536, f=512))
        else:
            out.append(flash_invocation(
                name, B=min(B, 8), H=H, S=S, D=D, KV=KV))
            out.append(ssd_invocation(
                name, B=min(B, 8), L=S, H=ssd_H, P=ssd_P, N=ssd_N,
                chunk=128))
    # MoE FFN hot-spot at the train shape: 8 experts, top-2, capacity
    # ceil(4096 * 2 / 8 * 1.25) = 1280 dispatched tokens per expert
    out.append(gmm_invocation("train_4k", E=8, C=1280, D=2048, F=5632))
    return out


def check_registry_coverage(
        invocations: Sequence[KernelInvocation]) -> List[Finding]:
    """K107 — every public kernel entry in ``kernels/ops.py`` must have
    at least one lint spec, or new kernels silently escape Pass 3."""
    from repro.kernels import ops as _ops
    covered = {inv.kernel for inv in invocations}
    out: List[Finding] = []
    for name, fn in inspect.getmembers(_ops, inspect.isfunction):
        if name.startswith("_") or fn.__module__ != _ops.__name__:
            continue
        if name not in covered:
            out.append(_f(
                "K107", "warning", name,
                f"kernel entry ops.{name} has no KernelInvocation spec "
                f"— Pass 3 cannot check it",
                "add a spec builder mirroring the wrapper's shape math "
                "to analysis.kernel_checks"))
    return out


def check_kernels(
        invocations: Optional[Sequence[KernelInvocation]] = None
) -> List[Finding]:
    invs = list(default_invocations() if invocations is None
                else invocations)
    out: List[Finding] = []
    for inv in invs:
        out.extend(check_invocation(inv))
    out.extend(check_registry_coverage(invs))
    return out


# ---------------------------------------------------------------------------
# RNG determinism lint
# ---------------------------------------------------------------------------
@dataclass
class RNGKeySpec:
    """One PRNG keying scheme.  ``combine`` is either the string
    ``"nested"`` (a chain of ``fold_in`` calls, one coordinate each —
    injective by construction, the scheme ``RolloutWorker.act`` and the
    paged sampler use) or a callable collapsing the coordinates into a
    single fold value (checked for collisions by enumeration)."""
    name: str
    coords: Tuple[str, ...]
    domain: Dict[str, range]
    combine: Union[str, Callable[..., Any]] = "nested"


def default_rng_specs() -> List[RNGKeySpec]:
    return [
        # workers.RolloutWorker.act: fold_in(fold_in(fold_in(base,
        # rollout_round), cycle_step), env_id)
        RNGKeySpec("rollout_act", ("rollout_round", "cycle_step", "env_id"),
                   {"rollout_round": range(4), "cycle_step": range(64),
                    "env_id": range(64)}),
        # serve.engine: token i of request r from
        # fold_in(PRNGKey(r.seed), position)
        RNGKeySpec("paged_sampler", ("seed", "position"),
                   {"seed": range(16), "position": range(256)}),
    ]


_MAX_ENUM = 1_000_000


def check_rng(specs: Optional[Sequence[RNGKeySpec]] = None
              ) -> List[Finding]:
    out: List[Finding] = []
    for spec in (default_rng_specs() if specs is None else specs):
        subject = spec.name
        missing = [c for c in spec.coords if c not in spec.domain]
        if missing:
            out.append(_f(
                "R101", "warning", subject,
                f"no enumeration domain declared for coordinate(s) "
                f"{missing} — collision check skipped",
                "declare a bounded range per coordinate",
                pass_name="rng"))
            continue
        if spec.combine == "nested":
            # fold_in chains are injective per coordinate: the identity
            # IS the coordinate tuple, which is unique by construction
            continue
        total = 1
        for c in spec.coords:
            total *= max(len(spec.domain[c]), 1)
        if total > _MAX_ENUM:
            out.append(_f(
                "R101", "warning", subject,
                f"domain too large to enumerate ({total} points)",
                "shrink the declared domain to a representative bound",
                pass_name="rng"))
            continue
        seen: Dict[Any, Tuple[int, ...]] = {}
        for point in itertools.product(
                *(spec.domain[c] for c in spec.coords)):
            ident = spec.combine(*point)
            if ident in seen:
                a = dict(zip(spec.coords, seen[ident]))
                b = dict(zip(spec.coords, point))
                out.append(_f(
                    "R101", "error", subject,
                    f"fold_in coordinate collision: {a} and {b} both "
                    f"key to {ident!r} — two logically distinct draws "
                    f"share a PRNG stream, breaking the bit-identical "
                    f"chunking guarantee",
                    "nest the fold_in per coordinate instead of "
                    "combining coordinates arithmetically",
                    pass_name="rng"))
                break
            seen[ident] = point
    return out
