"""flowlint — static analysis for M2Flow transformation artifacts.

The M2Flow premise moves correctness out of worker code and into the
transformation artifacts: the workflow graph, the execution plan, the
channel topology the plan implies, and the kernel invocations the
workers will issue.  flowlint checks those artifacts *before* anything
runs:

  * Pass 1 (``plan_checks``)  — graph/plan invariants (P1xx/P2xx);
  * Pass 2 (``concurrency``)  — deadlock/livelock analysis over the
    channel topology (C1xx);
  * Pass 3 (``kernel_checks``) — Pallas kernel shape/index-map lint at
    the config-zoo shapes plus RNG-determinism (K1xx/R1xx).

Entry points: :func:`analyze` (library), ``tools/flowlint.py`` (CLI/CI),
``Controller(strict=True)`` (reject bad plans before execution), and
:class:`LockOrderRecorder` (runtime validation of Pass 2's model).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.analysis.concurrency import (
    ChannelDecl,
    ChannelTopology,
    LockOrderRecorder,
    PortDecl,
    build_topology,
    check_topology,
)
from repro.analysis.findings import (
    Finding,
    FlowLintError,
    SEVERITIES,
    filter_findings,
    format_findings,
    max_severity,
    severity_rank,
)
from repro.analysis.kernel_checks import (
    KernelInvocation,
    RNGKeySpec,
    check_invocation,
    check_kernels,
    check_rng,
)
from repro.analysis.plan_checks import (
    check_cost_models,
    check_graph,
    check_plan,
)

__all__ = [
    "ChannelDecl", "ChannelTopology", "Finding", "FlowLintError",
    "KernelInvocation", "LockOrderRecorder", "PortDecl", "RNGKeySpec",
    "SEVERITIES", "analyze", "analyze_target", "build_topology",
    "check_cost_models", "check_graph", "check_invocation",
    "check_kernels", "check_plan", "check_rng", "check_topology",
    "filter_findings", "format_findings", "max_severity", "severity_rank",
]


def analyze(graph: Optional[Any] = None, plan: Optional[Any] = None,
            cost_model: Optional[Dict[str, Any]] = None, *,
            cluster: Optional[Any] = None, cfg: Optional[Any] = None,
            cycle_specs: Optional[Dict[str, Any]] = None,
            sync_edges: Sequence[Tuple[str, str]] = (),
            kernels: bool = False,
            min_severity: str = "info") -> List[Finding]:
    """Run every applicable flowlint pass over the given artifacts.

    Pass whatever exists: a graph alone gets Pass 1's graph checks; a
    plan adds the plan invariants and Pass 2's concurrency analysis (the
    channel topology is derived from the plan); ``kernels=True`` adds
    Pass 3's config-zoo kernel sweep and the RNG-determinism check
    (artifact-independent, so opt-in).
    """
    findings: List[Finding] = []
    if graph is not None:
        findings.extend(check_graph(graph, cycle_specs))
        if cost_model is not None:
            findings.extend(check_cost_models(graph, cost_model))
    if plan is not None:
        findings.extend(check_plan(plan, graph=graph, cluster=cluster,
                                   cfg=cfg, cycle_specs=cycle_specs,
                                   sync_edges=sync_edges))
        topo = build_topology(graph, plan, cycle_specs)
        findings.extend(check_topology(topo))
    if kernels:
        findings.extend(check_kernels())
        findings.extend(check_rng())
    return filter_findings(findings, min_severity)


def analyze_target(target: Any, *, kernels: bool = False,
                   min_severity: str = "info") -> List[Finding]:
    """Transform a :class:`repro.analysis.targets.LintTarget` (run the
    planner) and analyze graph + plan together."""
    from repro.analysis.targets import plan_for
    plan = plan_for(target)
    return analyze(target.graph, plan, target.cost_models,
                   cluster=target.cluster, cfg=target.scheduler_cfg,
                   cycle_specs=target.cycle_specs,
                   sync_edges=target.sync_edges, kernels=kernels,
                   min_severity=min_severity)
