"""flowlint Pass 1 — graph and execution-plan invariants.

Checks the *transformation artifacts* (the workflow graph and the
ExecutionPlan the Scheduler/Controller produced) instead of any worker's
code — the M2Flow premise is that correctness lives in these artifacts:

  * graph hygiene: cycles outside declared CycleSpecs, orphan nodes,
    disconnected components;
  * placement hygiene: every worker placed, no unknown workers, no dead
    or out-of-range devices;
  * schedule-tree invariants: Pipelined/Async sides on disjoint devices,
    chunk granularities aligned with ``chunk_multiple`` (the
    silent-zero-advantage bug class), non-empty device splits;
  * collapsed-cycle round-trips: every cycle leaf has a members entry
    (or its members silently escape the Temporal offload/onload
    discipline) and hybrid member_devices match the member tuple;
  * weight-sync edges: both endpoints exist and own a non-empty device
    slice, or the resharding data plane has no mesh to land on.

All checks are pure functions of the artifacts; nothing executes.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import networkx as nx

from repro.analysis.findings import Finding
from repro.core.flowgraph import FlowGraph, cycle_node_name
from repro.core.scheduler import Async, Leaf, Pipelined, Temporal, leaves

PASS = "plan"


def _f(code: str, severity: str, subject: str, message: str,
       hint: str = "") -> Finding:
    return Finding(code, severity, subject, message, hint, PASS)


# ---------------------------------------------------------------------------
# graph checks
# ---------------------------------------------------------------------------
def check_graph(graph: FlowGraph,
                cycle_specs: Optional[Dict[str, Any]] = None
                ) -> List[Finding]:
    out: List[Finding] = []
    g = graph.g
    specs = cycle_specs or {}

    # P101 — cycles outside declared CycleSpecs.  A cycle collapses into
    # one schedulable node; without a CycleSpec the executor cannot
    # realize it as a closed loop and raises at runtime — catch it here.
    for comp in nx.strongly_connected_components(g):
        members = tuple(sorted(comp))
        is_cycle = len(members) > 1 or g.has_edge(members[0], members[0])
        if is_cycle:
            name = cycle_node_name(members)
            if name not in specs:
                out.append(_f(
                    "P101", "error", name,
                    f"cycle over {members} has no declared CycleSpec",
                    "register a CycleSpec for this collapsed node "
                    "(WorkflowRunner.cycle_specs) or break the cycle"))
            else:
                spec = specs[name]
                order = tuple(getattr(spec, "order", ()))
                if sorted(order) != list(members):
                    out.append(_f(
                        "P102", "error", name,
                        f"CycleSpec order {order} does not cover the "
                        f"cycle members {members}",
                        "the spec's order must name every member of the "
                        "strongly-connected component exactly once"))

    # P103 — orphan nodes: a worker with no data dependencies at all in
    # a multi-node graph is almost always a forgotten channel edge.
    if g.number_of_nodes() > 1:
        for n in g.nodes:
            if g.in_degree(n) == 0 and g.out_degree(n) == 0:
                out.append(_f(
                    "P103", "warning", n,
                    "node has no incoming or outgoing edges",
                    "connect it with add_edge(...) or drop it from the "
                    "graph; the scheduler will otherwise place it as an "
                    "independent stage"))

    # P104 — disconnected graph (beyond orphans): separate weakly-
    # connected components of size >= 2 mean two sub-workflows that never
    # exchange data — usually a missing edge, occasionally intentional.
    comps = [c for c in nx.weakly_connected_components(g) if len(c) >= 2]
    if len(comps) > 1:
        out.append(_f(
            "P104", "warning",
            "+".join(sorted(min(c) for c in comps)),
            f"graph splits into {len(comps)} disconnected sub-workflows",
            "if these workflows are truly independent, lint and plan "
            "them separately"))
    return out


def check_cost_models(graph: FlowGraph,
                      cost_models: Dict[str, Any]) -> List[Finding]:
    """P105 — every graph node needs a cost model, or Algorithm 1 prices
    that stage from thin air and the plan's est_time is fiction."""
    out: List[Finding] = []
    for n in sorted(graph.nodes):
        if n not in cost_models:
            out.append(_f(
                "P105", "warning", n,
                "no cost model for this worker — the scheduler will "
                "price its stage with defaults",
                "run the profiling iteration (WorkflowRunner.profile) "
                "or register a CostModel for it"))
    return out


# ---------------------------------------------------------------------------
# plan checks
# ---------------------------------------------------------------------------
def _expand(name: str, members: Dict[str, Tuple[str, ...]]) -> Tuple[str, ...]:
    return members.get(name, (name,))


def _side_workers(sched, members: Dict[str, Tuple[str, ...]]) -> List[str]:
    out: List[str] = []
    for lf in leaves(sched):
        out.extend(_expand(lf.worker, members))
    return out


def _placed_devices(workers: Iterable[str],
                    placement: Dict[str, List[int]]) -> set:
    devs: set = set()
    for w in workers:
        devs |= set(placement.get(w, ()))
    return devs


def check_plan(plan: Any, graph: Optional[FlowGraph] = None,
               cluster: Optional[Any] = None,
               cfg: Optional[Any] = None,
               cycle_specs: Optional[Dict[str, Any]] = None,
               sync_edges: Sequence[Tuple[str, str]] = ()
               ) -> List[Finding]:
    """Pass-1 invariants of one ExecutionPlan (duck-typed: needs
    ``schedule``, ``placement`` and ``members``)."""
    out: List[Finding] = []
    sched = plan.schedule
    placement: Dict[str, List[int]] = dict(plan.placement or {})
    members: Dict[str, Tuple[str, ...]] = dict(
        getattr(plan, "members", None) or {})

    plan_workers = set(_side_workers(sched, members))

    # ---- placement membership ------------------------------------------------
    if graph is not None:
        graph_workers = set()
        for n in graph.nodes:
            graph_workers.update(_expand(n, members))
        for w in sorted(set(placement) - graph_workers):
            out.append(_f(
                "P201", "warning", w,
                "placement names a worker absent from the workflow graph",
                "stale placement entry — drop it or add the worker to "
                "the graph"))
        missing_side = graph_workers
    else:
        missing_side = plan_workers
    for w in sorted(missing_side):
        if not placement.get(w):
            out.append(_f(
                "P202", "error", w,
                "worker has no (or an empty) device slice in the "
                "placement",
                "every scheduled worker needs devices; re-run "
                "Controller.plan or fix the hand-built placement"))

    # ---- device liveness -----------------------------------------------------
    if cluster is not None:
        n_dev = cluster.num_devices
        for w, devs in sorted(placement.items()):
            for d in devs:
                if not (0 <= d < n_dev):
                    out.append(_f(
                        "P203", "error", w,
                        f"placement references device {d} outside the "
                        f"cluster (0..{n_dev - 1})",
                        "the plan was built for a different topology; "
                        "re-plan against this cluster"))
                elif not cluster.device_alive(d):
                    out.append(_f(
                        "P204", "error", w,
                        f"placement references device {d} on a failed "
                        f"host",
                        "re-plan over cluster.available_devices() "
                        "(recovery does this automatically)"))

    # ---- schedule-tree invariants -------------------------------------------
    out.extend(_check_tree(sched, placement, members, cfg))

    # ---- collapsed-cycle round-trips ----------------------------------------
    out.extend(_check_cycles(sched, placement, members, cycle_specs))

    # ---- weight-sync edges ---------------------------------------------------
    for src, dst in sync_edges:
        for end, role in ((src, "source"), (dst, "destination")):
            known = end in plan_workers or end in placement
            if not known:
                out.append(_f(
                    "P207", "error", f"{src}->{dst}",
                    f"weight-sync {role} {end!r} is not part of the plan",
                    "weight_sync_workers must name scheduled workers"))
            elif not placement.get(end):
                out.append(_f(
                    "P208", "error", f"{src}->{dst}",
                    f"weight-sync {role} {end!r} has no device slice — "
                    f"the resharding data plane has no mesh to place "
                    f"params on",
                    "give the worker a non-empty placement (its "
                    "state_shardings need a mesh)"))
    return out


def _check_tree(sched, placement, members, cfg) -> List[Finding]:
    out: List[Finding] = []
    chunk_multiple = int(getattr(cfg, "chunk_multiple", 1) or 1)

    def walk(node):
        if isinstance(node, Leaf):
            return
        if isinstance(node, (Pipelined, Async)):
            kind = type(node).__name__
            # P205 — spatial sides must sit on disjoint devices: an
            # overlap time-shares what the cost model priced as parallel
            # (the Pipelined-starvation bug class PR 6's property tests
            # caught at runtime).
            s_devs = _placed_devices(_side_workers(node.s, members),
                                     placement)
            t_devs = _placed_devices(_side_workers(node.t, members),
                                     placement)
            shared = sorted(s_devs & t_devs)
            if shared:
                out.append(_f(
                    "P205", "error", kind,
                    f"{kind} sides share device(s) {shared} — the plan "
                    f"priced them as disjoint",
                    "re-place the sides on disjoint slices (the "
                    "scheduler's n_s/n_t split) or use a Temporal cut"))
            if node.n_s <= 0 or node.n_t <= 0:
                out.append(_f(
                    "P206", "error", kind,
                    f"{kind} records an empty device split "
                    f"(n_s={node.n_s}, n_t={node.n_t})",
                    "both sides of a spatial cut need at least one "
                    "device"))
            if isinstance(node, Pipelined):
                m = node.granularity
                if m <= 0 or m % chunk_multiple:
                    out.append(_f(
                        "P209", "error", kind,
                        f"pipeline granularity {m} is not a positive "
                        f"multiple of chunk_multiple={chunk_multiple}",
                        "a chunk boundary that splits a data atom (e.g. "
                        "a GRPO group) silently zeroes group-relative "
                        "advantages; set SchedulerConfig.chunk_multiple"))
            if isinstance(node, Async) and node.depth < 0:
                out.append(_f(
                    "P210", "error", kind,
                    f"negative staleness bound K={node.depth}",
                    "async depth must be >= 0 (0 = synchronous)"))
        walk(node.s)
        walk(node.t)

    walk(sched)
    return out


def _check_cycles(sched, placement, members, cycle_specs) -> List[Finding]:
    out: List[Finding] = []
    specs = cycle_specs or {}
    for lf in leaves(sched):
        looks_cyclic = lf.cycle_mode is not None or lf.worker in members
        if not looks_cyclic:
            continue
        ms = members.get(lf.worker, ())
        if len(ms) < 2:
            # P211 — a cycle leaf with no members entry: the switcher
            # sees only the synthetic node name, so its members escape
            # the offload/onload discipline at every Temporal cut (the
            # offload/onload sets stop round-tripping).
            out.append(_f(
                "P211", "error", lf.worker,
                "cycle leaf has no members entry in plan.members — its "
                "member workers escape offload/onload at Temporal cuts",
                "record {collapsed node: member tuple} on the plan "
                "(Controller.plan does this from graph.condense())"))
            continue
        if specs and lf.worker not in specs:
            out.append(_f(
                "P212", "error", lf.worker,
                "no CycleSpec registered for this cycle leaf",
                "pass cycle_specs={node: CycleSpec(...)} to "
                "Controller.execute"))
        if lf.cycle_mode == "hybrid":
            md = lf.member_devices or ()
            if len(md) != len(ms):
                out.append(_f(
                    "P213", "error", lf.worker,
                    f"hybrid member_devices {md} does not match the "
                    f"{len(ms)} member(s) {ms}",
                    "one device share per member, ordered like the "
                    "sorted member tuple"))
            elif sum(md) > lf.devices or any(s <= 0 for s in md):
                out.append(_f(
                    "P213", "error", lf.worker,
                    f"hybrid member_devices {md} exceed the leaf's "
                    f"{lf.devices} device(s) (or contain empty shares)",
                    "member shares must be positive and sum to at most "
                    "the leaf's device count"))
            if lf.cycle_chunks < 1:
                out.append(_f(
                    "P214", "error", lf.worker,
                    f"hybrid cycle_chunks={lf.cycle_chunks} < 1",
                    "the per-step env pipeline needs at least one chunk "
                    "(2 = double-buffered)"))
    return out
