"""Lint targets: the repo's workflow families and example graphs.

A :class:`LintTarget` bundles everything :func:`repro.analysis.analyze`
needs for one workflow — the graph, its CycleSpecs, synthetic cost
models (so planning is instant; no JAX model is built), a scheduler
config and a cluster.  The CLI and the CI gate iterate
:func:`all_targets`; the acceptance bar is zero findings on every one.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.controller import Controller, ExecutionPlan
from repro.core.flowgraph import FlowGraph
from repro.core.placement import Cluster
from repro.core.profiler import CostModel
from repro.core.scheduler import SchedulerConfig


@dataclass
class LintTarget:
    name: str
    graph: FlowGraph
    cycle_specs: Dict[str, Any] = field(default_factory=dict)
    cost_models: Dict[str, CostModel] = field(default_factory=dict)
    scheduler_cfg: SchedulerConfig = field(default_factory=SchedulerConfig)
    cluster: Cluster = field(default_factory=lambda: Cluster(
        num_nodes=1, devices_per_node=8))
    total_batch: int = 64
    # (src, dst) weight-sync edges (trainer -> generation workers)
    sync_edges: Tuple[Tuple[str, str], ...] = ()
    mode: str = "auto"
    # > 0: plan with the async off-policy dimension over this horizon
    async_iterations: int = 0


def _chain_cost_models(names) -> Dict[str, CostModel]:
    out: Dict[str, CostModel] = {}
    for i, n in enumerate(names):
        out[n] = CostModel(n, base_time=0.05 + 0.02 * i, slope_time=1e-3,
                           onload_time=0.2, offload_time=0.2)
    return out


def plan_for(target: LintTarget) -> ExecutionPlan:
    """Run the M2Flow transformation for a target (the artifact Pass 1/2
    actually lint)."""
    ctl = Controller(target.cluster, profiles=target.cost_models,
                     scheduler_cfg=target.scheduler_cfg)
    if target.async_iterations > 0:
        return ctl.plan_async(target.graph,
                              total_batch=target.total_batch,
                              iterations=target.async_iterations)
    return ctl.plan(target.graph, total_batch=target.total_batch,
                    mode=target.mode)


# ---------------------------------------------------------------------------
# Workflow-family targets (the three RL families the repo ships)
# ---------------------------------------------------------------------------
def grpo_target(mode: str = "auto") -> LintTarget:
    from repro.rl.grpo_workflow import WORKFLOW_ORDER, grpo_graph
    group = 8
    return LintTarget(
        name=f"grpo[{mode}]",
        graph=grpo_graph(),
        cost_models=_chain_cost_models(WORKFLOW_ORDER),
        scheduler_cfg=SchedulerConfig(
            total_batch=64, granularity_divisors=(1, 2, 4),
            device_quantum=2, chunk_multiple=group),
        total_batch=64,
        sync_edges=(("actor", "rollout"), ("actor", "inference")),
        mode=mode)


def async_grpo_target() -> LintTarget:
    t = grpo_target()
    t.name = "grpo[async]"
    t.async_iterations = 8
    return t


def rlhf_target(mode: str = "auto") -> LintTarget:
    from repro.rl.rlhf_workflow import rlhf_graph
    names = ("rollout", "inference", "reference", "critic_v", "reward",
             "actor")
    return LintTarget(
        name=f"rlhf[{mode}]",
        graph=rlhf_graph(),
        cost_models=_chain_cost_models(names),
        scheduler_cfg=SchedulerConfig(
            total_batch=32, granularity_divisors=(1, 2, 4),
            device_quantum=2, chunk_multiple=32),
        total_batch=32,
        sync_edges=(("actor", "rollout"), ("actor", "inference")),
        mode=mode)


def embodied_target(cycle_mode: Optional[str] = None) -> LintTarget:
    from repro.rl.embodied_workflow import (
        embodied_cycle_specs,
        embodied_graph,
    )
    num_envs = 16
    cms = _chain_cost_models(
        ("simulator", "policy_gen", "advantage", "train"))
    return LintTarget(
        name=f"embodied[{cycle_mode or 'auto'}]",
        graph=embodied_graph(),
        cycle_specs=embodied_cycle_specs(horizon=8, chunks=2),
        cost_models=cms,
        scheduler_cfg=SchedulerConfig(
            total_batch=num_envs, granularity_divisors=(1,),
            chunk_multiple=num_envs, device_quantum=2,
            cycle_mode=cycle_mode, cycle_chunks=2),
        total_batch=num_envs,
        sync_edges=(("train", "policy_gen"),))


def workflow_targets() -> List[LintTarget]:
    return [
        grpo_target(),
        grpo_target("collocated"),
        grpo_target("disaggregated"),
        async_grpo_target(),
        rlhf_target(),
        embodied_target(),
        embodied_target("collocated"),
        embodied_target("hybrid"),
    ]


# ---------------------------------------------------------------------------
# Example targets (every examples/*.py that builds a flow graph)
# ---------------------------------------------------------------------------
def deep_research_target() -> LintTarget:
    import importlib.util
    import pathlib
    import sys
    # examples/ is not a package — load the module by path, the same
    # graph main() plans
    path = (pathlib.Path(__file__).resolve().parents[3] / "examples"
            / "deep_research.py")
    spec = importlib.util.spec_from_file_location("_dr_example", path)
    mod = importlib.util.module_from_spec(spec)
    sys.modules.setdefault("_dr_example", mod)
    spec.loader.exec_module(mod)
    return LintTarget(
        name="example:deep_research",
        graph=mod.build_graph(),
        cycle_specs=mod.cycle_specs(),
        cost_models=mod.cost_models(),
        scheduler_cfg=SchedulerConfig(
            total_batch=64, granularity_divisors=(1, 2, 4),
            device_quantum=2),
        total_batch=64,
        sync_edges=(("train", "policy_gen"),))


def example_targets() -> List[LintTarget]:
    """Graphs the examples plan: quickstart / reasoning_grpo run the
    GRPO chain, async_grpo plans it with the async dimension,
    embodied_ppo runs the embodied cycle, deep_research builds its own
    policy↔tool loop (serve_batch has no flow graph)."""
    q = grpo_target()
    q.name = "example:quickstart"
    a = async_grpo_target()
    a.name = "example:async_grpo"
    e = embodied_target()
    e.name = "example:embodied_ppo"
    return [q, a, e, deep_research_target()]


def all_targets() -> List[LintTarget]:
    return workflow_targets() + example_targets()
