"""Architecture configs (assigned pool + the paper's own models)."""
from __future__ import annotations

import importlib

_MODULES = [
    "granite_moe_3b_a800m",
    "zamba2_2p7b",
    "whisper_large_v3",
    "llama4_scout_17b_a16e",
    "llama_3_2_vision_90b",
    "codeqwen1_5_7b",
    "mamba2_370m",
    "yi_9b",
    "mistral_large_123b",
    "stablelm_12b",
    "qwen2_5_7b",
]

_loaded = False


def _load_all() -> None:
    global _loaded
    if _loaded:
        return
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True


from repro.configs.base import (  # noqa: E402,F401
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    get_config,
    list_archs,
)
from repro.configs.shapes import SHAPES, get_shape, list_shapes  # noqa: E402,F401
