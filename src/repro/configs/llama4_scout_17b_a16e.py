"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("llama4-scout-17b-a16e")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        kind="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=0,
        vocab_size=202048,
        moe=MoEConfig(num_experts=16, top_k=1, expert_d_ff=8192,
                      shared_expert_d_ff=8192),
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
        notes="MoE top-1 with shared expert, early-fusion multimodal (text path)",
    )
