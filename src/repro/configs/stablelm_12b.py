"""stablelm-12b [hf:stabilityai/stablelm-2-1_6b family] — qk-norm."""
from repro.configs.base import ModelConfig, register


@register("stablelm-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b",
        kind="dense",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        d_ff=13824,
        vocab_size=100352,
        qk_norm=True,
        source="hf:stabilityai/stablelm-2-1_6b",
    )
