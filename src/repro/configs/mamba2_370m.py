"""mamba2-370m [arXiv:2405.21060] — SSD (state-space duality), attn-free."""
from repro.configs.base import ModelConfig, SSMConfig, register


@register("mamba2-370m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        kind="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm=SSMConfig(state_size=128),
        tie_embeddings=True,
        source="arXiv:2405.21060",
    )
