"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407]."""
from repro.configs.base import ModelConfig, register


@register("mistral-large-123b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        kind="dense",
        num_layers=88,
        d_model=12288,
        num_heads=96,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=32768,
        source="hf:mistralai/Mistral-Large-Instruct-2407",
    )
