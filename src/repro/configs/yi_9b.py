"""yi-9b [arXiv:2403.04652] — llama-arch with aggressive GQA (kv=4)."""
from repro.configs.base import ModelConfig, register


@register("yi-9b")
def config() -> ModelConfig:
    return ModelConfig(
        name="yi-9b",
        kind="dense",
        num_layers=48,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        d_ff=11008,
        vocab_size=64000,
        source="arXiv:2403.04652",
    )
