"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B] — qwen1.5 arch (qkv bias)."""
from repro.configs.base import ModelConfig, register


@register("codeqwen1.5-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b",
        kind="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        d_ff=13440,
        vocab_size=92416,
        qkv_bias=True,
        source="hf:Qwen/CodeQwen1.5-7B",
    )
