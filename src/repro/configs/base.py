"""Configuration system: model / shape / parallelism configs + registry.

Every assigned architecture lives in its own module under
``repro.configs`` and registers a :class:`ModelConfig` via
:func:`register`.  ``--arch <id>`` in the launchers resolves through
:func:`get_config`.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture kinds
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
ENCDEC = "encdec"  # audio backbone (whisper-style)
VLM = "vlm"

ARCH_KINDS = (DENSE, MOE, SSM, HYBRID, ENCDEC, VLM)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int
    top_k: int
    expert_d_ff: int
    # Capacity factor for token dispatch; capacity per expert is
    # ceil(tokens * top_k / num_experts * capacity_factor).
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # Auxiliary load-balance loss weight (Switch-style).
    aux_loss_weight: float = 1e-2
    # Shared (always-on) expert d_ff; 0 disables.
    shared_expert_d_ff: int = 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block configuration."""

    state_size: int
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 128
    conv_width: int = 4
    # number of SSD heads = d_inner / head_dim (derived)


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture's full configuration.

    Only the *backbone* transformer/SSM is described; modality frontends
    (audio conv stack, vision encoder) are stubs whose outputs are supplied
    as precomputed embeddings by ``input_specs``.
    """

    name: str
    kind: str
    num_layers: int
    d_model: int
    num_heads: int  # 0 for attention-free archs
    num_kv_heads: int
    d_ff: int  # dense FFN width (per-expert width lives in moe.expert_d_ff)
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    max_seq_len: int = 8192
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    qkv_bias: bool = False
    qk_norm: bool = False
    # --- MoE ---
    moe: Optional[MoEConfig] = None
    # --- SSM / hybrid ---
    ssm: Optional[SSMConfig] = None
    # hybrid: one *shared-weight* attention block applied every N ssm layers
    attn_every: int = 0
    # --- enc-dec (audio) ---
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500  # whisper: 30s -> 1500 frames after conv
    # --- vlm ---
    cross_attn_every: int = 0  # every Nth layer is a cross-attn layer
    num_image_tokens: int = 1024
    # --- long-context variant ---
    sliding_window: int = 0  # 0 = full attention; >0 = windowed
    # --- source citation ---
    source: str = ""
    notes: str = ""

    # ------------------------------------------------------------------
    # Derived quantities
    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded so the embedding shards cleanly on a 16-way axis."""
        return _ceil_to(self.vocab_size, 16 * 128)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        if self.ssm is None:
            return 0
        return self.ssm.expand * self.d_model

    @property
    def num_ssm_heads(self) -> int:
        if self.ssm is None:
            return 0
        return self.d_inner // self.ssm.head_dim

    @property
    def num_self_layers(self) -> int:
        """Decoder self-attention/SSM layers excluding periodic extras."""
        if self.kind == VLM and self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            return self.num_layers - n_cross
        return self.num_layers

    @property
    def num_cross_layers(self) -> int:
        if self.kind == VLM and self.cross_attn_every:
            return self.num_layers // self.cross_attn_every
        if self.kind == ENCDEC:
            return self.num_layers  # every decoder layer cross-attends
        return 0

    # ------------------------------------------------------------------
    # Parameter counting (for 6ND model FLOPs and roofline)
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        q = self.d_model * self.num_heads * hd
        kv = 2 * self.d_model * self.num_kv_heads * hd
        o = self.num_heads * hd * self.d_model
        return q + kv + o

    def _dense_ffn_params(self, d_ff: int) -> int:
        # gated (SwiGLU-style): gate, up, down
        return 3 * self.d_model * d_ff

    def _ssm_params(self) -> int:
        assert self.ssm is not None
        di, ds = self.d_inner, self.ssm.state_size
        nh = self.num_ssm_heads
        # in_proj -> [z, x, B, C, dt]; out_proj
        in_proj = self.d_model * (2 * di + 2 * ds + nh)
        conv = self.ssm.conv_width * (di + 2 * ds)
        out_proj = di * self.d_model
        return in_proj + conv + out_proj + 2 * nh  # A_log, D

    def layer_params(self) -> Dict[str, int]:
        """Parameter count per layer *type*."""
        out: Dict[str, int] = {}
        if self.kind in (DENSE, ENCDEC, VLM):
            out["self"] = self._attn_params() + self._dense_ffn_params(self.d_ff)
        if self.kind == MOE:
            assert self.moe is not None
            expert = self._dense_ffn_params(self.moe.expert_d_ff)
            router = self.d_model * self.moe.num_experts
            shared = (
                self._dense_ffn_params(self.moe.shared_expert_d_ff)
                if self.moe.shared_expert_d_ff
                else 0
            )
            out["self"] = (
                self._attn_params() + self.moe.num_experts * expert + router + shared
            )
            out["self_active"] = (
                self._attn_params() + self.moe.top_k * expert + router + shared
            )
        if self.kind == SSM:
            out["ssm"] = self._ssm_params() + (
                self._dense_ffn_params(self.d_ff) if self.d_ff else 0
            )
        if self.kind == HYBRID:
            # zamba-style: mamba blocks carry no FFN; d_ff belongs to the
            # shared attention block.
            out["ssm"] = self._ssm_params()
            out["shared_attn"] = self._attn_params() + self._dense_ffn_params(
                max(self.d_ff, 4 * self.d_model)
            )
        if self.kind == VLM:
            out["cross"] = self._attn_params() + self._dense_ffn_params(self.d_ff)
        if self.kind == ENCDEC:
            out["enc"] = self._attn_params() + self._dense_ffn_params(self.d_ff)
            out["cross"] = self._attn_params()
        return out

    def param_count(self, active_only: bool = False) -> int:
        lp = self.layer_params()
        emb = self.padded_vocab * self.d_model
        total = emb if self.tie_embeddings else 2 * emb
        if self.kind in (DENSE,):
            total += self.num_layers * lp["self"]
        elif self.kind == MOE:
            key = "self_active" if active_only else "self"
            total += self.num_layers * lp[key]
        elif self.kind == SSM:
            total += self.num_layers * lp["ssm"]
        elif self.kind == HYBRID:
            total += self.num_layers * lp["ssm"]
            total += lp["shared_attn"]  # shared weights counted ONCE
        elif self.kind == VLM:
            total += self.num_self_layers * lp["self"]
            total += self.num_cross_layers * lp["cross"]
        elif self.kind == ENCDEC:
            total += self.num_encoder_layers * lp["enc"]
            total += self.num_layers * (lp["self"] + lp["cross"])
        return total

    def active_param_count(self) -> int:
        return self.param_count(active_only=True)

    # ------------------------------------------------------------------
    def validate(self) -> None:
        assert self.kind in ARCH_KINDS, self.kind
        if self.kind in (SSM, HYBRID):
            assert self.ssm is not None
        if self.kind == MOE:
            assert self.moe is not None
        if self.num_heads:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
                "GQA requires num_heads % num_kv_heads == 0"
            )

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests.

        2 layers, d_model <= 512, <= 4 experts, per assignment.
        """
        kw: Dict[str, object] = dict(
            name=self.name + "-smoke",
            num_layers=2,
            d_model=256,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_heads else 0,
            head_dim=64 if self.num_heads else 0,
            d_ff=512 if self.d_ff else 0,
            vocab_size=512,
            max_seq_len=256,
            num_encoder_layers=2 if self.num_encoder_layers else 0,
            encoder_seq_len=32 if self.kind == ENCDEC else self.encoder_seq_len,
            cross_attn_every=2 if self.cross_attn_every else 0,
            num_image_tokens=16 if self.kind == VLM else self.num_image_tokens,
            attn_every=2 if self.attn_every else 0,
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                expert_d_ff=128,
                capacity_factor=self.moe.capacity_factor,
                aux_loss_weight=self.moe.aux_loss_weight,
                shared_expert_d_ff=64 if self.moe.shared_expert_d_ff else 0,
            )
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(
                state_size=16, expand=2, head_dim=32, chunk_size=32, conv_width=4
            )
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    phase: str  # "train" | "prefill" | "decode"

    @property
    def tokens_per_step(self) -> int:
        if self.phase == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    # import side-effect registration
    from repro.configs import _load_all  # noqa: F401

    _load_all()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}"
        )
    cfg = _REGISTRY[name]()
    cfg.validate()
    return cfg


def list_archs() -> List[str]:
    from repro.configs import _load_all

    _load_all()
    return sorted(_REGISTRY)
