"""qwen2.5-7b — the paper's own reasoning-RL model family [arXiv Qwen2.5].

Used by the end-to-end examples and benchmarks (Fig. 8b analogue).
"""
from repro.configs.base import ModelConfig, register


@register("qwen2.5-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-7b",
        kind="dense",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        qkv_bias=True,
        source="arXiv:2412.15115 (Qwen2.5)",
    )
