"""Assigned input shapes (public-pool assignment for this paper)."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ShapeConfig

TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, phase="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, phase="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, phase="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, phase="decode")

SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def list_shapes() -> List[str]:
    return list(SHAPES)
