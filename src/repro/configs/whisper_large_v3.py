"""whisper-large-v3 backbone [arXiv:2212.04356].

Enc-dec transformer backbone only; the mel-spectrogram + conv frontend is a
stub — input_specs feeds precomputed (B, 1500, d_model) frame embeddings.
"""
from repro.configs.base import ModelConfig, register


@register("whisper-large-v3")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        kind="encdec",
        num_layers=32,
        num_encoder_layers=32,
        encoder_seq_len=1500,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        max_seq_len=448,
        source="arXiv:2212.04356",
    )
