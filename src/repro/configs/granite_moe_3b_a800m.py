"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base].

Assignment header: 32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155,
"MoE 40e top-8".  The HF card lists 32 experts; we follow the assignment
header (40 experts) and note the discrepancy in DESIGN.md.
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("granite-moe-3b-a800m")
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        kind="moe",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=0,
        vocab_size=49155,
        moe=MoEConfig(num_experts=40, top_k=8, expert_d_ff=512),
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )
