"""zamba2-2.7b [arXiv:2411.15242] — Mamba2 backbone + shared attn blocks."""
from repro.configs.base import ModelConfig, SSMConfig, register


@register("zamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        kind="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        ssm=SSMConfig(state_size=64),
        attn_every=6,  # one shared-weight attention block every 6 mamba layers
        source="arXiv:2411.15242",
    )
