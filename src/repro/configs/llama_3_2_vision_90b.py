"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-11B-Vision].

Cross-attention image layers every 5th layer; ViT frontend is a stub —
input_specs feeds precomputed (B, num_image_tokens, d_model) patch
embeddings.
"""
from repro.configs.base import ModelConfig, register


@register("llama-3.2-vision-90b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        kind="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        cross_attn_every=5,
        num_image_tokens=1024,
        source="hf:meta-llama/Llama-3.2-11B-Vision",
    )
