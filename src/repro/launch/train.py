"""Production training launcher: mesh + sharded state + train loop.

On a real TPU pod this is the per-host entry point (`jax.distributed`
initializes from the TPU environment); on CPU it runs the same code path
on a 1×1 mesh with a reduced config (--smoke), so the launcher itself is
exercised by CI.

Usage:
  python -m repro.launch.train --arch yi-9b --smoke --steps 10
  python -m repro.launch.train --arch mistral-large-123b \
      --seq 4096 --batch 256 --multi-pod        # on a 512-chip pod slice
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import init_model
from repro.train import TrainHParams, init_adamw, lm_loss, make_train_step
from repro.train.checkpoint import save_checkpoint
from repro.train.optimizer import AdamWConfig
from repro.train.sharding_rules import array_batch_specs, param_specs
from repro.utils.logging import log
from repro.utils.sharding import set_active_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + local mesh (CPU)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--checkpoint", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        mesh = make_local_mesh()
    else:
        if jax.process_count() > 1 or "tpu" in jax.default_backend():
            jax.distributed.initialize()
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    set_active_mesh(mesh)
    log("launch", f"arch={cfg.name} mesh={dict(mesh.shape)} "
        f"params≈{cfg.param_count() / 1e9:.2f}B")

    hp = TrainHParams(
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=10, clip_norm=1.0),
        n_microbatches=args.n_micro,
        remat=not args.smoke,
    )

    with mesh:
        params = init_model(jax.random.PRNGKey(0), cfg)
        pspecs = param_specs(mesh, cfg, params)
        params = jax.tree_util.tree_map(
            lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
            params, pspecs, is_leaf=lambda x: isinstance(x, P))
        opt = init_adamw(params)
        step = jax.jit(make_train_step(cfg, hp, loss_fn=lm_loss),
                       donate_argnums=(0, 1))

        rng = np.random.default_rng(0)
        t0 = time.time()
        for i in range(args.steps):
            batch_np = {"tokens": rng.integers(
                0, cfg.vocab_size, (args.batch, args.seq)).astype(np.int32)}
            specs = array_batch_specs(mesh, batch_np)
            batch = jax.tree_util.tree_map(
                lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
                batch_np, specs, is_leaf=lambda x: isinstance(x, P))
            params, opt, metrics = step(params, opt, batch)
            if i % 10 == 0 or i == args.steps - 1:
                log("train", f"step {i}",
                    loss=f"{float(metrics['loss']):.4f}",
                    gnorm=f"{float(metrics['grad_norm']):.3f}")
        tokens = args.steps * args.batch * args.seq
        log("done", f"{tokens / (time.time() - t0):.0f} tok/s")

    if args.checkpoint:
        save_checkpoint(args.checkpoint, {"params": params, "opt": opt},
                        step=args.steps, metadata={"arch": cfg.name})
        log("ckpt", f"saved to {args.checkpoint}")
    set_active_mesh(None)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
