"""Production mesh construction (TPU v5e pods).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so smoke tests see 1 device while the
dry-run sees 512 forced host devices).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax.sharding has no AxisType
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    """``jax.make_mesh`` across jax versions: pass ``axis_types`` only when
    the pinned jax supports it; otherwise plain axis handling."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int = 1) -> Mesh:
    """Small mesh over however many local devices exist (tests)."""
    n = len(jax.devices())
    assert model * data <= n, (model, data, n)
    return _make_mesh((data, model), ("data", "model"))
