"""Production mesh construction (TPU v5e pods).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so smoke tests see 1 device while the
dry-run sees 512 forced host devices).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # older jax.sharding has no AxisType
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    """``jax.make_mesh`` across jax versions: pass ``axis_types`` only when
    the pinned jax supports it; otherwise plain axis handling."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int = 1) -> Mesh:
    """Small mesh over however many local devices exist (tests)."""
    n = len(jax.devices())
    assert model * data <= n, (model, data, n)
    return _make_mesh((data, model), ("data", "model"))


def mesh_for_devices(global_ids: Sequence[int], *,
                     axis: str = "data") -> Optional[Mesh]:
    """1-D mesh over the LOCAL jax devices backing a cluster device slice
    (the mesh a worker rebuilds when ``bind_devices`` rebinds it).

    Global cluster ids fold onto local devices round-robin
    (``id % n_local``): at production scale the slice maps 1:1 onto real
    accelerators; on a CI/laptop host every id lands on the lone CPU
    device.  Duplicates are dropped — a Mesh must not repeat devices."""
    if not global_ids:
        return None
    local = jax.devices()
    picked, seen = [], set()
    for g in global_ids:
        d = local[int(g) % len(local)]
        if d.id not in seen:
            seen.add(d.id)
            picked.append(d)
    return Mesh(np.array(picked), (axis,))
