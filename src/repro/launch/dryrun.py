import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + str(
    int(os.environ.get("REPRO_DRYRUN_HOSTS", "1"))
    * int(os.environ.get("REPRO_DRYRUN_DEVICES", "512")))

# NOTE: the statements above MUST be the first in this module — jax locks
# the device count on first init — which is why the docstring below is a
# plain string and __future__ imports are omitted.

_DOC = """Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

The statements above MUST stay first: jax locks the device count on first
init, and only the dry-run should see the placeholder devices.  The faked
topology is configurable (REPRO_DRYRUN_HOSTS × REPRO_DRYRUN_DEVICES
placeholder devices, default 1 × 512) so tests and benchmarks can
parametrize shape instead of hardcoding one — see tests/conftest.py.

For each case we record memory_analysis (fits-on-chip proof),
cost_analysis (FLOPs/bytes for §Roofline) and the collective schedule
parsed from the compiled HLO, into experiments/dryrun/*.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape, list_archs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.train import TrainHParams, init_adamw, make_serve_step, make_train_step
from repro.train import trainer as trainer_mod
from repro.train.sharding_rules import (
    array_batch_specs,
    decode_state_specs,
    param_specs,
)
from repro.utils.hlo_analysis import analyze as analyze_hlo
from repro.utils.roofline import RooflineReport, model_flops
from repro.utils.sharding import MODEL, batch_axes, maybe_axis, set_active_mesh

ASSIGNED_ARCHS = [
    "granite-moe-3b-a800m",
    "zamba2-2.7b",
    "whisper-large-v3",
    "llama4-scout-17b-a16e",
    "llama-3.2-vision-90b",
    "codeqwen1.5-7b",
    "mamba2-370m",
    "yi-9b",
    "mistral-large-123b",
    "stablelm-12b",
]
SHAPE_NAMES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Per-case configuration policy
# ---------------------------------------------------------------------------
def arch_for_shape(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """long_500k uses the sub-quadratic variant: sliding-window (8192) for
    attention archs; SSM/hybrid archs are O(1)-state already."""
    if shape.name == "long_500k" and cfg.num_heads and cfg.kind != "hybrid":
        return cfg.replace(sliding_window=8192)
    return cfg


def hparams_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                *, n_micro: Optional[int] = None,
                seq_parallel: bool = True) -> TrainHParams:
    if n_micro is None:
        # activation memory scales with d_model·depth; deeper/wider models
        # need more microbatches (see EXPERIMENTS.md §Perf for tuning)
        act_cost = cfg.d_model * cfg.num_layers
        if act_cost >= 500_000:
            n_micro = 16
        elif act_cost >= 120_000:
            n_micro = 8
        else:
            n_micro = 4
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh.shape.get(a, 1)
    # keep per-microbatch batch divisible by the dp axis
    while n_micro > 1 and (shape.global_batch // n_micro) % dp != 0:
        n_micro //= 2
    act_spec = None
    if seq_parallel and shape.seq_len % mesh.shape.get(MODEL, 1) == 0:
        bax = batch_axes(mesh)
        act_spec = P(bax, MODEL, None)
    return TrainHParams(n_microbatches=max(n_micro, 1), remat=True,
                        act_spec=act_spec)


# ---------------------------------------------------------------------------
# input_specs
# ---------------------------------------------------------------------------
def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _with_shardings(tree_sds, tree_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        tree_sds, tree_specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )


def params_sds(cfg: ModelConfig, mesh: Mesh, dtype=jnp.bfloat16):
    sds = jax.eval_shape(
        lambda: M.init_model(jax.random.PRNGKey(0), cfg, dtype))
    return _with_shardings(sds, param_specs(mesh, cfg, sds), mesh)


def batch_sds(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "old_logprobs": jax.ShapeDtypeStruct((B, S), jnp.float32),
        "advantages": jax.ShapeDtypeStruct((B, S), jnp.float32),
        "loss_mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
    }
    if cfg.kind == "vlm":
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.kind == "encdec":
        batch["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return _with_shardings(batch, array_batch_specs(mesh, batch), mesh)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                hp: Optional[TrainHParams] = None,
                cache_dtype=jnp.bfloat16, decode_unroll: int = 1):
    """Returns (step_fn, args tuple of ShapeDtypeStructs, donate_argnums)."""
    cfg = arch_for_shape(cfg, shape)
    hp = hp or hparams_for(cfg, shape, mesh)
    if shape.phase == "train":
        pv = params_sds(cfg, mesh)
        opt = jax.eval_shape(init_adamw, pv)
        opt = _with_shardings(
            opt,
            jax.tree_util.tree_map(
                lambda s: s.sharding.spec if hasattr(s, "sharding") and s.sharding
                else P(), opt),
            mesh,
        )
        # moments mirror param shardings; step scalar replicated
        opt = opt._replace(
            mu=_with_shardings(opt.mu, param_specs(mesh, cfg, opt.mu), mesh),
            nu=_with_shardings(opt.nu, param_specs(mesh, cfg, opt.nu), mesh),
            step=_sds((), jnp.int32, mesh, P()),
        )
        batch = batch_sds(cfg, shape, mesh)
        if hp.grad_specs is None:
            hp = hp._replace(grad_specs=param_specs(mesh, cfg, pv))
        step = make_train_step(cfg, hp)
        return step, (pv, opt, batch), (0, 1)

    if shape.phase == "prefill":
        pv = params_sds(cfg, mesh)
        batch = batch_sds(cfg, shape, mesh)
        step = trainer_mod.make_prefill_step(cfg, hp)
        return step, (pv, batch), ()

    # decode
    pv = params_sds(cfg, mesh)
    B = shape.global_batch
    state_sds = jax.eval_shape(
        lambda: M.init_decode_state(cfg, B, shape.seq_len, cache_dtype))
    state = _with_shardings(state_sds, decode_state_specs(mesh, cfg, state_sds),
                            mesh)
    bax = maybe_axis(mesh, B, batch_axes(mesh))
    token = _sds((B, 1), jnp.int32, mesh, P(bax, None))
    pos = _sds((), jnp.int32, mesh, P())
    step = make_serve_step(cfg, unroll=decode_unroll)
    return step, (pv, token, state, pos), (2,)


# ---------------------------------------------------------------------------
# Run one case
# ---------------------------------------------------------------------------
def run_case(arch: str, shape_name: str, *, multi_pod: bool = False,
             hp: Optional[TrainHParams] = None,
             cache_dtype=jnp.bfloat16, decode_unroll: int = 1,
             cfg_transform=None,
             save: bool = True, verbose: bool = True,
             tag: str = "") -> Dict[str, Any]:
    cfg = get_config(arch)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    set_active_mesh(mesh)
    try:
        step, args, donate = input_specs(cfg, shape, mesh, hp,
                                         cache_dtype=cache_dtype,
                                         decode_unroll=decode_unroll)
        with mesh:
            lowered = jax.jit(step, donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
    finally:
        set_active_mesh(None)
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()  # per-body XLA numbers (no trip counts)
    hlo = analyze_hlo(compiled.as_text())  # trip-count-aware (per device)
    rep = RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=hlo.flops,
        hlo_bytes=hlo.bytes,
        collective_bytes=hlo.collective_bytes,
        model_flops=model_flops(cfg, shape),
        arg_bytes=int(mem.argument_size_in_bytes),
        temp_bytes=int(mem.temp_size_in_bytes),
        collective_counts=hlo.collective_counts,
    ).finalize()
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_est_bytes": int(mem.argument_size_in_bytes)
            + int(mem.temp_size_in_bytes)
            + int(mem.output_size_in_bytes) - int(mem.alias_size_in_bytes),
        },
        "cost_xla": {k: float(v) for k, v in cost.items()
                     if k in ("flops", "bytes accessed", "transcendentals")},
        "hlo": {"flops": hlo.flops, "bytes": hlo.bytes,
                "dot_flops": hlo.dot_flops, "conv_flops": hlo.conv_flops,
                "unknown_trip_loops": hlo.unknown_trip_loops},
        "collectives": {"counts": hlo.collective_counts,
                        "bytes_by_kind": hlo.collective_bytes_by_kind,
                        "total_bytes": hlo.collective_bytes},
        "roofline": {
            "compute_s": rep.compute_s, "memory_s": rep.memory_s,
            "collective_s": rep.collective_s, "dominant": rep.dominant,
            "model_flops": rep.model_flops,
            "useful_flops_ratio": rep.useful_flops_ratio,
        },
    }
    if verbose:
        hbm = result["memory"]["peak_est_bytes"] / 2**30
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}"
              f"  lower={t_lower:.1f}s compile={t_compile:.1f}s"
              f"  peak≈{hbm:.2f}GiB/chip  dom={rep.dominant}")
        print("         " + rep.row())
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fn = os.path.join(OUT_DIR, f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.arch is None else [args.arch]
    shapes = SHAPE_NAMES if args.shape is None else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_case(arch, shape, multi_pod=mp)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUN CASES PASSED")


if __name__ == "__main__":
    main()
