"""Simulated multi-host launch path (paper §4 scale-out).

Presents N hosts × M devices behind the existing ``Cluster``/``Router``
abstractions so every plan → place → execute path runs against >1 host
without real machines:

  * :class:`SimulatedCluster` — a ``Cluster`` whose devices belong to
    named hosts; hosts can *fail* (their devices drop out of
    ``available_devices`` and new allocations reject them) and be
    *restored*, which is what the fault-injection harness
    (``core.faults``) drives;
  * :func:`maybe_init_jax_distributed` — real ``jax.distributed`` init
    when a coordinator is configured (``REPRO_COORD_ADDR``), process-
    local shards otherwise — the same code path either way;
  * :func:`cluster_from_env` — topology from ``REPRO_DRYRUN_HOSTS`` /
    ``REPRO_DRYRUN_DEVICES`` (the knob ``launch/dryrun.py`` and
    ``tests/conftest.py`` document), so tests and benchmarks can
    parametrize shape instead of hardcoding one.

Global device IDs stay flat (host h, local device j → ``h*M + j``), so
schedules, placements, and worker meshes are oblivious to host
boundaries; only liveness and the router's ``host=`` registration field
carry host identity.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import List, Optional, Set

from repro.core.placement import Cluster


@dataclass
class SimulatedCluster(Cluster):
    """A ``Cluster`` whose nodes are named hosts with a liveness bit.

    ``num_nodes``/``devices_per_node`` keep their base meaning; a failed
    host's devices stay visible in ``num_devices`` (global IDs must not
    shift under running placements) but disappear from
    ``available_devices`` and are rejected by ``allocate``.
    """
    _dead_hosts: Set[int] = field(default_factory=set)

    # -- host identity ------------------------------------------------------
    @property
    def num_hosts(self) -> int:
        return self.num_nodes

    def host_name(self, host: int) -> str:
        return f"host{host}"

    def host_of(self, global_id: int) -> str:
        return self.host_name(self.node_of(global_id))

    def host_devices(self, host: int) -> List[int]:
        lo = host * self.devices_per_node
        return list(range(lo, lo + self.devices_per_node))

    # -- liveness -----------------------------------------------------------
    def device_alive(self, global_id: int) -> bool:
        return self.node_of(global_id) not in self._dead_hosts

    def alive_hosts(self) -> List[int]:
        return [h for h in range(self.num_nodes) if h not in self._dead_hosts]

    def fail_host(self, host: int) -> List[int]:
        """Mark a host dead; returns the owners whose allocations touched
        it.  Their ``Cluster`` entries are NOT freed here — detection and
        re-placement are the recovery path's job (runner.recover), and a
        half-freed cluster would hide exactly the stale-allocation bugs
        the fault tests exist to catch."""
        assert 0 <= host < self.num_nodes, host
        self._dead_hosts.add(host)
        dead = set(self.host_devices(host))
        return sorted(owner for owner, ids in self._allocations.items()
                      if dead & set(ids))

    def restore_host(self, host: int) -> None:
        self._dead_hosts.discard(host)


def maybe_init_jax_distributed() -> bool:
    """Initialize ``jax.distributed`` when a coordinator is configured.

    Reads ``REPRO_COORD_ADDR`` (host:port), ``REPRO_NUM_PROCESSES``, and
    ``REPRO_PROCESS_ID``; returns True when multi-process JAX came up.
    Without a coordinator (the common CI/test case) this is a no-op and
    the process-local devices — possibly faked via ``launch.dryrun`` —
    stand in for the fleet.
    """
    addr = os.environ.get("REPRO_COORD_ADDR")
    if not addr:
        return False
    import jax

    jax.distributed.initialize(
        coordinator_address=addr,
        num_processes=int(os.environ.get("REPRO_NUM_PROCESSES", "1")),
        process_id=int(os.environ.get("REPRO_PROCESS_ID", "0")),
    )
    return True


def cluster_from_env(default_hosts: int = 1,
                     default_devices: int = 8,
                     *, hosts: Optional[int] = None,
                     devices_per_host: Optional[int] = None
                     ) -> SimulatedCluster:
    """Build a SimulatedCluster from the dry-run topology knobs.

    Explicit arguments win over ``REPRO_DRYRUN_HOSTS`` /
    ``REPRO_DRYRUN_DEVICES``, which win over the defaults.
    """
    n = hosts if hosts is not None else int(
        os.environ.get("REPRO_DRYRUN_HOSTS", default_hosts))
    m = devices_per_host if devices_per_host is not None else int(
        os.environ.get("REPRO_DRYRUN_DEVICES", default_devices))
    assert n >= 1 and m >= 1, (n, m)
    return SimulatedCluster(num_nodes=n, devices_per_node=m)
