"""Data pipeline: synthetic tokenized math-style prompts + batching.

The paper trains on AReaL-boba math data; offline we generate a synthetic
arithmetic-reasoning dataset with a *verifiable* answer so the rule-based
reward (±5, §5.1) is exact.  Token space: 0..9 digits, ops, and control
tokens.  This gives the end-to-end example a real learnable signal.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import numpy as np

# token vocabulary
PAD, BOS, EOS, EQ, PLUS, TIMES, ANS = 0, 1, 2, 3, 4, 5, 6
DIGIT0 = 7  # digits 0..9 -> tokens 7..16
VOCAB = 17


def encode_digits(n: int) -> List[int]:
    return [DIGIT0 + int(c) for c in str(n)]


def decode_digits(toks) -> int:
    ds = [t - DIGIT0 for t in toks if DIGIT0 <= t < DIGIT0 + 10]
    if not ds:
        return -1
    return int("".join(str(d) for d in ds))


@dataclasses.dataclass
class MathTask:
    prompt: List[int]
    answer: int


def sample_task(rng: np.random.Generator, max_operand: int = 9,
                add_only: bool = False) -> MathTask:
    a = int(rng.integers(0, max_operand + 1))
    b = int(rng.integers(0, max_operand + 1))
    op = 0 if add_only else int(rng.integers(0, 2))
    prompt = [BOS] + encode_digits(a) + [PLUS if op == 0 else TIMES] \
        + encode_digits(b) + [EQ]
    ans = a + b if op == 0 else a * b
    return MathTask(prompt=prompt, answer=ans)


class PromptDataset:
    """Infinite sampler of padded prompt batches."""

    def __init__(self, batch_size: int, prompt_len: int = 8,
                 max_operand: int = 9, seed: int = 0,
                 add_only: bool = False):
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.max_operand = max_operand
        self.add_only = add_only
        self.rng = np.random.default_rng(seed)

    def next_batch(self) -> Dict[str, np.ndarray]:
        toks = np.full((self.batch_size, self.prompt_len), PAD, np.int32)
        answers = np.zeros((self.batch_size,), np.int32)
        lens = np.zeros((self.batch_size,), np.int32)
        for i in range(self.batch_size):
            t = sample_task(self.rng, self.max_operand,
                            self.add_only)
            L = min(len(t.prompt), self.prompt_len)
            # left-pad so prompts end at the same position
            toks[i, self.prompt_len - L:] = t.prompt[:L]
            answers[i] = t.answer
            lens[i] = L
        return {"prompt_tokens": toks, "answers": answers,
                "prompt_lens": lens}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
