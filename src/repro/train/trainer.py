"""Training / inference / serving step builders.

``make_train_step`` produces the pjit-able RL policy-gradient step
(GRPO/PPO-style clipped surrogate with token-level loss, per the paper's
§5.1 modifications); ``make_prefill_step`` is the *inference* worker
(logprob recompute); ``make_serve_step`` is the decode worker.

These are the compute bodies that the M2Flow workers (repro.core) invoke —
the system schedules *around* them without touching their semantics.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import token_logprobs
from repro.train.optimizer import AdamWConfig, AdamWState, adamw_update, init_adamw

Batch = Dict[str, jax.Array]


class TrainHParams(NamedTuple):
    optimizer: AdamWConfig = AdamWConfig()
    n_microbatches: int = 1
    remat: bool = False
    compute_dtype: Any = jnp.float32
    # PPO/GRPO clipping
    clip_eps_low: float = 0.2
    clip_eps_high: float = 0.2
    kl_coef: float = 0.0
    entropy_coef: float = 0.0
    # value-function loss weight (PPO critic head; 0 disables)
    value_coef: float = 0.0
    # PartitionSpec for the residual stream (sequence parallelism); None off
    act_spec: Any = None
    # PartitionSpec pytree for grads/accumulator (pins the microbatch-scan
    # carry sharding — otherwise XLA replicates embed grads); None off
    grad_specs: Any = None
    # dtype of the gradient accumulator across microbatches; f32 default,
    # bf16 halves the largest training temp (tradeoff logged in §Perf)
    accum_dtype: Any = jnp.float32


# ---------------------------------------------------------------------------
# RL policy loss (token-level, DAPO-style averaging)
# ---------------------------------------------------------------------------
def policy_loss(
    cfg: ModelConfig,
    hp: TrainHParams,
    params: Any,
    batch: Batch,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Clipped-surrogate policy gradient on response tokens.

    batch:
      tokens        (B, S) int32 — prompt + response
      old_logprobs  (B, S) f32   — behaviour logprobs, aligned so entry t
                                   scores tokens[t] (entry 0 unused)
      advantages    (B, S) f32
      loss_mask     (B, S) f32   — 1 on response tokens
      (+ image_embeds / frame_embeds for vlm / encdec archs)
    """
    extra = {}
    for k in ("image_embeds", "frame_embeds"):
        if k in batch:
            extra[k] = batch[k]
    logits, aux = M.forward(
        params, cfg, batch["tokens"], extra or None, remat=hp.remat,
        act_spec=hp.act_spec,
    )
    # logits[t] predicts tokens[t+1]
    lp = token_logprobs(logits[:, :-1], batch["tokens"][:, 1:],
                        cfg.vocab_size)  # (B, S-1)
    old_lp = batch["old_logprobs"][:, 1:]
    adv = batch["advantages"][:, 1:]
    mask = batch["loss_mask"][:, 1:].astype(jnp.float32)

    log_ratio = lp - old_lp
    ratio = jnp.exp(log_ratio)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - hp.clip_eps_low, 1.0 + hp.clip_eps_high) * adv
    pg = -jnp.minimum(unclipped, clipped)

    # token-level averaging (DAPO): sum over all tokens / total token count,
    # so long responses do not dominate per-sequence averages.
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(pg * mask) / denom

    metrics = {
        "pg_loss": loss,
        "aux_loss": aux,
        "ratio_mean": jnp.sum(ratio * mask) / denom,
        "approx_kl": jnp.sum((ratio - 1.0 - log_ratio) * mask) / denom,
        "clip_frac": jnp.sum(
            (jnp.abs(ratio - 1.0) > hp.clip_eps_high).astype(jnp.float32) * mask
        ) / denom,
    }
    if hp.entropy_coef > 0:
        lg = logits[:, :-1].astype(jnp.float32)
        V = lg.shape[-1]
        lg = jnp.where(jnp.arange(V) < cfg.vocab_size, lg, -1e30)
        logp = jax.nn.log_softmax(lg, axis=-1)
        ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)  # (B, S-1)
        ent_mean = jnp.sum(ent * mask) / denom
        loss = loss - hp.entropy_coef * ent_mean
        metrics["entropy"] = ent_mean
    if hp.kl_coef > 0 and "ref_logprobs" in batch:
        ref = batch["ref_logprobs"][:, 1:]
        # k3 estimator (Schulman): e^(ref-lp) - (ref-lp) - 1
        d = ref - lp
        kl = jnp.sum((jnp.exp(d) - d - 1.0) * mask) / denom
        loss = loss + hp.kl_coef * kl
        metrics["kl_ref"] = kl
    loss = loss + aux
    metrics["loss"] = loss
    return loss, metrics


def lm_loss(cfg: ModelConfig, hp: TrainHParams, params: Any,
            batch: Batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Plain next-token cross-entropy (used for supervised warm-up/tests)."""
    logits, aux = M.forward(params, cfg, batch["tokens"], remat=hp.remat)
    lp = token_logprobs(logits[:, :-1], batch["tokens"][:, 1:],
                        cfg.vocab_size)
    mask = batch.get("loss_mask", jnp.ones_like(lp))[:, 1:] if "loss_mask" in batch \
        else jnp.ones_like(lp)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = -jnp.sum(lp * mask) / denom + aux
    return loss, {"loss": loss, "ce": loss - aux}


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, hp: TrainHParams, loss_fn=policy_loss):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Gradient accumulation: the global batch is split into n_microbatches
    chunks scanned sequentially (grads averaged), bounding activation
    memory at one microbatch.
    """

    def grads_of(params, mb: Batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, hp, p, mb), has_aux=True
        )(params)

    def pin(grads):
        if hp.grad_specs is None:
            return grads
        from jax.sharding import PartitionSpec
        from repro.utils.sharding import shard_hint
        return jax.tree_util.tree_map(
            lambda g, sp: shard_hint(g, sp), grads, hp.grad_specs,
            is_leaf=lambda x: isinstance(x, PartitionSpec))

    def train_step(params, opt_state: AdamWState, batch: Batch):
        nm = hp.n_microbatches
        if nm <= 1:
            (loss, metrics), grads = grads_of(params, batch)
            grads = pin(grads)
        else:
            def reshape(x):
                return x.reshape((nm, x.shape[0] // nm) + x.shape[1:])
            mbs = jax.tree_util.tree_map(reshape, batch)

            def body(acc, mb):
                (l, m), g = grads_of(params, mb)
                acc_g, acc_l = acc
                g = pin(jax.tree_util.tree_map(
                    lambda x: x.astype(hp.accum_dtype), g))
                acc_g = pin(jax.tree_util.tree_map(jnp.add, acc_g, g))
                return (acc_g, acc_l + l), m

            zero = pin(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, hp.accum_dtype), params
            ))
            (gsum, lsum), ms = jax.lax.scan(body, (zero, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / nm, gsum)
            loss = lsum / nm
            metrics = jax.tree_util.tree_map(lambda x: x[-1], ms)
        params, opt_state, opt_metrics = adamw_update(
            hp.optimizer, params, grads, opt_state
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, hp: Optional[TrainHParams] = None):
    """Inference worker: recompute per-token logprobs for a rollout batch."""
    hp = hp or TrainHParams()

    def prefill_step(params, batch: Batch) -> jax.Array:
        extra = {}
        for k in ("image_embeds", "frame_embeds"):
            if k in batch:
                extra[k] = batch[k]
        logits, _ = M.forward(params, cfg, batch["tokens"], extra or None,
                              remat=hp.remat, act_spec=hp.act_spec)
        lp = token_logprobs(logits[:, :-1], batch["tokens"][:, 1:],
                            cfg.vocab_size)
        # align: entry t scores tokens[t]; entry 0 zero
        return jnp.pad(lp, ((0, 0), (1, 0)))

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, unroll: int = 1):
    """Decode worker: ONE new token against the standing cache."""

    def serve_step(params, token: jax.Array, state: M.DecodeState,
                   pos: jax.Array):
        logits, state = M.decode_step(params, cfg, token, state, pos,
                                      unroll=unroll)
        return logits, state

    return serve_step


def init_train_state(key, cfg: ModelConfig, dtype=jnp.float32):
    params = M.init_model(key, cfg, dtype)
    return params, init_adamw(params)
