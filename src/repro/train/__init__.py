from repro.train.optimizer import (  # noqa: F401
    AdamWConfig,
    AdamWState,
    adamw_update,
    init_adamw,
)
from repro.train.trainer import (  # noqa: F401
    TrainHParams,
    init_train_state,
    lm_loss,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    policy_loss,
)
