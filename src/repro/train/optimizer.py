"""Optimizers from scratch (no optax): AdamW + global-norm clip + schedules.

The optimizer state is a plain pytree mirroring the params, so it inherits
whatever sharding the params carry (FSDP-style 2D sharding ⇒ the moments
are automatically ZeRO-sharded).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.utils.treeutil import global_norm

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array  # scalar int32
    mu: Params
    nu: Params


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    warmup_steps: int = 0
    total_steps: int = 0  # 0 = constant lr after warmup
    min_lr_frac: float = 0.1


def init_adamw(params: Params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree_util.tree_map(jnp.copy, zeros))


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.warmup_steps > 0:
        warm = jnp.minimum(1.0, (step + 1.0) / cfg.warmup_steps)
    else:
        warm = 1.0
    if cfg.total_steps > 0:
        frac = jnp.clip(
            (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        decay = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    else:
        decay = 1.0
    return lr * warm * decay


def clip_by_global_norm(grads: Params, max_norm: float) -> Tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def _is_matrix(p) -> bool:
    return p.ndim >= 2


def adamw_update(
    cfg: AdamWConfig,
    params: Params,
    grads: Params,
    state: AdamWState,
) -> Tuple[Params, AdamWState, Dict[str, jax.Array]]:
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = schedule_lr(cfg, state.step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and _is_matrix(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    newp = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    newm = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    newv = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return newp, AdamWState(step=step, mu=newm, nu=newv), metrics


# ---------------------------------------------------------------------------
# SGD (used by tests as a simple reference and for the critic warm start)
# ---------------------------------------------------------------------------
class SGDState(NamedTuple):
    step: jax.Array


def init_sgd(params: Params) -> SGDState:
    return SGDState(step=jnp.zeros((), jnp.int32))


def sgd_update(lr: float, params: Params, grads: Params,
               state: SGDState) -> Tuple[Params, SGDState, Dict[str, jax.Array]]:
    newp = jax.tree_util.tree_map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    return newp, SGDState(step=state.step + 1), {"grad_norm": global_norm(grads)}
