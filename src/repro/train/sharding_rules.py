"""Parameter/batch sharding rules for the production meshes.

Baseline scheme (see DESIGN.md §5 and EXPERIMENTS.md §Perf for iterations):
  * TP  ("model" axis): attention heads / d_ff / expert dim / vocab
  * FSDP ("data" axis): d_model-sized dims of every weight — weights live
    sharded 256-way and are all-gathered per layer inside the scan (XLA
    GSPMD inserts the gathers), ZeRO-sharding the optimizer moments for
    free since they mirror param sharding.
  * batch over ("pod", "data") — pods are pure data-parallel replicas of
    the weight sharding (HSDP), so weight all-gathers never cross the
    pod axis; only the gradient all-reduce does.

Every rule goes through ``spec_for`` which drops any axis that does not
divide (24 heads on a 16-way axis ⇒ replicated heads, d_ff still sharded).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.utils.sharding import DATA, MODEL, batch_axes, maybe_axis, spec_for
from repro.utils.treeutil import map_with_path

# (suffix, base_rank, axes) — first match wins, most specific first.
# base_rank is the unstacked rank; stacked leading layer dims get None.
_RULES: Sequence[Tuple[str, int, Tuple]] = (
    ("/embed/tokens", 2, (MODEL, DATA)),
    ("/embed/unembed", 2, (DATA, MODEL)),
    ("/attn/wq", 3, (DATA, MODEL, None)),
    ("/attn/wk", 3, (DATA, MODEL, None)),
    ("/attn/wv", 3, (DATA, MODEL, None)),
    ("/attn/wo", 3, (MODEL, None, DATA)),
    ("/xattn/wq", 3, (DATA, MODEL, None)),
    ("/xattn/wk", 3, (DATA, MODEL, None)),
    ("/xattn/wv", 3, (DATA, MODEL, None)),
    ("/xattn/wo", 3, (MODEL, None, DATA)),
    ("/mlp/gate", 2, (DATA, MODEL)),
    ("/mlp/up", 2, (DATA, MODEL)),
    ("/mlp/down", 2, (MODEL, DATA)),
    ("/shared/gate", 2, (DATA, MODEL)),
    ("/shared/up", 2, (DATA, MODEL)),
    ("/shared/down", 2, (MODEL, DATA)),
    ("/moe/router", 2, (DATA, None)),
    ("/mixer/in_proj", 2, (DATA, MODEL)),
    ("/mixer/out_proj", 2, (MODEL, DATA)),
    ("/mixer/conv_w", 2, (None, MODEL)),
    ("/mixer/conv_b", 1, (MODEL,)),
)

_MOE_EXPERT_RULES = {
    # when num_experts % model_axis == 0 -> expert parallelism
    "/moe/gate": ((MODEL, DATA, None), (None, DATA, MODEL)),
    "/moe/up": ((MODEL, DATA, None), (None, DATA, MODEL)),
    "/moe/down": ((MODEL, None, DATA), (None, MODEL, DATA)),
}


def _spec_for_leaf(mesh: Mesh, cfg: ModelConfig, path: str, leaf) -> P:
    shape = tuple(leaf.shape)
    rank = len(shape)
    for suffix, base_rank, axes in _RULES:
        if path.endswith(suffix):
            pad = (None,) * (rank - base_rank)
            return spec_for(mesh, shape, pad + tuple(axes))
    for suffix, (ep_axes, tp_axes) in _MOE_EXPERT_RULES.items():
        if path.endswith(suffix):
            assert cfg.moe is not None
            msize = mesh.shape.get(MODEL, 1)
            axes = ep_axes if cfg.moe.num_experts % msize == 0 else tp_axes
            pad = (None,) * (rank - 3)
            return spec_for(mesh, shape, pad + tuple(axes))
    # biases, norms, A_log, D, gates ... -> replicated
    return P()


def param_specs(mesh: Mesh, cfg: ModelConfig, params: Any) -> Any:
    """PartitionSpec pytree mirroring ``params`` (arrays or SDS)."""
    return map_with_path(lambda p, leaf: _spec_for_leaf(mesh, cfg, p, leaf), params)


def param_shardings(mesh: Mesh, cfg: ModelConfig, params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        param_specs(mesh, cfg, params),
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------
def batch_spec(mesh: Mesh, batch_size: int) -> P:
    return P(maybe_axis(mesh, batch_size, batch_axes(mesh)))


def array_batch_specs(mesh: Mesh, tree: Any) -> Any:
    """Shard dim0 (batch) of every array in a batch pytree."""

    def leaf(x):
        b = x.shape[0] if x.ndim else 1
        ax = maybe_axis(mesh, b, batch_axes(mesh))
        return P(*((ax,) + (None,) * (x.ndim - 1)))

    return jax.tree_util.tree_map(leaf, tree)


def decode_state_specs(mesh: Mesh, cfg: ModelConfig, state: Any) -> Any:
    """KV/SSM cache shardings: batch over ("pod","data"); "model" goes to
    kv-heads when divisible, otherwise to the cache *sequence* dim (W) —
    sequence-parallel decode attention (XLA reduces softmax/PV across the
    model axis instead of replicating a multi-GB cache).

    Cache layouts (see models.model):
      kv.k/v        (L..., B, W, KV, hd)
      kv.positions  (L..., B, W)
      ssm.ssm       (L..., B, H, P, N)
      ssm.conv      (L..., B, w-1, ch)
      cross k/v     (L, B, S_src, KV, hd)
    """
    bax = batch_axes(mesh)
    msize = mesh.shape.get(MODEL, 1)

    def kv_axes(shape):
        # (..., B, W, KV, hd): prefer heads on model, else W on model
        lead = len(shape) - 4
        B, W, KV, hd = shape[-4:]
        if KV % msize == 0:
            return (None,) * lead + (bax, None, MODEL, None), "heads"
        if W % msize == 0:
            return (None,) * lead + (bax, MODEL, None, None), "seq"
        return (None,) * lead + (bax, None, None, None), "none"

    # determine once (from the main kv cache if present) whether positions
    # must be seq-sharded to match k/v
    def leaf(path: str, x) -> P:
        shape = tuple(x.shape)
        rank = len(shape)
        if path.endswith("/positions"):
            # (..., B, W) — shard W on model iff k/v shard W
            lead = rank - 2
            B, W = shape[-2:]
            kv_mode = "seq" if (cfg.num_kv_heads % msize != 0 and W % msize == 0)                 else "none"
            ax_w = MODEL if kv_mode == "seq" else None
            axes = (None,) * lead + (bax, ax_w)
            return spec_for(mesh, shape, axes)
        if path.endswith("/k") or path.endswith("/v") or "cross_kv" in path:
            axes, _ = kv_axes(shape)
            return spec_for(mesh, shape, axes)
        if path.endswith("/ssm"):
            lead = rank - 4
            axes = (None,) * lead + (bax, MODEL, None, None)
            return spec_for(mesh, shape, axes)
        if path.endswith("/conv"):
            lead = rank - 3
            axes = (None,) * lead + (bax, None, MODEL)
            return spec_for(mesh, shape, axes)
        return P()

    return map_with_path(leaf, state)


def decode_state_shardings(mesh: Mesh, cfg: ModelConfig, state: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        decode_state_specs(mesh, cfg, state),
        is_leaf=lambda x: isinstance(x, P),
    )
