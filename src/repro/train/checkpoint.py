"""Checkpointing: pytree <-> directory of .npz + msgpack metadata.

No orbax dependency; works for params + optimizer state + arbitrary
metadata.  Layout:
    <dir>/manifest.msgpack   {step, treedef_repr, keys}
    <dir>/arrays.npz         flat arrays keyed by path
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import msgpack
import numpy as np

from repro.utils.treeutil import tree_paths


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = tree_paths(tree)
    return {k: np.asarray(v) for k, v in flat.items()}


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}", v) for k, v in node.items()}
        if hasattr(node, "_fields"):
            return type(node)(**{
                f: rec(f"{prefix}/{f}", getattr(node, f)) for f in node._fields
            })
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(rec(f"{prefix}/{i}", v) for i, v in enumerate(node))
        arr = flat[prefix]
        return jax.numpy.asarray(arr)

    return rec("", template)


def save_checkpoint(path: str, tree: Any, step: int = 0,
                    metadata: Optional[Dict[str, Any]] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **{k.replace("/", "|"): v
                                                  for k, v in flat.items()})
    manifest = {"step": step, "keys": list(flat.keys()),
                "metadata": metadata or {}}
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))


def load_checkpoint(path: str, template: Any) -> Tuple[Any, int, Dict]:
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k.replace("|", "/"): z[k] for k in z.files}
    tree = _unflatten_into(template, flat)
    return tree, manifest["step"], manifest.get("metadata", {})
