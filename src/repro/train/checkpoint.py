"""Checkpointing: pytree <-> directory of .npz + msgpack metadata.

No orbax dependency; works for params + optimizer state + arbitrary
metadata.  Layout:
    <dir>/manifest.msgpack   {step, treedef_repr, keys}
    <dir>/arrays.npz         flat arrays keyed by path
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import msgpack
import numpy as np

from repro.utils.treeutil import tree_paths


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = tree_paths(tree)
    return {k: np.asarray(v) for k, v in flat.items()}


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}/{k}", v) for k, v in node.items()}
        if hasattr(node, "_fields"):
            return type(node)(**{
                f: rec(f"{prefix}/{f}", getattr(node, f)) for f in node._fields
            })
        if isinstance(node, (list, tuple)):
            t = type(node)
            return t(rec(f"{prefix}/{i}", v) for i, v in enumerate(node))
        arr = flat[prefix]
        return jax.numpy.asarray(arr)

    return rec("", template)


def _escape_key(k: str) -> str:
    """Invertible path-separator escaping (JSON-pointer style): ``~`` is
    the escape char, so a literal ``~`` becomes ``~0`` before ``/``
    becomes ``~1``.  The previous scheme — ``k.replace("/", "|")``
    inverted by ``k.replace("|", "/")`` — silently corrupted any state
    key containing a literal ``|`` on load."""
    return k.replace("~", "~0").replace("/", "~1")


def _unescape_key(k: str) -> str:
    # decode ~1 before ~0 (the JSON-pointer order): an original "~1"
    # escapes to "~01", which must NOT decode its tail as a separator
    return k.replace("~1", "/").replace("~0", "~")


def save_checkpoint(path: str, tree: Any, step: int = 0,
                    metadata: Optional[Dict[str, Any]] = None) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **{_escape_key(k): v
                                                  for k, v in flat.items()})
    manifest = {"step": step, "keys": list(flat.keys()),
                "metadata": metadata or {}}
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))


def checkpoint_exists(path: str) -> bool:
    return (os.path.exists(os.path.join(path, "manifest.msgpack"))
            and os.path.exists(os.path.join(path, "arrays.npz")))


def load_checkpoint(path: str, template: Any) -> Tuple[Any, int, Dict]:
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    with np.load(os.path.join(path, "arrays.npz")) as z:
        files = set(z.files)
        flat: Dict[str, np.ndarray] = {}
        for k in manifest["keys"]:
            esc = _escape_key(k)
            if esc in files:
                flat[k] = z[esc]
            elif k.replace("/", "|") in files:  # legacy "|" checkpoints
                flat[k] = z[k.replace("/", "|")]
            else:
                raise KeyError(f"checkpoint {path} is missing array {k!r}")
    tree = _unflatten_into(template, flat)
    return tree, manifest["step"], manifest.get("metadata", {})
