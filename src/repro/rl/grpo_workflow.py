"""End-to-end GRPO workflow runner on the M2Flow runtime (Fig. 5b).

The *logical* workflow is the plain imperative loop of the paper:

    for batch in data:
        update_rollout_weights()
        rollout.generate(data_ch -> rollout_ch)
        inference.compute_logprobs(rollout_ch -> scored_ch)
        reward.score(...)
        actor.train(scored_ch).wait()

M2Flow then decides where/when each worker actually runs: the shared
:class:`~repro.rl.runner.WorkflowRunner` base executes one *profiling
iteration* (timing each worker at two granularities), asks the Scheduler
for a plan (or a forced collocated/disaggregated mode), and runs the
remaining iterations through the Execution Flow Manager under that plan
— which is *binding*: ``Controller.execute`` rebinds every worker's
device slice to the plan's placement, Temporal cuts go through the
managed ContextSwitcher, and weight sync is a measured resharding
data-plane operation.  No change to the workflow code.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import Cluster, FlowGraph, SchedulerConfig
from repro.obs import metrics as _metrics
from repro.rl.runner import WorkflowRunner
from repro.rl.workers import (
    ActorWorker,
    InferenceWorker,
    RewardWorker,
    RolloutWorker,
)
from repro.train.data import PromptDataset
from repro.train.trainer import TrainHParams

WORKFLOW_ORDER = ("rollout", "inference", "reward", "actor")


def grpo_graph() -> FlowGraph:
    """The GRPO chain graph (module-level so tooling — flowlint,
    benchmarks — can build it without constructing a runner)."""
    graph = FlowGraph()
    prev = None
    for name in WORKFLOW_ORDER:
        graph.add_worker(name)
        if prev is not None:
            graph.add_edge(prev, name, channel=f"{prev}->{name}")
        prev = name
    return graph


@dataclass
class GRPOConfig:
    batch_size: int = 32
    group_size: int = 4
    prompt_len: int = 8
    max_new_tokens: int = 8
    temperature: float = 1.0
    iterations: int = 10
    mode: str = "auto"  # auto | collocated | disaggregated
    seed: int = 0
    profile_batches: tuple = (8, 32)
    # Bounded-staleness off-policy asynchrony: rollouts for iteration i may
    # be generated with parameters up to `async_depth` (K) versions stale
    # while training runs concurrently; samples are importance-corrected
    # per token (rl.advantage.staleness_importance_weights).  K = 0 is
    # fully synchronous on-policy execution.  K >= 1 supersedes `mode`
    # (the async horizon plan replaces the per-iteration plan).
    async_depth: int = 0
    # truncation bound for the per-token importance ratios
    staleness_clip: float = 2.0
    # apply the correction (disable to get raw clipped-PPO staleness
    # handling, the pre-correction behaviour)
    staleness_correction: bool = True
    # legacy alias (AReaL-style 1-step asynchrony): maps to async_depth=1
    async_offpolicy: bool = False

    def __post_init__(self):
        if self.async_offpolicy and self.async_depth == 0:
            self.async_depth = 1


@dataclass
class IterationStats:
    iteration: int
    wall_time: float
    mean_reward: float
    accuracy: float
    metrics: Dict[str, float] = field(default_factory=dict)


class GRPORunner(WorkflowRunner):
    """GRPO over the shared WorkflowRunner (binding-placement) loop."""

    weight_sync_workers = ("rollout", "inference")

    def __init__(self, cfg: ModelConfig, rl: GRPOConfig,
                 hp: Optional[TrainHParams] = None,
                 cluster: Optional[Cluster] = None, **kw):
        self.model_cfg = cfg
        self.rl = rl
        self.hp = hp or TrainHParams()
        assert rl.batch_size % rl.group_size == 0, (
            f"batch_size={rl.batch_size} must be a multiple of "
            f"group_size={rl.group_size} (whole GRPO groups)")
        n_queries = rl.batch_size // rl.group_size
        self.data = PromptDataset(n_queries, prompt_len=rl.prompt_len,
                                  seed=rl.seed)
        super().__init__(iterations=rl.iterations,
                         batch_size=rl.batch_size, mode=rl.mode,
                         profile_batches=rl.profile_batches,
                         cluster=cluster, **kw)

    def reset_stream(self) -> None:
        # recovery determinism: a rebuilt run must see the same prompt
        # sequence a fresh runner would
        self.data = PromptDataset(self.rl.batch_size // self.rl.group_size,
                                  prompt_len=self.rl.prompt_len,
                                  seed=self.rl.seed)

    # ------------------------------------------------------------------
    # declarative surface
    # ------------------------------------------------------------------
    def build_workers(self) -> Dict[str, Any]:
        cfg, rl = self.model_cfg, self.rl
        self.actor = ActorWorker(
            "actor/0", cfg=cfg, hp=self.hp, seed=rl.seed,
            devices=self.cluster.allocate("actor", 4))
        self.rollout = RolloutWorker(
            "rollout/0", cfg=cfg, max_new_tokens=rl.max_new_tokens,
            temperature=rl.temperature, seed=rl.seed,
            devices=self.cluster.allocate("rollout", 4))
        self.inference = InferenceWorker(
            "inference/0", cfg=cfg,
            devices=self.cluster.allocate("inference", 2))
        self.reward = RewardWorker(
            "reward/0", prompt_len=rl.prompt_len, group_size=rl.group_size)
        return {"rollout": self.rollout, "inference": self.inference,
                "reward": self.reward, "actor": self.actor}

    def build_task_fns(self) -> Dict[str, Any]:
        return {
            "rollout": lambda w, c: w.generate(c),
            "inference": lambda w, c: w.compute_logprobs(c),
            "reward": lambda w, c: w.score(c),
            "actor": lambda w, c: w.train(c),
        }

    def build_graph(self) -> FlowGraph:
        return grpo_graph()

    def make_batch(self) -> Dict[str, np.ndarray]:
        return self._expand_groups(self.data.next_batch())

    def scheduler_config(self) -> SchedulerConfig:
        return SchedulerConfig(
            total_batch=self.rl.batch_size,
            granularity_divisors=(1, 2, 4),
            device_quantum=2,
            # never pipeline below a GRPO group: a chunk that splits a
            # group degrades grpo_advantages to per-sequence groups of 1
            # (identically zero advantage — no learning signal)
            chunk_multiple=self.rl.group_size,
        )

    # ------------------------------------------------------------------
    def _expand_groups(self, batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Each query is repeated group_size times (GRPO sampling)."""
        g = self.rl.group_size
        return {k: np.repeat(v, g, axis=0) for k, v in batch.items()}

    def plan_execution(self) -> None:
        self.controller.scheduler_cfg = self.scheduler_config()
        if self.rl.async_depth > 0:
            # Horizon plan with the configured staleness bound.  NOTE:
            # async_depth supersedes rl.mode; the AsyncPipelineDriver
            # realizes the cross-iteration overlap directly on the
            # workers while the plan's placement column is still made
            # binding (bind_placement) before the horizon starts.
            self.plan = self.controller.plan_async(
                self.graph(), total_batch=self.rl.batch_size,
                iterations=self.rl.iterations,
                depths=[self.rl.async_depth])
        else:
            self.plan = self.controller.plan(
                self.graph(), total_batch=self.rl.batch_size,
                mode=self.mode)

    # ------------------------------------------------------------------
    def _record_stats(self, it: int, wall: float, out) -> IterationStats:
        rewards = out.get("rewards", np.zeros(1))
        acc = float((rewards > 0).mean())
        st = IterationStats(
            iteration=it, wall_time=wall,
            mean_reward=float(rewards.mean()), accuracy=acc,
            metrics=self.actor.metrics_history[-1]
            if self.actor.metrics_history else {})
        self.stats.append(st)
        reg = _metrics.active()
        if reg is not None and wall > 0:
            tok = self.rl.batch_size * (self.rl.prompt_len
                                        + self.rl.max_new_tokens)
            reg.gauge("grpo/tokens_per_s").set(tok / wall)
            reg.gauge("grpo/mean_reward").set(st.mean_reward)
        return st

    def log_iteration(self, st: IterationStats) -> None:
        print(f"iter {st.iteration:3d}  wall={st.wall_time:6.2f}s "
              f"reward={st.mean_reward:+6.2f} acc={st.accuracy:5.2f} "
              f"loss={st.metrics.get('loss', float('nan')):+.4f}")

    # ------------------------------------------------------------------
    # Bounded-staleness off-policy loop (async_depth = K >= 1)
    # ------------------------------------------------------------------
    def _run_async_horizon(self, verbose: bool) -> None:
        """Drive the whole horizon through the AsyncPipelineDriver:
        generation keeps producing rollouts under parameter version v while
        the trainer advances to v+1, …; the queue's staleness bound K and
        the per-token importance correction keep the update sound.

        Thread discipline: the trainer publishes an immutable
        ``(version, params)`` pair after each update; the producer thread
        is the ONLY writer of the rollout/inference workers' registered
        state, and the consumer re-scores stale samples with explicit
        params (no shared-state mutation) — so version tags always match
        the weights a rollout was actually generated with."""
        from repro.core.pipeline import AsyncPipelineDriver
        from repro.rl.advantage import staleness_importance_weights

        # the async plan's placement is binding too
        self.controller.bind_placement(self.plan, self.workers)

        # atomically-swapped (version, params) snapshot; version counts
        # completed trainer updates and always matches the params beside it
        self._published = (0, self.actor.params())
        t_prev = time.perf_counter()

        def sync(_gate_version: int) -> int:
            version, params = self._published
            # measured resharding sync; the paged engine applies it in
            # flight at its next step boundary and the version tag rides
            # along so per-request weight_versions match the queue tag
            self._sync_weights(params=params, version=version)
            return version  # tag = the version actually pulled

        def produce(i: int, version: int):
            # rollout -> behaviour logprobs -> reward, all at `version`
            batch = self.make_batch()
            chunk = self.task_fns["rollout"](self.rollout, batch)
            chunk = self.task_fns["inference"](self.inference, chunk)
            chunk = self.task_fns["reward"](self.reward, chunk)
            return chunk

        def consume(item):
            nonlocal t_prev
            chunk = item.data
            version = self._published[0]
            staleness = version - item.version
            if staleness > 0 and self.rl.staleness_correction:
                # Re-score the stale rollout at the CURRENT parameters
                # (explicit params: the shared inference worker's state
                # belongs to the producer thread) and damp each token so
                # the loss's behavior-referenced ratio becomes a
                # TRUNCATED importance weight.  The behavior term is
                # old_logprobs — the same prefill recompute the loss
                # references — so the damper cancels token-for-token.
                chunk = self.inference.compute_logprobs(
                    chunk, key="target_logprobs",
                    params=self._published[1])
                rho = staleness_importance_weights(
                    chunk["old_logprobs"], chunk["target_logprobs"],
                    chunk["loss_mask"], staleness=staleness,
                    clip_ratio=self.rl.staleness_clip)
                chunk["advantages"] = chunk["advantages"] * rho
            out = self.task_fns["actor"](self.actor, chunk)
            self._published = (version + 1, self.actor.params())
            now = time.perf_counter()
            st = self._record_stats(version, now - t_prev, out)
            t_prev = now
            if verbose:
                print(f"iter {st.iteration:3d}  wall={st.wall_time:6.2f}s "
                      f"stale={staleness} reward={st.mean_reward:+6.2f} "
                      f"acc={st.accuracy:5.2f}")
            return out

        driver = AsyncPipelineDriver(
            produce_fn=produce, consume_fn=consume, sync_fn=sync,
            staleness_bound=self.rl.async_depth,
            name=f"grpo-async-{id(self)}")
        self._driver = driver
        driver.run(self.rl.iterations)

    def finish_async(self) -> None:  # kept for API compatibility
        pass

    def run_loop(self, verbose: bool = True) -> None:
        if self.rl.async_depth > 0:
            self._run_async_horizon(verbose)
            return
        super().run_loop(verbose)
        self.finish_async()

    def throughput(self) -> float:
        """tokens/sec over the measured iterations (paper metric)."""
        if not self.stats:
            return 0.0
        tok = self.rl.batch_size * (self.rl.prompt_len + self.rl.max_new_tokens)
        return tok * len(self.stats) / sum(s.wall_time for s in self.stats)
