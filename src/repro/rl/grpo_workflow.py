"""End-to-end GRPO workflow runner on the M2Flow runtime (Fig. 5b).

The *logical* workflow is the plain imperative loop of the paper:

    for batch in data:
        update_rollout_weights()
        rollout.generate(data_ch -> rollout_ch)
        inference.compute_logprobs(rollout_ch -> scored_ch)
        reward.score(...)
        actor.train(scored_ch).wait()

M2Flow then decides where/when each worker actually runs: the runner
first executes one *profiling iteration* (tracing the channel data flow
to extract the workflow graph, timing each worker at two granularities),
asks the Scheduler for a plan (or a forced collocated/disaggregated
mode), and runs the remaining iterations through the Execution Flow
Manager under that plan — no change to the workflow code.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import (
    Channel,
    Cluster,
    Controller,
    FlowGraph,
    Profiler,
    SchedulerConfig,
)
from repro.core.profiler import CostModel, measure_onoffload
from repro.rl.workers import (
    ActorWorker,
    InferenceWorker,
    RewardWorker,
    RolloutWorker,
)
from repro.train.data import PromptDataset
from repro.train.trainer import TrainHParams

WORKFLOW_ORDER = ("rollout", "inference", "reward", "actor")


@dataclass
class GRPOConfig:
    batch_size: int = 32
    group_size: int = 4
    prompt_len: int = 8
    max_new_tokens: int = 8
    temperature: float = 1.0
    iterations: int = 10
    mode: str = "auto"  # auto | collocated | disaggregated
    seed: int = 0
    profile_batches: tuple = (8, 32)
    # AReaL-style one-step off-policy asynchrony (paper §4): iteration i
    # rolls out with the weights of iteration i-1 while i-1's training
    # update runs concurrently; the PPO clip absorbs the staleness.
    async_offpolicy: bool = False


@dataclass
class IterationStats:
    iteration: int
    wall_time: float
    mean_reward: float
    accuracy: float
    metrics: Dict[str, float] = field(default_factory=dict)


class GRPORunner:
    """Owns the workers + data and runs the M2Flow-scheduled loop."""

    def __init__(self, cfg: ModelConfig, rl: GRPOConfig,
                 hp: Optional[TrainHParams] = None,
                 cluster: Optional[Cluster] = None):
        self.model_cfg = cfg
        self.rl = rl
        self.cluster = cluster or Cluster(num_nodes=1, devices_per_node=8)
        hp = hp or TrainHParams()
        n_queries = rl.batch_size // rl.group_size
        self.data = PromptDataset(n_queries, prompt_len=rl.prompt_len,
                                  seed=rl.seed)

        self.actor = ActorWorker("actor/0", cfg=cfg, hp=hp, seed=rl.seed,
                                 devices=self.cluster.allocate("actor", 4))
        self.rollout = RolloutWorker(
            "rollout/0", cfg=cfg, max_new_tokens=rl.max_new_tokens,
            temperature=rl.temperature, seed=rl.seed,
            devices=self.cluster.allocate("rollout", 4))
        self.inference = InferenceWorker(
            "inference/0", cfg=cfg,
            devices=self.cluster.allocate("inference", 2))
        self.reward = RewardWorker(
            "reward/0", prompt_len=rl.prompt_len, group_size=rl.group_size)

        self.workers = {"rollout": self.rollout, "inference": self.inference,
                        "reward": self.reward, "actor": self.actor}
        self.task_fns = {
            "rollout": lambda w, c: w.generate(c),
            "inference": lambda w, c: w.compute_logprobs(c),
            "reward": lambda w, c: w.score(c),
            "actor": lambda w, c: w.train(c),
        }
        self.controller = Controller(self.cluster)
        self.stats: List[IterationStats] = []
        self.plan = None

    # ------------------------------------------------------------------
    def _expand_groups(self, batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Each query is repeated group_size times (GRPO sampling)."""
        g = self.rl.group_size
        return {k: np.repeat(v, g, axis=0) for k, v in batch.items()}

    def _sync_weights(self) -> None:
        params = self.actor.params()
        self.rollout.update_weights(params)
        self.inference.update_weights(params)

    # ------------------------------------------------------------------
    # Phase 1: profiling iteration — trace graph + fit cost models
    # ------------------------------------------------------------------
    def profile(self) -> FlowGraph:
        self._sync_weights()
        prof = Profiler(warmup=1, repeats=1)
        profiles: Dict[str, CostModel] = {}
        base = self._expand_groups(self.data.next_batch())

        chain = {}
        chain["rollout"] = base
        graph = FlowGraph()
        prev = None
        for name in WORKFLOW_ORDER:
            graph.add_worker(name)
            if prev is not None:
                graph.add_edge(prev, name, channel=f"{prev}->{name}")
            prev = name

        for name in WORKFLOW_ORDER:
            w, fn = self.workers[name], self.task_fns[name]
            inp = chain[name]

            def run_at(b, w=w, fn=fn, inp=inp):
                sub = {k: v[:b] for k, v in inp.items()}
                return fn(w, sub)

            sizes = [b for b in self.rl.profile_batches
                     if b <= self.rl.batch_size] or [self.rl.batch_size]
            cm = prof.measure(name, run_at, sizes)
            out = fn(w, inp)
            nxt = WORKFLOW_ORDER[WORKFLOW_ORDER.index(name) + 1] \
                if name != WORKFLOW_ORDER[-1] else None
            if nxt:
                chain[nxt] = out
            if hasattr(w, "_state") and w.state_bytes():
                on, off = measure_onoffload(w)
                cm.onload_time, cm.offload_time = on, off
            cm.base_mem = float(w.state_bytes())
            profiles[name] = cm
        self.controller.profiles = profiles
        self.graph = graph
        return graph

    # ------------------------------------------------------------------
    def plan_execution(self) -> None:
        self.controller.scheduler_cfg = SchedulerConfig(
            total_batch=self.rl.batch_size,
            granularity_divisors=(1, 2, 4),
            device_quantum=2,
        )
        self.plan = self.controller.plan(
            self.graph, total_batch=self.rl.batch_size, mode=self.rl.mode)

    # ------------------------------------------------------------------
    def run_iteration(self, it: int) -> IterationStats:
        t0 = time.perf_counter()
        if self.rl.async_offpolicy:
            out = self._run_iteration_async()
        else:
            self._sync_weights()
            batch = self._expand_groups(self.data.next_batch())
            out = self.controller.execute(
                self.plan, self.workers, self.task_fns, batch)
        wall = time.perf_counter() - t0
        rewards = out.get("rewards", np.zeros(1))
        acc = float((rewards > 0).mean())
        st = IterationStats(
            iteration=it, wall_time=wall,
            mean_reward=float(rewards.mean()), accuracy=acc,
            metrics=self.actor.metrics_history[-1]
            if self.actor.metrics_history else {})
        self.stats.append(st)
        return st

    def _run_iteration_async(self):
        """One-step off-policy iteration: rollout(i) with stale weights
        overlaps train(i-1) running in a background thread."""
        import threading

        batch = self._expand_groups(self.data.next_batch())
        # rollout -> inference -> reward with the CURRENT (stale) weights
        chunk = self.task_fns["rollout"](self.rollout, batch)
        chunk = self.task_fns["inference"](self.inference, chunk)
        chunk = self.task_fns["reward"](self.reward, chunk)
        # wait for the previous update, then kick off this one
        prev = getattr(self, "_train_thread", None)
        if prev is not None:
            prev.join()
        result = {}

        def train():
            result.update(self.task_fns["actor"](self.actor, chunk))

        th = threading.Thread(target=train, daemon=True)
        th.start()
        self._train_thread = th
        # sync the NOW-stale-by-one weights for the next rollout
        self._sync_weights()
        return chunk

    def finish_async(self) -> None:
        th = getattr(self, "_train_thread", None)
        if th is not None:
            th.join()
            self._train_thread = None

    def run(self, verbose: bool = True) -> List[IterationStats]:
        self.profile()
        self.plan_execution()
        if verbose:
            print(self.plan.pretty())
        for it in range(self.rl.iterations):
            st = self.run_iteration(it)
            if verbose:
                print(f"iter {it:3d}  wall={st.wall_time:6.2f}s "
                      f"reward={st.mean_reward:+6.2f} acc={st.accuracy:5.2f} "
                      f"loss={st.metrics.get('loss', float('nan')):+.4f}")
        self.finish_async()
        return self.stats

    def throughput(self) -> float:
        """tokens/sec over the measured iterations (paper metric)."""
        if not self.stats:
            return 0.0
        tok = self.rl.batch_size * (self.rl.prompt_len + self.rl.max_new_tokens)
        return tok * len(self.stats) / sum(s.wall_time for s in self.stats)
