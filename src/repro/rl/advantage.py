"""Advantage estimators: GRPO group normalization, GAE, REINFORCE++.

All operate on numpy arrays host-side (they sit between workers in the
workflow, not inside the jitted steps).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def grpo_advantages(rewards: np.ndarray, group_size: int,
                    eps: float = 1e-6) -> np.ndarray:
    """Group-relative advantages (GRPO): responses to the same query form a
    group; advantage = (r - mean_group) / std_group, broadcast per token by
    the caller.  rewards: (B,) with B = n_queries * group_size, grouped
    consecutively."""
    B = rewards.shape[0]
    assert B % group_size == 0, (B, group_size)
    g = rewards.reshape(B // group_size, group_size)
    mean = g.mean(axis=1, keepdims=True)
    std = g.std(axis=1, keepdims=True)
    adv = (g - mean) / (std + eps)
    return adv.reshape(B)


def reinforce_pp_advantages(rewards: np.ndarray,
                            baseline_momentum: float = 0.9,
                            state: Optional[float] = None
                            ) -> Tuple[np.ndarray, float]:
    """REINFORCE++ style: global moving-average baseline + batch whitening."""
    b = rewards.mean() if state is None else (
        baseline_momentum * state + (1 - baseline_momentum) * rewards.mean())
    adv = rewards - b
    std = adv.std() + 1e-6
    return adv / std, float(b)


def gae_advantages(rewards: np.ndarray, values: np.ndarray,
                   dones: Optional[np.ndarray] = None, gamma: float = 0.99,
                   lam: float = 0.95, *,
                   terminated: Optional[np.ndarray] = None,
                   truncated: Optional[np.ndarray] = None,
                   terminal_values: Optional[np.ndarray] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Generalized advantage estimation over (T, B) step-major rollouts.

    values: (T+1, B) — bootstrap value appended.
    Returns (advantages (T, B), returns (T, B)).

    Episode ends come in two kinds and they bootstrap differently:

      * ``terminated`` — the MDP truly ended (goal reached, failure
        state): the future value is genuinely zero, so the TD target
        drops the ``gamma * V(s')`` bootstrap;
      * ``truncated`` — the episode was CUT (e.g. an env's ``max_steps``
        horizon): the state had remaining value, so the target keeps the
        bootstrap.  Pass ``terminal_values`` (T, B) holding
        ``V(terminal_obs)`` — the value of the episode's true final
        observation (``info["terminal_obs"]`` from the env) — because
        ``values[t+1]`` at a truncation boundary scores the *post-reset*
        observation of the next episode, not the state that was cut.

    Both kinds reset the advantage carry (no credit flows across
    episode boundaries).  Legacy positional ``dones`` treats every end
    as terminated — the timeout-as-terminal bias this signature exists
    to remove."""
    if terminated is None:
        terminated = dones if dones is not None else np.zeros_like(rewards)
    if truncated is None:
        truncated = np.zeros_like(terminated)
    T, B = rewards.shape
    adv = np.zeros((T, B), np.float32)
    last = np.zeros((B,), np.float32)
    for t in reversed(range(T)):
        v_next = values[t + 1]
        if terminal_values is not None:
            v_next = np.where(truncated[t] > 0, terminal_values[t], v_next)
        notterm = 1.0 - terminated[t]
        ends = np.clip(terminated[t] + truncated[t], 0.0, 1.0)
        delta = rewards[t] + gamma * v_next * notterm - values[t]
        last = delta + gamma * lam * (1.0 - ends) * last
        adv[t] = last
    returns = adv + values[:-1]
    return adv, returns


def broadcast_to_tokens(adv_seq: np.ndarray, loss_mask: np.ndarray
                        ) -> np.ndarray:
    """Per-sequence advantage -> per-token (B, S) masked broadcast."""
    return adv_seq[:, None].astype(np.float32) * loss_mask.astype(np.float32)


def staleness_importance_weights(behavior_logprobs: np.ndarray,
                                 target_logprobs: np.ndarray,
                                 loss_mask: np.ndarray,
                                 *, staleness: int,
                                 clip_ratio: float = 2.0) -> np.ndarray:
    """Per-token truncation dampers realizing truncated importance
    sampling for off-policy (stale) samples.

    A rollout generated under parameters ``v`` but trained at ``v + s``
    (``s`` = staleness, bounded by the AsyncQueue's K) needs the
    truncated-IS weight ``min(exp(Δ), clip_ratio)`` where
    ``Δ = logπ_target − logπ_behavior``.  The behavior-referenced PPO
    ratio in the loss ALREADY equals ``exp(Δ)`` at the start of the
    update, so multiplying advantages by the full ratio would count the
    off-policy gap twice.  This returns only the *truncation factor*

        w = min(1, clip_ratio · exp(−Δ))

    so that (loss ratio at train start) × w = min(exp(Δ), clip_ratio) —
    the RollArt/AReaL-style truncated importance weight, applied exactly
    once.  Pass the SAME behavior logprobs the loss references
    (``old_logprobs``) so the two factors cancel token-for-token.

    ``staleness == 0`` means behavior and target policy are the SAME
    parameters, so the method returns exactly 1.0 everywhere — async depth
    K = 0 reduces bit-for-bit to synchronous on-policy GRPO.

    Shapes: all (B, S); returns (B, S) float32 with 1.0 off-mask.
    """
    if staleness <= 0:
        return np.ones_like(loss_mask, dtype=np.float32)
    delta = np.clip(target_logprobs - behavior_logprobs, -20.0, 20.0)
    w = np.minimum(1.0, clip_ratio * np.exp(-delta)).astype(np.float32)
    mask = loss_mask.astype(bool)
    return np.where(mask, w, np.float32(1.0))


def whiten(x: np.ndarray, mask: Optional[np.ndarray] = None,
           eps: float = 1e-6) -> np.ndarray:
    if mask is None:
        return (x - x.mean()) / (x.std() + eps)
    m = mask.astype(bool)
    mu, sd = x[m].mean(), x[m].std()
    out = np.where(m, (x - mu) / (sd + eps), 0.0)
    return out.astype(np.float32)
