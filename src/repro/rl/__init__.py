from repro.rl.advantage import (  # noqa: F401
    gae_advantages,
    grpo_advantages,
    reinforce_pp_advantages,
    staleness_importance_weights,
    whiten,
)
from repro.rl.env import EnvConfig, VecReachEnv  # noqa: F401
from repro.rl.grpo_workflow import GRPOConfig, GRPORunner  # noqa: F401
from repro.rl.reward import math_reward  # noqa: F401
from repro.rl.workers import (  # noqa: F401
    ActorWorker,
    InferenceWorker,
    RewardWorker,
    RolloutWorker,
    SimulatorWorker,
)
from repro.rl.embodied_workflow import (  # noqa: F401
    EmbodiedAdvantageWorker,
    EmbodiedIterStats,
    EmbodiedPPOConfig,
    EmbodiedPPORunner,
)
from repro.rl.rlhf_workflow import (  # noqa: F401
    CriticWorker,
    PPOConfig,
    PPORewardWorker,
    ReferenceWorker,
    RLHFRunner,
)
from repro.rl.runner import WorkflowRunner  # noqa: F401
