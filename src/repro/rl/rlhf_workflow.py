"""Full PPO/RLHF workflow (paper Fig. 1 top-right): four models in the loop.

  actor      — trainable policy (clipped PPO with per-token values)
  critic     — trainable value model (separate backbone + value head)
  reference  — frozen copy of the initial actor (KL anchor)
  reward     — scalar scorer (rule-based here, per §5.1; a learned RM
               plugs into the same worker slot)

plus the rollout and inference workers shared with GRPO.  The workflow
graph has 6 nodes with a diamond (rollout feeds reference/critic/reward
in parallel, all meeting at the actor update) — the richest scheduling
graph in the repo, and the reason RLHF is the paper's motivating example
for flexible orchestration.  The runner goes through the shared
:class:`~repro.rl.runner.WorkflowRunner`, so the diamond exercises the
same binding-placement profile → plan → execute path as GRPO.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import Cluster, FlowGraph, SchedulerConfig
from repro.core.worker import Worker
from repro.models import forward, init_model
from repro.models.layers import dense_init, token_logprobs
from repro.rl.advantage import gae_advantages, whiten
from repro.rl.reward import math_reward
from repro.rl.runner import WorkflowRunner
from repro.rl.workers import InferenceWorker, RolloutWorker
from repro.train.data import PromptDataset
from repro.train.optimizer import (
    AdamWConfig,
    adamw_update,
    init_adamw,
)
from repro.train.trainer import TrainHParams, policy_loss


# ---------------------------------------------------------------------------
# Critic: backbone + value head
# ---------------------------------------------------------------------------
def init_critic(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "backbone": init_model(k1, cfg),
        "vhead": dense_init(k2, (cfg.d_model, 1), jnp.float32),
    }


def critic_values(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    """Per-token value estimates (B, S)."""
    _, _, hidden = forward(params["backbone"], cfg, tokens,
                           return_hidden=True)
    v = hidden.astype(jnp.float32) @ params["vhead"]
    return v[..., 0]


class CriticWorker(Worker):
    def __init__(self, name: str, *, cfg: ModelConfig, lr: float = 1e-3,
                 seed: int = 1, devices=(), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.cfg = cfg
        params = init_critic(jax.random.PRNGKey(seed), cfg)
        self.register_state("params", params)
        self.register_state("opt", init_adamw(params))
        self.opt_cfg = AdamWConfig(lr=lr, clip_norm=1.0)
        self._values = jax.jit(
            lambda p, t: critic_values(p, cfg, t))

        def vloss(p, tokens, returns, mask):
            v = critic_values(p, cfg, tokens)
            err = jnp.square(v - returns) * mask
            return jnp.sum(err) / jnp.maximum(jnp.sum(mask), 1.0)

        self._grad = jax.jit(jax.value_and_grad(vloss))

    def values(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = dict(chunk)
        out["values"] = np.asarray(
            self._values(self.get_state("params"),
                         jnp.asarray(chunk["tokens"])))
        return out

    def train_value(self, chunk: Dict[str, np.ndarray]) -> Dict[str, Any]:
        params, opt = self.get_state("params"), self.get_state("opt")
        loss, grads = self._grad(
            params, jnp.asarray(chunk["tokens"]),
            jnp.asarray(chunk["returns"]), jnp.asarray(chunk["loss_mask"]))
        params, opt, _ = adamw_update(self.opt_cfg, params, grads, opt)
        self.set_state("params", params)
        self.set_state("opt", opt)
        out = dict(chunk)
        out["value_loss"] = float(loss)
        return out


class ReferenceWorker(Worker):
    """Frozen initial policy — supplies ref logprobs for the KL penalty."""

    def __init__(self, name: str, *, cfg: ModelConfig, params,
                 devices=(), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.cfg = cfg
        self.register_state("params", jax.tree_util.tree_map(
            jnp.copy, params))

        def lp(p, tokens):
            logits, _ = forward(p, cfg, tokens)
            out = token_logprobs(logits[:, :-1], tokens[:, 1:],
                                 cfg.vocab_size)
            return jnp.pad(out, ((0, 0), (1, 0)))

        self._lp = jax.jit(lp)

    def ref_logprobs(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = dict(chunk)
        out["ref_logprobs"] = np.asarray(
            self._lp(self.get_state("params"), jnp.asarray(chunk["tokens"])))
        return out


class PPOActorWorker(Worker):
    """Trainable actor with the clipped PPO loss + KL-to-reference."""

    def __init__(self, name: str, *, cfg: ModelConfig, hp: TrainHParams,
                 seed: int = 0, devices=(), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.cfg = cfg
        self.hp = hp
        params = init_model(jax.random.PRNGKey(seed), cfg)
        self.register_state("params", params)
        self.register_state("opt", init_adamw(params))

        def step(params, opt, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: policy_loss(cfg, hp, p, batch), has_aux=True
            )(params)
            params, opt, om = adamw_update(hp.optimizer, params, grads, opt)
            metrics.update(om)
            return params, opt, metrics

        self._step = jax.jit(step)
        self.metrics_history: List[Dict[str, float]] = []

    def params(self):
        return self.get_state("params")

    def train(self, chunk: Dict[str, np.ndarray]) -> Dict[str, Any]:
        batch = {k: jnp.asarray(chunk[k]) for k in
                 ("tokens", "old_logprobs", "advantages", "loss_mask",
                  "ref_logprobs") if k in chunk}
        params, opt, metrics = self._step(
            self.get_state("params"), self.get_state("opt"), batch)
        self.set_state("params", params)
        self.set_state("opt", opt)
        m = {k: float(v) for k, v in metrics.items()}
        self.metrics_history.append(m)
        out = dict(chunk)
        out["metrics"] = m
        return out


# ---------------------------------------------------------------------------
# PPO reward + advantage worker (the GRPO RewardWorker's PPO analogue)
# ---------------------------------------------------------------------------
class PPORewardWorker(Worker):
    """Rule-based reward + per-token GAE over the critic's values.

    Consumes ``values`` (from the critic) alongside the rollout tokens,
    places the scalar reward on the last valid token, and runs GAE +
    whitening — so advantage estimation is a schedulable workflow node
    rather than inline runner code."""

    def __init__(self, name: str, *, prompt_len: int, gamma: float = 1.0,
                 lam: float = 0.95, devices=(), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.prompt_len = prompt_len
        self.gamma = gamma
        self.lam = lam

    def score(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        toks = chunk["tokens"]
        B, S = toks.shape
        rewards = math_reward(toks, chunk["answers"], self.prompt_len)
        mask = np.zeros((B, S), np.float32)
        mask[:, self.prompt_len:] = toks[:, self.prompt_len:] != 0

        # --- per-token GAE: reward lands on the last valid token ---
        values = chunk["values"] * mask  # (B, S)
        last_idx = np.maximum(mask.cumsum(1).argmax(1), self.prompt_len)
        r_tok = np.zeros((B, S), np.float32)
        r_tok[np.arange(B), last_idx] = rewards
        # treat the response as a short episode over time axis S
        adv, ret = gae_advantages(
            r_tok.T,
            np.concatenate([values.T, np.zeros((1, B), np.float32)]),
            np.zeros((S, B), np.float32), gamma=self.gamma, lam=self.lam)
        adv = whiten(adv.T, mask)
        out = dict(chunk)
        out["rewards"] = rewards
        out["advantages"] = adv * mask
        out["returns"] = ret.T * mask
        out["loss_mask"] = mask
        return out


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------
@dataclass
class PPOConfig:
    batch_size: int = 32
    prompt_len: int = 8
    max_new_tokens: int = 4
    temperature: float = 1.0
    iterations: int = 20
    kl_coef: float = 0.02
    gamma: float = 1.0
    lam: float = 0.95
    mode: str = "auto"
    seed: int = 0
    profile_batches: tuple = (8, 32)


@dataclass
class PPOIterStats:
    iteration: int
    wall_time: float
    mean_reward: float
    accuracy: float
    value_loss: float
    metrics: Dict[str, float] = field(default_factory=dict)


def rlhf_graph() -> FlowGraph:
    """The 6-node RLHF diamond (module-level so tooling — flowlint,
    benchmarks — can build it without constructing a runner);
    critic_v → reward encodes the data dependency of GAE on values."""
    g = FlowGraph()
    for w in ("rollout", "inference", "reference", "critic_v", "reward",
              "actor"):
        g.add_worker(w)
    g.add_edge("rollout", "inference")
    g.add_edge("rollout", "reference")
    g.add_edge("rollout", "critic_v")
    g.add_edge("rollout", "reward")
    g.add_edge("critic_v", "reward")
    g.add_edge("inference", "actor")
    g.add_edge("reference", "actor")
    g.add_edge("critic_v", "actor")
    g.add_edge("reward", "actor")
    return g


class RLHFRunner(WorkflowRunner):
    """actor+critic+reference+reward PPO over the M2Flow runtime.

    Declares the 6-node diamond to the shared WorkflowRunner; profiling,
    planning, binding placement, managed context switches and measured
    weight sync are all inherited.  The critic's value update rides in
    ``post_execute`` (it trains on the coalesced full batch the actor
    just consumed)."""

    weight_sync_workers = ("rollout", "inference")

    def __init__(self, cfg: ModelConfig, ppo: PPOConfig,
                 hp: Optional[TrainHParams] = None,
                 cluster: Optional[Cluster] = None, **kw):
        self.cfg = cfg
        self.ppo = ppo
        self.hp = hp or TrainHParams(
            optimizer=AdamWConfig(lr=1e-3, clip_norm=1.0),
            kl_coef=ppo.kl_coef, entropy_coef=0.02)
        self.data = self._build_data()
        super().__init__(iterations=ppo.iterations,
                         batch_size=ppo.batch_size, mode=ppo.mode,
                         profile_batches=ppo.profile_batches,
                         cluster=cluster, **kw)

    def _build_data(self) -> PromptDataset:
        data = PromptDataset(self.ppo.batch_size,
                             prompt_len=self.ppo.prompt_len,
                             seed=self.ppo.seed, add_only=True)
        data.max_operand = 3
        return data

    def reset_stream(self) -> None:
        # recovery determinism: replay the fresh runner's prompt sequence
        self.data = self._build_data()

    # ------------------------------------------------------------------
    # declarative surface
    # ------------------------------------------------------------------
    def build_workers(self) -> Dict[str, Any]:
        cfg, ppo = self.cfg, self.ppo
        self.actor = PPOActorWorker(
            "actor/0", cfg=cfg, hp=self.hp, seed=ppo.seed,
            devices=self.cluster.allocate("actor", 2))
        self.rollout = RolloutWorker(
            "rollout/0", cfg=cfg, max_new_tokens=ppo.max_new_tokens,
            temperature=ppo.temperature, seed=ppo.seed,
            devices=self.cluster.allocate("rollout", 2))
        self.inference = InferenceWorker(
            "inference/0", cfg=cfg,
            devices=self.cluster.allocate("inference", 1))
        self.reference = ReferenceWorker(
            "reference/0", cfg=cfg, params=self.actor.params(),
            devices=self.cluster.allocate("reference", 1))
        self.critic = CriticWorker(
            "critic/0", cfg=cfg, seed=ppo.seed + 1,
            devices=self.cluster.allocate("critic_v", 2))
        self.reward = PPORewardWorker(
            "reward/0", prompt_len=ppo.prompt_len, gamma=ppo.gamma,
            lam=ppo.lam)
        return {"rollout": self.rollout, "inference": self.inference,
                "reference": self.reference, "critic_v": self.critic,
                "reward": self.reward, "actor": self.actor}

    def build_task_fns(self) -> Dict[str, Any]:
        return {
            "rollout": lambda w, c: w.generate(c),
            "inference": lambda w, c: w.compute_logprobs(c),
            "reference": lambda w, c: w.ref_logprobs(c),
            "critic_v": lambda w, c: w.values(c),
            "reward": lambda w, c: w.score(c),
            "actor": lambda w, c: w.train(c),
        }

    def build_graph(self) -> FlowGraph:
        return rlhf_graph()

    def make_batch(self) -> Dict[str, np.ndarray]:
        return dict(self.data.next_batch())

    def scheduler_config(self) -> SchedulerConfig:
        # chunk_multiple = full batch: GAE whitening and the value target
        # are batch-global statistics, so pipeline chunks must never
        # split an update batch
        return SchedulerConfig(
            total_batch=self.ppo.batch_size,
            granularity_divisors=(1, 2, 4),
            device_quantum=1,
            chunk_multiple=self.ppo.batch_size,
        )

    # ------------------------------------------------------------------
    def post_execute(self, out):
        # the critic's value update rides with the training stage
        return self.critic.train_value(out)

    def _record_stats(self, it: int, wall: float, out) -> PPOIterStats:
        rewards = out.get("rewards", np.zeros(1))
        st = PPOIterStats(
            iteration=it, wall_time=wall,
            mean_reward=float(rewards.mean()),
            accuracy=float((rewards > 0).mean()),
            value_loss=out.get("value_loss", float("nan")),
            metrics=out.get("metrics", {}))
        self.stats.append(st)
        return st

    def log_iteration(self, st: PPOIterStats) -> None:
        if st.iteration % 5 == 0 or st.iteration == self.ppo.iterations - 1:
            print(f"ppo iter {st.iteration:3d} wall={st.wall_time:5.2f}s "
                  f"reward={st.mean_reward:+6.2f} acc={st.accuracy:4.2f} "
                  f"vloss={st.value_loss:7.3f} "
                  f"kl={st.metrics.get('kl_ref', 0.0):+.4f}")
