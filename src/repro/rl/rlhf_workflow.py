"""Full PPO/RLHF workflow (paper Fig. 1 top-right): four models in the loop.

  actor      — trainable policy (clipped PPO with per-token values)
  critic     — trainable value model (separate backbone + value head)
  reference  — frozen copy of the initial actor (KL anchor)
  reward     — scalar scorer (rule-based here, per §5.1; a learned RM
               plugs into the same worker slot)

plus the rollout and inference workers shared with GRPO.  The workflow
graph has 6 nodes with a diamond (rollout feeds reference/critic/reward
in parallel, all meeting at the actor update) — the richest scheduling
graph in the repo, and the reason RLHF is the paper's motivating example
for flexible orchestration.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import Cluster, Controller, FlowGraph, SchedulerConfig
from repro.core.worker import Worker
from repro.models import forward, init_model
from repro.models.layers import dense_init, token_logprobs
from repro.rl.advantage import gae_advantages, whiten
from repro.rl.reward import math_reward
from repro.rl.workers import InferenceWorker, RolloutWorker
from repro.train.data import PromptDataset
from repro.train.optimizer import (
    AdamWConfig,
    adamw_update,
    init_adamw,
)
from repro.train.trainer import TrainHParams, policy_loss


# ---------------------------------------------------------------------------
# Critic: backbone + value head
# ---------------------------------------------------------------------------
def init_critic(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "backbone": init_model(k1, cfg),
        "vhead": dense_init(k2, (cfg.d_model, 1), jnp.float32),
    }


def critic_values(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    """Per-token value estimates (B, S)."""
    _, _, hidden = forward(params["backbone"], cfg, tokens,
                           return_hidden=True)
    v = hidden.astype(jnp.float32) @ params["vhead"]
    return v[..., 0]


class CriticWorker(Worker):
    def __init__(self, name: str, *, cfg: ModelConfig, lr: float = 1e-3,
                 seed: int = 1, devices=(), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.cfg = cfg
        params = init_critic(jax.random.PRNGKey(seed), cfg)
        self.register_state("params", params)
        self.register_state("opt", init_adamw(params))
        self.opt_cfg = AdamWConfig(lr=lr, clip_norm=1.0)
        self._values = jax.jit(
            lambda p, t: critic_values(p, cfg, t))

        def vloss(p, tokens, returns, mask):
            v = critic_values(p, cfg, tokens)
            err = jnp.square(v - returns) * mask
            return jnp.sum(err) / jnp.maximum(jnp.sum(mask), 1.0)

        self._grad = jax.jit(jax.value_and_grad(vloss))

    def values(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = dict(chunk)
        out["values"] = np.asarray(
            self._values(self.get_state("params"),
                         jnp.asarray(chunk["tokens"])))
        return out

    def train_value(self, chunk: Dict[str, np.ndarray]) -> Dict[str, Any]:
        params, opt = self.get_state("params"), self.get_state("opt")
        loss, grads = self._grad(
            params, jnp.asarray(chunk["tokens"]),
            jnp.asarray(chunk["returns"]), jnp.asarray(chunk["loss_mask"]))
        params, opt, _ = adamw_update(self.opt_cfg, params, grads, opt)
        self.set_state("params", params)
        self.set_state("opt", opt)
        out = dict(chunk)
        out["value_loss"] = float(loss)
        return out


class ReferenceWorker(Worker):
    """Frozen initial policy — supplies ref logprobs for the KL penalty."""

    def __init__(self, name: str, *, cfg: ModelConfig, params,
                 devices=(), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.cfg = cfg
        self.register_state("params", jax.tree_util.tree_map(
            jnp.copy, params))

        def lp(p, tokens):
            logits, _ = forward(p, cfg, tokens)
            out = token_logprobs(logits[:, :-1], tokens[:, 1:],
                                 cfg.vocab_size)
            return jnp.pad(out, ((0, 0), (1, 0)))

        self._lp = jax.jit(lp)

    def ref_logprobs(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = dict(chunk)
        out["ref_logprobs"] = np.asarray(
            self._lp(self.get_state("params"), jnp.asarray(chunk["tokens"])))
        return out


class PPOActorWorker(Worker):
    """Trainable actor with the clipped PPO loss + KL-to-reference."""

    def __init__(self, name: str, *, cfg: ModelConfig, hp: TrainHParams,
                 seed: int = 0, devices=(), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.cfg = cfg
        self.hp = hp
        params = init_model(jax.random.PRNGKey(seed), cfg)
        self.register_state("params", params)
        self.register_state("opt", init_adamw(params))

        def step(params, opt, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: policy_loss(cfg, hp, p, batch), has_aux=True
            )(params)
            params, opt, om = adamw_update(hp.optimizer, params, grads, opt)
            metrics.update(om)
            return params, opt, metrics

        self._step = jax.jit(step)
        self.metrics_history: List[Dict[str, float]] = []

    def params(self):
        return self.get_state("params")

    def train(self, chunk: Dict[str, np.ndarray]) -> Dict[str, Any]:
        batch = {k: jnp.asarray(chunk[k]) for k in
                 ("tokens", "old_logprobs", "advantages", "loss_mask",
                  "ref_logprobs") if k in chunk}
        params, opt, metrics = self._step(
            self.get_state("params"), self.get_state("opt"), batch)
        self.set_state("params", params)
        self.set_state("opt", opt)
        m = {k: float(v) for k, v in metrics.items()}
        self.metrics_history.append(m)
        out = dict(chunk)
        out["metrics"] = m
        return out


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------
@dataclass
class PPOConfig:
    batch_size: int = 32
    prompt_len: int = 8
    max_new_tokens: int = 4
    temperature: float = 1.0
    iterations: int = 20
    kl_coef: float = 0.02
    gamma: float = 1.0
    lam: float = 0.95
    mode: str = "auto"
    seed: int = 0


@dataclass
class PPOIterStats:
    iteration: int
    wall_time: float
    mean_reward: float
    accuracy: float
    value_loss: float
    metrics: Dict[str, float] = field(default_factory=dict)


class RLHFRunner:
    """actor+critic+reference+reward PPO over the M2Flow runtime."""

    def __init__(self, cfg: ModelConfig, ppo: PPOConfig,
                 hp: Optional[TrainHParams] = None):
        self.cfg = cfg
        self.ppo = ppo
        self.cluster = Cluster(num_nodes=1, devices_per_node=8)
        hp = hp or TrainHParams(optimizer=AdamWConfig(lr=1e-3, clip_norm=1.0),
                                kl_coef=ppo.kl_coef, entropy_coef=0.02)
        self.data = PromptDataset(ppo.batch_size, prompt_len=ppo.prompt_len,
                                  seed=ppo.seed, add_only=True)
        self.data.max_operand = 3

        self.actor = PPOActorWorker(
            "actor/0", cfg=cfg, hp=hp, seed=ppo.seed,
            devices=self.cluster.allocate("actor", 2))
        self.rollout = RolloutWorker(
            "rollout/0", cfg=cfg, max_new_tokens=ppo.max_new_tokens,
            temperature=ppo.temperature, seed=ppo.seed,
            devices=self.cluster.allocate("rollout", 2))
        self.inference = InferenceWorker(
            "inference/0", cfg=cfg,
            devices=self.cluster.allocate("inference", 1))
        self.reference = ReferenceWorker(
            "reference/0", cfg=cfg, params=self.actor.params(),
            devices=self.cluster.allocate("reference", 1))
        self.critic = CriticWorker(
            "critic/0", cfg=cfg, seed=ppo.seed + 1,
            devices=self.cluster.allocate("critic", 2))
        self.stats: List[PPOIterStats] = []

    # the 6-node RLHF workflow graph (for the scheduler/benchmarks)
    def graph(self) -> FlowGraph:
        g = FlowGraph()
        for w in ("rollout", "inference", "reference", "critic_v", "reward",
                  "actor"):
            g.add_worker(w)
        g.add_edge("rollout", "inference")
        g.add_edge("rollout", "reference")
        g.add_edge("rollout", "critic_v")
        g.add_edge("rollout", "reward")
        g.add_edge("inference", "actor")
        g.add_edge("reference", "actor")
        g.add_edge("critic_v", "actor")
        g.add_edge("reward", "actor")
        return g

    def _sync(self):
        p = self.actor.params()
        self.rollout.update_weights(p)
        self.inference.update_weights(p)

    def run_iteration(self, it: int) -> PPOIterStats:
        t0 = time.perf_counter()
        self._sync()
        ppo = self.ppo
        batch = self.data.next_batch()
        # rollout
        chunk = self.rollout.generate(dict(batch))
        # fan-out: inference / reference / critic values / reward
        chunk = self.inference.compute_logprobs(chunk)
        chunk = self.reference.ref_logprobs(chunk)
        chunk = self.critic.values(chunk)
        toks = chunk["tokens"]
        B, S = toks.shape
        rewards = math_reward(toks, batch["answers"], ppo.prompt_len)
        mask = np.zeros((B, S), np.float32)
        mask[:, ppo.prompt_len:] = toks[:, ppo.prompt_len:] != 0

        # --- per-token GAE: reward lands on the last valid token ---
        values = chunk["values"] * mask  # (B, S)
        last_idx = np.maximum(mask.cumsum(1).argmax(1), ppo.prompt_len)
        r_tok = np.zeros((B, S), np.float32)
        r_tok[np.arange(B), last_idx] = rewards
        # treat the response as a short episode over time axis S
        adv, ret = gae_advantages(
            r_tok.T, np.concatenate([values.T, np.zeros((1, B), np.float32)]),
            np.zeros((S, B), np.float32), gamma=ppo.gamma, lam=ppo.lam)
        adv = whiten(adv.T, mask)
        chunk["advantages"] = adv * mask
        chunk["returns"] = ret.T * mask
        chunk["loss_mask"] = mask

        # --- updates ---
        chunk = self.actor.train(chunk)
        chunk = self.critic.train_value(chunk)
        st = PPOIterStats(
            iteration=it, wall_time=time.perf_counter() - t0,
            mean_reward=float(rewards.mean()),
            accuracy=float((rewards > 0).mean()),
            value_loss=chunk["value_loss"],
            metrics=chunk.get("metrics", {}))
        self.stats.append(st)
        return st

    def run(self, verbose: bool = True) -> List[PPOIterStats]:
        for it in range(self.ppo.iterations):
            st = self.run_iteration(it)
            if verbose and (it % 5 == 0 or it == self.ppo.iterations - 1):
                print(f"ppo iter {it:3d} wall={st.wall_time:5.2f}s "
                      f"reward={st.mean_reward:+6.2f} acc={st.accuracy:4.2f} "
                      f"vloss={st.value_loss:7.3f} "
                      f"kl={st.metrics.get('kl_ref', 0.0):+.4f}")
        return self.stats
