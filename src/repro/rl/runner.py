"""Shared profile → plan → execute driver for RL workflows (paper Fig. 5b).

`GRPORunner` and `RLHFRunner` used to duplicate this loop (and RLHF
bypassed the runtime entirely, calling workers imperatively).  The
:class:`WorkflowRunner` base makes the loop declarative — a subclass
names its workers, task functions and workflow graph, and the base owns:

  ``profile()``         one traced iteration in topological order →
                        per-worker :class:`CostModel`s (timings, memory,
                        on/offload round-trips, measured rollout tail);
  ``plan_execution()``  Controller.plan → a *binding* ExecutionPlan;
  ``run_iteration()``   measured weight sync through the resharding data
                        plane + ``Controller.execute`` (which diffs the
                        plan's placement, rebinds worker device slices,
                        and drives Temporal cuts through the managed
                        ContextSwitcher);
  ``run()``             the whole loop.

Both the GRPO chain and the RLHF diamond therefore exercise the same
binding-placement path; a new workflow is ~five declarative hooks.
"""
from __future__ import annotations

import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import networkx as nx
import numpy as np

from repro.comm.resharding import timed_weight_sync, transfer_stats
from repro.core import Cluster, Controller, FlowGraph, Profiler, SchedulerConfig
from repro.core.faults import HeartbeatMonitor
from repro.core.pipeline import assert_no_leaked_threads
from repro.core.profiler import CostModel, fit_tail_factor, measure_onoffload
from repro.core.worker import WorkerFailure
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.utils import logging as _log


class WorkflowRunner:
    """Owns the workers + controller and drives the M2Flow-scheduled loop.

    Subclass responsibilities (the declarative surface):

      * ``build_workers()  -> {node: Worker}``
      * ``build_task_fns() -> {node: fn(worker, chunk) -> chunk}``
      * ``build_graph()    -> FlowGraph`` over the same node names
      * ``make_batch()     -> dict-of-arrays batch``
      * ``scheduler_config() -> SchedulerConfig``
      * ``_record_stats(it, wall, out) -> stat`` (appends + returns)
      * optionally ``post_execute(out)``, ``log_iteration(st)``,
        ``weight_sync_workers`` (node names that receive trainer
        weights; the trainer must expose ``params()`` as ``self.actor``).
    """

    # node names whose workers receive the trainer's weights each
    # iteration (must expose update_weights)
    weight_sync_workers: Tuple[str, ...] = ("rollout", "inference")
    # the one sync target whose update_weights accepts a version tag
    # (its engine stamps per-request weight versions for the async
    # staleness correction); None = no versioned target
    versioned_sync_worker: Optional[str] = "rollout"

    def __init__(self, *, iterations: int, batch_size: int,
                 mode: str = "auto",
                 profile_batches: Sequence[int] = (8, 32),
                 cluster: Optional[Cluster] = None,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 0,
                 fault_injector: Optional[Any] = None,
                 fault_tolerant: Optional[bool] = None,
                 max_recoveries: int = 2):
        self.iterations = iterations
        self.batch_size = batch_size
        self.mode = mode
        self.profile_batches = tuple(profile_batches)
        # periodic trainer-state checkpointing (train.checkpoint): save
        # every `checkpoint_every` iterations into `checkpoint_dir` and
        # auto-resume from it when run() starts
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        # failure injection + recovery (core.faults): the injector's kill
        # switch is spliced into the task fns; `fault_tolerant` gates the
        # run_loop's catch-and-recover (default: on exactly when an
        # injector is present, so a genuine bug in a normal test run
        # still raises instead of silently recovering in a loop)
        self.fault_injector = fault_injector
        self.fault_tolerant = (fault_tolerant if fault_tolerant is not None
                               else fault_injector is not None)
        self.max_recoveries = max_recoveries
        self.recoveries = 0
        self.recovery_log: List[WorkerFailure] = []
        self.cluster = cluster or Cluster(num_nodes=1, devices_per_node=8)
        self.workers: Dict[str, Any] = self.build_workers()
        self.task_fns: Dict[str, Callable] = self._arm_task_fns(
            self.build_task_fns())
        self._graph: Optional[FlowGraph] = None
        # straggler observability: every task call beats the monitor
        # (via the executor), run_loop reads the interval percentiles.
        # The hard timeout is infinite — the monitor's job here is
        # cadence statistics, not liveness enforcement
        self.heartbeat = HeartbeatMonitor(timeout=math.inf)
        self.controller = Controller(self.cluster, heartbeat=self.heartbeat)
        self.plan = None
        self.stats: List[Any] = []
        # cumulative weight-sync accounting (resharding data plane):
        # total measured seconds, total bytes moved, number of syncs
        self.sync_stats: Dict[str, float] = {
            "seconds": 0.0, "bytes": 0.0, "syncs": 0}

    def _arm_task_fns(self, task_fns: Dict[str, Callable]
                      ) -> Dict[str, Callable]:
        if self.fault_injector is not None:
            return self.fault_injector.arm(task_fns)
        return task_fns

    # ------------------------------------------------------------------
    # declarative surface
    # ------------------------------------------------------------------
    def build_workers(self) -> Dict[str, Any]:
        raise NotImplementedError

    def build_task_fns(self) -> Dict[str, Callable]:
        raise NotImplementedError

    def build_graph(self) -> FlowGraph:
        raise NotImplementedError

    def make_batch(self) -> Dict[str, np.ndarray]:
        raise NotImplementedError

    def reset_stream(self) -> None:
        """Reset the data stream to its construction-time state.  Called
        by :meth:`recover` so a recovered run replays EXACTLY the batch
        sequence a fresh runner resumed from the same checkpoint would
        see — the invariant the recovery-determinism tests assert.
        Subclasses with a data source must override (rebuild the dataset
        with the same seed, zero rollout-round counters, ...)."""

    def scheduler_config(self) -> SchedulerConfig:
        return SchedulerConfig(total_batch=self.batch_size)

    def cycle_specs(self) -> Dict[str, Any]:
        """{collapsed node name: core.pipeline.CycleSpec} for workflows
        whose graph contains cycles (e.g. embodied sim<->generation);
        the executor needs them to run a cycle Leaf as a closed loop."""
        return {}

    def _record_stats(self, it: int, wall: float, out) -> Any:
        raise NotImplementedError

    def post_execute(self, out):
        """Hook after the planned graph ran (e.g. auxiliary updates that
        ride with the training stage)."""
        return out

    def log_iteration(self, st) -> None:
        print(f"iter {st.iteration:3d}  wall={st.wall_time:6.2f}s "
              f"reward={st.mean_reward:+6.2f} acc={st.accuracy:5.2f}")

    # ------------------------------------------------------------------
    def graph(self) -> FlowGraph:
        if self._graph is None:
            self._graph = self.build_graph()
        return self._graph

    def topo_order(self) -> List[str]:
        return list(nx.topological_sort(self.graph().g))

    # ------------------------------------------------------------------
    # weight sync: a data-plane operation through comm.resharding
    # ------------------------------------------------------------------
    def _sync_weights(self, params: Optional[Any] = None,
                      version: Optional[int] = None) -> float:
        """Reshard the trainer's params onto each generation-side
        worker's mesh (``timed_weight_sync``), with byte accounting
        (``transfer_stats``).  The measured cost lands in the target
        workers' CostModels (``sync_time``/``sync_bytes``) where the
        Scheduler charges it on the Temporal cut that brings the worker
        back online.  Returns the measured seconds of this sync."""
        if params is None:
            params = self.actor.params()
        stats = transfer_stats(params)
        total = 0.0
        for name in self.weight_sync_workers:
            w = self.workers.get(name)
            if w is None:
                continue
            shardings = w.state_shardings(params)
            if shardings is not None:
                synced, dt = timed_weight_sync(params, shardings)
                total += dt
            else:
                synced, dt = params, 0.0
            if version is not None and name == self.versioned_sync_worker:
                w.update_weights(synced, version=version)
            else:
                w.update_weights(synced)
            cm = self.controller.profiles.get(name)
            if cm is not None:
                cm.sync_time = dt if cm.sync_time == 0.0 \
                    else 0.5 * cm.sync_time + 0.5 * dt
                cm.sync_bytes = stats["bytes"]
        self.sync_stats["seconds"] += total
        self.sync_stats["bytes"] += stats["bytes"] * len(
            [n for n in self.weight_sync_workers if n in self.workers])
        self.sync_stats["syncs"] += 1
        return total

    # ------------------------------------------------------------------
    # Phase 1: profiling iteration — fit cost models along the graph
    # ------------------------------------------------------------------
    def _profile_sizes(self) -> List[int]:
        sizes = [b for b in self.profile_batches if b <= self.batch_size]
        return sizes or [self.batch_size]

    def profile(self) -> FlowGraph:
        self._sync_weights()
        prof = Profiler(warmup=1, repeats=1)
        profiles: Dict[str, CostModel] = {}
        chunk = self.make_batch()
        for name in self.topo_order():
            w, fn = self.workers[name], self.task_fns[name]
            inp = dict(chunk)

            def run_at(b, w=w, fn=fn, inp=inp):
                sub = {k: (v[:b] if isinstance(v, np.ndarray)
                           and v.ndim >= 1 else v)
                       for k, v in inp.items()}
                return fn(w, sub)

            cm = prof.measure(name, run_at, self._profile_sizes())
            chunk = fn(w, inp)
            if hasattr(w, "_state") and w.state_bytes():
                on, off = measure_onoffload(w)
                cm.onload_time, cm.offload_time = on, off
            cm.base_mem = float(w.state_bytes())
            if hasattr(w, "request_records"):
                # engine-backed tail: fit the long-tail multiplier from
                # measured per-request completion times instead of
                # assuming the Fig. 2 length model
                recs = w.request_records()
                if recs:
                    cm.tail_factor = fit_tail_factor(t for _, t in recs)
            profiles[name] = cm
        self.controller.profiles = profiles
        return self.graph()

    # ------------------------------------------------------------------
    def plan_execution(self) -> None:
        self.controller.scheduler_cfg = self.scheduler_config()
        self.plan = self.controller.plan(
            self.graph(), total_batch=self.batch_size, mode=self.mode)

    # ------------------------------------------------------------------
    def run_iteration(self, it: int):
        t0 = time.perf_counter()
        tr = _trace.active()
        if tr is not None:
            tr.set_context(iteration=it)
        if self.fault_injector is not None:
            self.fault_injector.set_iteration(it)
        try:
            self._sync_weights()
            batch = self.make_batch()
            out = self.controller.execute(
                self.plan, self.workers, self.task_fns, batch,
                cycle_specs=self.cycle_specs())
            out = self.post_execute(out)
        finally:
            wall = time.perf_counter() - t0
            if tr is not None:
                tr.add(f"iteration-{it}", "iteration", t0,
                       time.perf_counter())
                tr.set_context(iteration=None)
        return self._record_stats(it, wall, out)

    # ------------------------------------------------------------------
    # periodic trainer checkpointing + resume (train.checkpoint)
    # ------------------------------------------------------------------
    def _trainer_state(self) -> Dict[str, Any]:
        return {"params": self.actor.get_state("params"),
                "opt": self.actor.get_state("opt")}

    def save_trainer_checkpoint(self, it: int) -> None:
        from repro.train.checkpoint import save_checkpoint
        save_checkpoint(self.checkpoint_dir, self._trainer_state(),
                        step=it + 1,
                        metadata={"workflow": type(self).__name__})

    def resume_trainer_checkpoint(self) -> int:
        """Restore actor params + optimizer state from checkpoint_dir if
        one exists; returns the iteration to resume from (0 = fresh)."""
        from repro.train.checkpoint import checkpoint_exists, load_checkpoint
        if not self.checkpoint_dir or not checkpoint_exists(
                self.checkpoint_dir):
            return 0
        tree, step, _ = load_checkpoint(self.checkpoint_dir,
                                        self._trainer_state())
        self.actor.set_state("params", tree["params"])
        self.actor.set_state("opt", tree["opt"])
        return step

    # ------------------------------------------------------------------
    # failure recovery (core.faults): detect -> teardown -> re-place ->
    # resume from the last checkpoint
    # ------------------------------------------------------------------
    def teardown(self) -> None:
        """Release everything the dead run held: router registrations,
        cluster allocations (both construction-time owners and plan-
        managed ones), the context switcher, and the failure latch.
        After this the cluster looks exactly as it did before the runner
        was constructed (minus any failed hosts)."""
        for name, w in self.workers.items():
            if hasattr(w, "shutdown"):
                w.shutdown()
            self.cluster.free(name)
        self.controller.placement_manager.release_all()
        self.controller._switcher = None
        self.controller.profiles = {}
        self.controller.reset_failures()
        self.plan = None
        self._graph = None
        # a wedged executor thread surviving teardown would silently
        # leak across recoveries — make it a typed error instead
        assert_no_leaked_threads()

    def recover(self, verbose: bool = True) -> int:
        """Re-establish the run after a WorkerFailure; returns the
        iteration to resume from.

        Recovery is DEFINED as a fresh runner resumed from the last
        checkpoint: tear everything down, reset the data stream, rebuild
        the workers on the surviving devices (``Cluster.allocate`` skips
        dead hosts), re-profile, re-plan (``Controller.plan`` draws from
        ``available_devices``), and restore trainer state.  Because each
        step replays ``run()``'s own prologue, the recovered run is
        bit-equivalent to the fresh-resume baseline by construction."""
        self.teardown()
        self.reset_stream()
        self.workers = self.build_workers()
        self.task_fns = self._arm_task_fns(self.build_task_fns())
        self.profile()
        self.plan_execution()
        start = self.resume_trainer_checkpoint()
        if verbose:
            print(f"recovered: re-placed on "
                  f"{len(self.cluster.available_devices())} live device(s), "
                  f"resuming at iteration {start}")
        return start

    def _observe_iteration(self, it: int, verbose: bool) -> None:
        """Per-iteration observability: straggler warnings from the
        heartbeat cadence (percentile path — the hard-timeout path only
        catches outright hangs), the matching obs gauges, and a metrics
        snapshot merged into verbose output while tracing is armed."""
        suspects = self.heartbeat.suspects()
        if suspects and verbose:
            _log.warn("straggler",
                      f"iteration {it}: {', '.join(suspects)} running "
                      f"behind their own beat cadence", iteration=it)
        reg = _metrics.active()
        if reg is not None:
            reg.gauge("faults/stragglers").set(len(suspects))
            reg.counter("runner/iterations").inc()
            reg.gauge("runner/recoveries").set(self.recoveries)
            for name in self.workers:
                p95 = self.heartbeat.interval_percentile(name, 95.0)
                if p95 is not None:
                    reg.gauge(f"faults/beat_p95_s/{name}").set(p95)
            if verbose:
                snap = reg.snapshot()
                for line in _metrics.format_snapshot(snap):
                    _log.info("metrics", line)

    def run_loop(self, verbose: bool = True) -> None:
        if self.plan is None:
            # allow run_loop() as the single entry point (recover() goes
            # through the same profile -> plan path)
            self.profile()
            self.plan_execution()
        start = self.resume_trainer_checkpoint()
        if start and verbose:
            print(f"resumed trainer state from {self.checkpoint_dir} "
                  f"at iteration {start}"
                  + (" (nothing left to run)"
                     if start >= self.iterations else ""))
        it = start
        while it < self.iterations:
            try:
                st = self.run_iteration(it)
            except WorkerFailure as f:
                if (not self.fault_tolerant
                        or self.recoveries >= self.max_recoveries):
                    raise
                self.recoveries += 1
                self.recovery_log.append(f)
                reg = _metrics.active()
                if reg is not None:
                    reg.counter("faults/recoveries").inc()
                tr = _trace.active()
                if tr is not None:
                    tr.instant("worker-failure", "fault", worker=f.worker,
                               step=f.step, iteration=it)
                if verbose:
                    print(f"worker failure at iteration {it}: "
                          f"{f.worker} (step {f.step}) — recovering "
                          f"({self.recoveries}/{self.max_recoveries})")
                it = self.recover(verbose)
                continue
            self._observe_iteration(it, verbose)
            if verbose:
                self.log_iteration(st)
            if (self.checkpoint_dir and self.checkpoint_every
                    and (it + 1) % self.checkpoint_every == 0):
                self.save_trainer_checkpoint(it)
            it += 1

    def run(self, verbose: bool = True) -> List[Any]:
        self.profile()
        self.plan_execution()
        if verbose:
            print(self.plan.pretty())
        self.run_loop(verbose)
        return self.stats
