"""Rule-based reward (paper §5.1): +5 if the boxed/numeric answer is
correct else -5; applied to the synthetic math tasks of repro.train.data."""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.train.data import EOS, decode_digits

CORRECT, WRONG = 5.0, -5.0


def math_reward(response_tokens: np.ndarray, answers: np.ndarray,
                prompt_len: int) -> np.ndarray:
    """response_tokens: (B, S_total) prompt+generated; answers: (B,)."""
    B = response_tokens.shape[0]
    out = np.full((B,), WRONG, np.float32)
    for i in range(B):
        resp = list(response_tokens[i, prompt_len:])
        if EOS in resp:
            resp = resp[: resp.index(EOS)]
        if decode_digits(resp) == int(answers[i]):
            out[i] = CORRECT
    return out


def format_bonus(response_tokens: np.ndarray, prompt_len: int,
                 bonus: float = 0.5) -> np.ndarray:
    """Small shaping bonus for terminating with EOS (optional)."""
    B = response_tokens.shape[0]
    out = np.zeros((B,), np.float32)
    for i in range(B):
        if EOS in list(response_tokens[i, prompt_len:]):
            out[i] = bonus
    return out
