"""Embodied PPO workflow on the M2Flow runtime (paper Fig. 1 bottom-left,
Fig. 9): the third workflow family bound to the shared WorkflowRunner.

The simulator↔policy loop is a CYCLE in the workflow graph.  The
scheduler collapses it into one node (Algorithm 1 line 2), chooses a
realization — **collocated** (members alternate per step on shared
devices) or **hybrid** (members on disjoint device shares, fine-grained-
pipelined over env chunks with double-buffered obs/action queues) — and
records it on the plan's Leaf; the ExecutionFlowManager then runs the
cycle as a real closed loop (obs → action → sim → reward), per step,
through the member workers' ``act`` / ``step_env`` tasks.

The policy is a small decoder-only LM over discretized observations:
prompt = [BOS, obs-token ×4] → one action token (9 discrete actions),
sampled with per-(step, env) keys so both realizations draw identical
actions.  Advantages are whitened critic-free GAE with the
terminated/truncated split (timeouts bootstrap, goals do not).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core import Cluster, CycleSpec, FlowGraph, SchedulerConfig
from repro.core.flowgraph import cycle_node_name
from repro.core.profiler import CostModel, Profiler, measure_onoffload
from repro.core.worker import Worker
from repro.rl.advantage import gae_advantages, whiten
from repro.rl.env import NUM_ACTIONS, OBS_DIM, EnvConfig
from repro.rl.runner import WorkflowRunner
from repro.rl.workers import ActorWorker, RolloutWorker, SimulatorWorker
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import TrainHParams

# token layout: PAD, BOS, 24 obs-bin tokens, 9 action tokens
PAD, BOS = 0, 1
OBS_BASE, OBS_BINS = 2, 6
ACT_BASE = OBS_BASE + OBS_BINS * OBS_DIM  # 26
VOCAB = ACT_BASE + NUM_ACTIONS  # 35
SEQ = 1 + OBS_DIM + 1  # BOS + obs + action


def obs_to_tokens(obs: np.ndarray) -> np.ndarray:
    """(N, 4) float obs -> (N, 5) int tokens [BOS, d0..d3]."""
    clipped = np.clip((obs + 1.5) / 3.0, 0.0, 0.999)
    bins = (clipped * OBS_BINS).astype(np.int32)
    toks = OBS_BASE + np.arange(OBS_DIM)[None, :] * OBS_BINS + bins
    return np.concatenate(
        [np.full((obs.shape[0], 1), BOS, np.int32), toks.astype(np.int32)],
        axis=1)


def default_policy_config() -> ModelConfig:
    return get_config("stablelm-12b").reduced().replace(
        name="stablelm-policy", vocab_size=VOCAB, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=256, max_seq_len=SEQ)


@dataclass
class EmbodiedPPOConfig:
    num_envs: int = 64
    horizon: int = 16       # cycle steps per iteration
    iterations: int = 60
    lr: float = 3e-3
    gamma: float = 0.95
    lam: float = 1.0
    # cycle realization: "auto" lets Algorithm 1 pick the cheaper of the
    # two costed realizations; "collocated"/"hybrid" force one (the
    # paper's Fig.-9 fixed baselines)
    mode: str = "auto"
    cycle_chunks: int = 2   # hybrid double-buffer chunk count
    seed: int = 0
    max_steps: int = 32     # env episode horizon (truncation point)
    # simulated sim/policy step costs (see EnvConfig / RolloutWorker):
    # flat-per-step = LIBERO-like CPU sim, per-env = ManiSkill-like
    step_latency: float = 0.0
    latency_per_env: float = 0.0
    act_latency: float = 0.0
    act_latency_per_env: float = 0.0
    profile_batches: tuple = (16, 64)
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0


@dataclass
class EmbodiedIterStats:
    iteration: int
    wall_time: float
    success_rate: float     # successes per env over the horizon
    mean_reward: float
    metrics: Dict[str, float] = field(default_factory=dict)


def embodied_graph() -> FlowGraph:
    """The embodied workflow graph (module-level so tooling — flowlint,
    benchmarks — can build it without constructing a runner)."""
    g = FlowGraph()
    for w in ("simulator", "policy_gen", "advantage", "train"):
        g.add_worker(w)
    g.add_edge("simulator", "policy_gen")
    g.add_edge("policy_gen", "simulator")  # the cycle
    g.add_edge("policy_gen", "advantage")
    g.add_edge("advantage", "train")
    return g


def embodied_cycle_specs(horizon: int = 8,
                         chunks: int = 2) -> Dict[str, CycleSpec]:
    name = cycle_node_name(("policy_gen", "simulator"))
    return {name: CycleSpec(order=("policy_gen", "simulator"),
                            steps=horizon, prime="simulator",
                            chunks=chunks)}


class EmbodiedPPORunner(WorkflowRunner):
    """simulator↔policy cycle + advantage + train through the runtime."""

    weight_sync_workers = ("policy_gen",)
    versioned_sync_worker = None

    def __init__(self, rl: EmbodiedPPOConfig,
                 cfg: Optional[ModelConfig] = None,
                 hp: Optional[TrainHParams] = None,
                 cluster: Optional[Cluster] = None, **kw):
        self.rl = rl
        self._rollout_round = 0
        self.model_cfg = cfg or default_policy_config()
        self.hp = hp or TrainHParams(
            optimizer=AdamWConfig(lr=rl.lr, clip_norm=1.0),
            clip_eps_low=0.2, clip_eps_high=0.2)
        super().__init__(iterations=rl.iterations, batch_size=rl.num_envs,
                         mode="auto",  # the cycle realization is forced
                                       # via SchedulerConfig.cycle_mode
                         profile_batches=rl.profile_batches,
                         cluster=cluster,
                         checkpoint_dir=rl.checkpoint_dir,
                         checkpoint_every=rl.checkpoint_every, **kw)

    def reset_stream(self) -> None:
        # recovery determinism: the rollout-round counter seeds each
        # round's randomness; a rebuilt run restarts it like a fresh
        # runner (resume_trainer_checkpoint then advances it to `start`)
        self._rollout_round = 0

    # ------------------------------------------------------------------
    # declarative surface
    # ------------------------------------------------------------------
    def build_workers(self) -> Dict[str, Any]:
        rl = self.rl
        env_cfg = EnvConfig(num_envs=rl.num_envs, max_steps=rl.max_steps,
                            step_latency=rl.step_latency,
                            latency_per_env=rl.latency_per_env)
        self.actor = ActorWorker(
            "train/0", cfg=self.model_cfg, hp=self.hp, seed=rl.seed,
            devices=self.cluster.allocate("train", 4))
        self.policy = RolloutWorker(
            "policy_gen/0", cfg=self.model_cfg, max_new_tokens=1,
            engine="static", seed=rl.seed,
            action_range=(ACT_BASE, ACT_BASE + NUM_ACTIONS),
            act_latency=rl.act_latency,
            act_latency_per_env=rl.act_latency_per_env,
            devices=self.cluster.allocate("policy_gen", 2))
        self.simulator = SimulatorWorker(
            "simulator/0", env_cfg=env_cfg, seed=rl.seed,
            devices=self.cluster.allocate("simulator", 1))
        self.advantage = EmbodiedAdvantageWorker(
            "advantage/0", gamma=rl.gamma, lam=rl.lam)
        return {"simulator": self.simulator, "policy_gen": self.policy,
                "advantage": self.advantage, "train": self.actor}

    def _policy_task(self, w: RolloutWorker, chunk: Dict) -> Dict:
        chunk = dict(chunk)
        chunk["prompt_tokens"] = obs_to_tokens(np.asarray(chunk["obs"]))
        return w.act(chunk)

    def build_task_fns(self) -> Dict[str, Any]:
        return {
            "simulator": lambda w, c: w.step_env(c),
            "policy_gen": self._policy_task,
            "advantage": lambda w, c: w.compute(c),
            "train": lambda w, c: w.train(c),
        }

    def build_graph(self) -> FlowGraph:
        return embodied_graph()

    def cycle_specs(self) -> Dict[str, CycleSpec]:
        return embodied_cycle_specs(horizon=self.rl.horizon,
                                    chunks=self.rl.cycle_chunks)

    def resume_trainer_checkpoint(self) -> int:
        start = super().resume_trainer_checkpoint()
        # keep the act-path RNG stream aligned with the resumed
        # iteration — rounds already consumed before the interruption
        # must not be replayed
        self._rollout_round = max(self._rollout_round, start)
        return start

    def make_batch(self) -> Dict[str, np.ndarray]:
        # rollout_round feeds the act path's RNG so each iteration draws
        # fresh exploration noise; carried as a per-env column so the
        # executor's env-axis chunking slices it like any other key
        batch = {"env_ids": np.arange(self.rl.num_envs, dtype=np.int64),
                 "rollout_round": np.full(self.rl.num_envs,
                                          self._rollout_round, np.int64)}
        self._rollout_round += 1
        return batch

    def scheduler_config(self) -> SchedulerConfig:
        rl = self.rl
        return SchedulerConfig(
            total_batch=rl.num_envs,
            # whitening + GAE are batch-global: never pipeline the outer
            # graph below the full env batch
            granularity_divisors=(1,),
            chunk_multiple=rl.num_envs,
            device_quantum=2,
            cycle_mode=None if rl.mode == "auto" else rl.mode,
            cycle_chunks=rl.cycle_chunks)

    # ------------------------------------------------------------------
    # profiling: the base chained-topo profile cannot run a cyclic
    # graph, so measure each member's per-STEP cost directly and scale
    # the cycle members' fits by the horizon (a cycle leaf's cost covers
    # the whole closed loop)
    # ------------------------------------------------------------------
    def profile(self) -> FlowGraph:
        self._sync_weights()
        prof = Profiler(warmup=1, repeats=1)
        sizes = self._profile_sizes()
        T = self.rl.horizon
        sim_w, pol_w = self.simulator, self.policy
        adv_w, train_w = self.advantage, self.actor

        def sim_at(b):
            return self.task_fns["simulator"](sim_w, {
                "env_ids": np.arange(b),
                "actions": np.zeros(b, np.int64), "cycle_step": 0})

        def pol_at(b):
            ids = np.arange(b)
            return self._policy_task(pol_w, {
                "obs": sim_w.env.observe(ids), "env_ids": ids,
                "cycle_step": 0})

        def adv_at(b):
            return self.task_fns["advantage"](adv_w, self._fake_traj(b))

        # build the train input OUTSIDE the timed callable: adv_at's GAE
        # + batch assembly is already measured as the advantage node and
        # must not be double-counted into the train fit
        train_inputs: Dict[int, Dict] = {}

        def train_at(b):
            if b not in train_inputs:
                train_inputs[b] = adv_at(b)
            return self.task_fns["train"](train_w, dict(train_inputs[b]))

        profiles: Dict[str, CostModel] = {}
        for name, w, fn in (("simulator", sim_w, sim_at),
                            ("policy_gen", pol_w, pol_at),
                            ("advantage", adv_w, adv_at),
                            ("train", train_w, train_at)):
            cm = prof.measure(name, fn, sizes)
            if name in ("simulator", "policy_gen"):
                cm.base_time *= T
                cm.slope_time *= T
            if hasattr(w, "_state") and w.state_bytes():
                cm.onload_time, cm.offload_time = measure_onoffload(w)
            cm.base_mem = float(w.state_bytes())
            profiles[name] = cm
        # the sim is instance-bound: extra devices do not speed a step
        profiles["simulator"].scalable = False
        profiles["simulator"].max_useful_devices = 1
        # profiling stepped some envs mid-episode; start training clean
        sim_w.env.reset()
        self.controller.profiles = profiles
        return self.graph()

    def _fake_traj(self, b: int) -> Dict[str, np.ndarray]:
        T = self.rl.horizon
        return {"rewards": np.zeros((T, b), np.float32),
                "terminated": np.zeros((T, b), np.float32),
                "truncated": np.zeros((T, b), np.float32),
                "prompt_tokens": np.ones((T, b, SEQ - 1), np.int32),
                "action_tokens": np.full((T, b), ACT_BASE, np.int32),
                "action_logprobs": np.zeros((T, b), np.float32),
                "successes": 0}

    # ------------------------------------------------------------------
    def _record_stats(self, it: int, wall: float, out) -> EmbodiedIterStats:
        rews = np.asarray(out.get("rewards", np.zeros((1, 1))))
        st = EmbodiedIterStats(
            iteration=it, wall_time=wall,
            success_rate=float(out.get("successes", 0)) / self.rl.num_envs,
            mean_reward=float(rews.sum(0).mean()),
            metrics=self.actor.metrics_history[-1]
            if self.actor.metrics_history else {})
        self.stats.append(st)
        return st

    def log_iteration(self, st: EmbodiedIterStats) -> None:
        if st.iteration % 5 == 0 or st.iteration == self.iterations - 1:
            recent = [s.success_rate for s in self.stats[-10:]]
            print(f"iter {st.iteration:3d} wall={st.wall_time:5.2f}s "
                  f"success/env={st.success_rate:5.2f} "
                  f"avg10={sum(recent) / len(recent):5.2f} "
                  f"reward={st.mean_reward:+6.2f}")

    def success_curve(self) -> List[float]:
        return [s.success_rate for s in self.stats]


class EmbodiedAdvantageWorker(Worker):
    """Whitened critic-free GAE + train-batch assembly as a schedulable
    node.  Bootstraps THROUGH truncation (timeout is not a terminal
    state) and resets credit at both kinds of episode end."""

    def __init__(self, name: str, *, gamma: float = 0.95, lam: float = 1.0,
                 devices=(), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.gamma = gamma
        self.lam = lam

    def compute(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        rews = np.asarray(chunk["rewards"], np.float32)        # (T, N)
        term = np.asarray(chunk["terminated"], np.float32)
        trunc = np.asarray(chunk["truncated"], np.float32)
        T, N = rews.shape
        values = np.zeros((T + 1, N), np.float32)  # critic-free PPO
        adv, _ = gae_advantages(rews, values, gamma=self.gamma,
                                lam=self.lam, terminated=term,
                                truncated=trunc)
        adv = whiten(adv)
        prompts = np.asarray(chunk["prompt_tokens"])           # (T, N, S-1)
        acts = np.asarray(chunk["action_tokens"])              # (T, N)
        S = prompts.shape[-1] + 1
        B = T * N
        toks = np.concatenate([prompts, acts[..., None]],
                              axis=-1).reshape(B, S).astype(np.int32)
        old_lp = np.zeros((B, S), np.float32)
        old_lp[:, S - 1] = np.asarray(chunk["action_logprobs"]).reshape(B)
        advantages = np.zeros((B, S), np.float32)
        advantages[:, S - 1] = adv.reshape(B)
        mask = np.zeros((B, S), np.float32)
        mask[:, S - 1] = 1.0
        out = dict(chunk)
        out["tokens"] = toks
        out["old_logprobs"] = old_lp
        out["advantages"] = advantages
        out["loss_mask"] = mask
        return out
