"""Vectorized synthetic embodied environment (the CPU "simulator" worker).

Mirrors the computational profile the paper measures (Fig. 3): step time
nearly flat in the number of environments, memory linear, CPU-bound.  The
task is a 2-D "reach the target" control problem: the policy emits one of
9 discrete actions (8 directions + stay) per step; reward is progress
toward the goal; an episode succeeds when within eps of the goal.

This gives embodied RL examples a *real* closed loop (obs -> action ->
sim -> reward) with a learnable optimal policy.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

_DIRS = np.array(
    [[0, 0], [1, 0], [-1, 0], [0, 1], [0, -1],
     [1, 1], [1, -1], [-1, 1], [-1, -1]], np.float32)
_DIRS[1:] /= np.linalg.norm(_DIRS[1:], axis=1, keepdims=True)

NUM_ACTIONS = 9
OBS_DIM = 4  # (dx, dy, dist, step_frac)


@dataclass
class EnvConfig:
    num_envs: int = 64
    arena: float = 10.0
    speed: float = 0.7
    eps: float = 0.5
    max_steps: int = 32
    # artificial per-step latency to mimic physics+render cost (Fig. 3b);
    # 0 disables (tests)
    step_latency: float = 0.0


class VecReachEnv:
    def __init__(self, cfg: EnvConfig, seed: int = 0):
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self.pos = np.zeros((cfg.num_envs, 2), np.float32)
        self.goal = np.zeros((cfg.num_envs, 2), np.float32)
        self.steps = np.zeros((cfg.num_envs,), np.int32)
        self.reset()

    def reset(self, env_ids: Optional[np.ndarray] = None) -> np.ndarray:
        ids = np.arange(self.cfg.num_envs) if env_ids is None else env_ids
        n = len(ids)
        self.pos[ids] = self.rng.uniform(-self.cfg.arena, self.cfg.arena,
                                         (n, 2)).astype(np.float32)
        self.goal[ids] = self.rng.uniform(-self.cfg.arena, self.cfg.arena,
                                          (n, 2)).astype(np.float32)
        self.steps[ids] = 0
        return self.observe()

    def observe(self) -> np.ndarray:
        d = self.goal - self.pos
        dist = np.linalg.norm(d, axis=1, keepdims=True)
        frac = (self.steps / self.cfg.max_steps)[:, None]
        return np.concatenate(
            [d / self.cfg.arena, dist / self.cfg.arena, frac], axis=1
        ).astype(np.float32)

    def step(self, actions: np.ndarray
             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Dict]:
        if self.cfg.step_latency:
            time.sleep(self.cfg.step_latency)
        old_dist = np.linalg.norm(self.goal - self.pos, axis=1)
        self.pos += _DIRS[actions] * self.cfg.speed
        self.steps += 1
        new_dist = np.linalg.norm(self.goal - self.pos, axis=1)
        progress = old_dist - new_dist
        success = new_dist < self.cfg.eps
        timeout = self.steps >= self.cfg.max_steps
        done = success | timeout
        reward = progress.astype(np.float32) + 10.0 * success.astype(np.float32)
        obs = self.observe()
        info = {"success": success.copy()}
        if done.any():
            self.reset(np.nonzero(done)[0])
        return obs, reward, done.astype(np.float32), info
