"""Vectorized synthetic embodied environment (the CPU "simulator" worker).

Mirrors the computational profile the paper measures (Fig. 3): step time
nearly flat in the number of environments (plus an optional per-env
component for GPU-parallel ManiSkill-like sims), memory linear,
CPU-bound.  The task is a 2-D "reach the target" control problem: the
policy emits one of 9 discrete actions (8 directions + stay) per step;
reward is progress toward the goal; an episode succeeds when within eps
of the goal.

This gives embodied RL examples a *real* closed loop (obs -> action ->
sim -> reward) with a learnable optimal policy.

Semantics:

* Episode ends split into ``terminated`` (the goal was reached — the MDP
  truly ended) and ``truncated`` (the ``max_steps`` horizon ran out — the
  episode was cut, not finished).  GAE must bootstrap through truncation
  but not through termination (``rl.advantage.gae_advantages``).
* ``step`` auto-resets finished envs and returns the POST-reset
  observation — the one the next action must be computed from.  The true
  final observation of the finished episode is exposed as
  ``info["terminal_obs"]`` (the value target for truncated episodes).
* Randomness is per-env (one generator per environment), so stepping an
  arbitrary subset (``env_ids``) consumes exactly the same random stream
  per env as stepping the full batch — chunked (hybrid-pipelined) and
  full-batch (collocated) cycle execution produce identical trajectories.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

_DIRS = np.array(
    [[0, 0], [1, 0], [-1, 0], [0, 1], [0, -1],
     [1, 1], [1, -1], [-1, 1], [-1, -1]], np.float32)
_DIRS[1:] /= np.linalg.norm(_DIRS[1:], axis=1, keepdims=True)

NUM_ACTIONS = 9
OBS_DIM = 4  # (dx, dy, dist, step_frac)


@dataclass
class EnvConfig:
    num_envs: int = 64
    arena: float = 10.0
    speed: float = 0.7
    eps: float = 0.5
    max_steps: int = 32
    # artificial per-step latency to mimic physics+render cost (Fig. 3b);
    # 0 disables (tests).  `step_latency` is flat per step call (the
    # LIBERO-like CPU-sim regime: chunking envs does not make a step
    # cheaper); `latency_per_env` scales with the number of envs stepped
    # (the ManiSkill-like GPU-parallel regime: a chunk costs its share).
    step_latency: float = 0.0
    latency_per_env: float = 0.0


class VecReachEnv:
    def __init__(self, cfg: EnvConfig, seed: int = 0):
        self.cfg = cfg
        # one generator per env: subset stepping stays bit-identical to
        # full-batch stepping (resets draw only from the reset env's
        # stream, never shifting its neighbours')
        self.rngs = [np.random.default_rng((seed, i))
                     for i in range(cfg.num_envs)]
        self.pos = np.zeros((cfg.num_envs, 2), np.float32)
        self.goal = np.zeros((cfg.num_envs, 2), np.float32)
        self.steps = np.zeros((cfg.num_envs,), np.int32)
        self.reset()

    def reset(self, env_ids: Optional[np.ndarray] = None) -> np.ndarray:
        ids = np.arange(self.cfg.num_envs) if env_ids is None else env_ids
        for i in ids:
            draw = self.rngs[int(i)].uniform(
                -self.cfg.arena, self.cfg.arena, (2, 2)).astype(np.float32)
            self.pos[i] = draw[0]
            self.goal[i] = draw[1]
        self.steps[ids] = 0
        return self.observe(env_ids)

    def observe(self, env_ids: Optional[np.ndarray] = None) -> np.ndarray:
        ids = slice(None) if env_ids is None else env_ids
        d = self.goal[ids] - self.pos[ids]
        dist = np.linalg.norm(d, axis=1, keepdims=True)
        frac = (self.steps[ids] / self.cfg.max_steps)[:, None]
        return np.concatenate(
            [d / self.cfg.arena, dist / self.cfg.arena, frac], axis=1
        ).astype(np.float32)

    def step(self, actions: np.ndarray,
             env_ids: Optional[np.ndarray] = None
             ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, Dict]:
        ids = np.arange(self.cfg.num_envs) if env_ids is None else \
            np.asarray(env_ids)
        if self.cfg.step_latency or self.cfg.latency_per_env:
            time.sleep(self.cfg.step_latency
                       + self.cfg.latency_per_env * len(ids))
        old_dist = np.linalg.norm(self.goal[ids] - self.pos[ids], axis=1)
        self.pos[ids] += _DIRS[actions] * self.cfg.speed
        self.steps[ids] += 1
        new_dist = np.linalg.norm(self.goal[ids] - self.pos[ids], axis=1)
        progress = old_dist - new_dist
        success = new_dist < self.cfg.eps
        terminated = success
        truncated = (self.steps[ids] >= self.cfg.max_steps) & ~terminated
        done = terminated | truncated
        reward = progress.astype(np.float32) + 10.0 * success.astype(np.float32)
        # the finished episode's TRUE final observation — captured before
        # the auto-reset below replaces it
        terminal_obs = self.observe(ids)
        if done.any():
            self.reset(ids[np.nonzero(done)[0]])
        # post-reset obs: what the next action (and the GAE bootstrap
        # value at episode starts) must be computed from
        obs = self.observe(ids)
        info = {"success": success.copy(),
                "terminated": terminated.copy(),
                "truncated": truncated.copy(),
                "terminal_obs": terminal_obs}
        return obs, reward, done.astype(np.float32), info
