"""RL component workers (paper Fig. 5a) built on the M2Flow Worker base.

Each worker owns its JAX state (registered for onload/offload context
switching) and exposes chunk-level task methods the Execution Flow
Manager drives at any granularity — the SPMD-over-any-batch property
elastic pipelining relies on (§3.3).
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.worker import Worker
from repro.models import init_model
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.serve import layouts as serve_layouts
from repro.rl.advantage import broadcast_to_tokens, grpo_advantages
from repro.rl.env import EnvConfig, VecReachEnv
from repro.rl.reward import math_reward
from repro.serve.engine import Engine, PagedEngine
from repro.train.optimizer import init_adamw
from repro.train.trainer import (
    TrainHParams,
    make_prefill_step,
    make_train_step,
)


class RolloutWorker(Worker):
    """Generation engine (the paper's SGLang/vLLM role).

    ``engine="paged"`` (the default for every arch a cache layout
    covers: dense, MoE, SSM, hybrid) generates through the
    continuous-batching :class:`~repro.serve.engine.PagedEngine`:
    requests join/leave the decode batch per step, the cache lives in
    the arch's layout (paged KV blocks or constant-size recurrent
    state), and trainer weight updates apply in flight with per-request
    version tags.  ``engine="static"`` keeps the legacy fixed-shape
    ``lax.scan`` engine; uncovered archs (encoder-decoder, VLM, windowed
    attention) fall back to it with a warning and an
    ``rollout/engine_fallback`` metric.
    """

    def __init__(self, name: str, *, cfg: ModelConfig,
                 max_new_tokens: int = 16, temperature: float = 1.0,
                 top_k: int = 0, top_p: float = 1.0,
                 seed: int = 0, devices: Sequence[int] = (),
                 process_index: int = 0, engine: str = "auto",
                 max_batch: int = 8, page_size: int = 16,
                 prefix_sharing: bool = True, prefill_chunk: int = 32,
                 action_range: Optional[tuple] = None,
                 act_latency: float = 0.0,
                 act_latency_per_env: float = 0.0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.cfg = cfg
        # [lo, hi) vocab window of action tokens for the closed-loop
        # `act` path (embodied cycles); None for pure text workflows
        self.action_range = action_range
        # artificial act-path latency mimicking a VLA-scale policy
        # forward (the tiny repro policy is ~free; the paper's embodied
        # generation side is not): flat per call + per env acted on
        self.act_latency = act_latency
        self.act_latency_per_env = act_latency_per_env
        if engine == "auto":
            if serve_layouts.covers(cfg):
                engine = "paged"
            else:
                engine = "static"
                # loud fallback: workloads missing the fast path must
                # show up in logs and flowtrace summaries, not vanish
                warnings.warn(
                    f"RolloutWorker {name!r}: no paged cache layout "
                    f"covers arch {cfg.name!r} (kind={cfg.kind}, "
                    f"sliding_window={cfg.sliding_window}); falling "
                    f"back to the static engine", stacklevel=2)
                tr = _trace.active()
                if tr is not None:
                    tr.instant("engine-fallback", "rollout",
                               worker=name, arch=cfg.name, kind=cfg.kind)
                    reg = _metrics.active()
                    if reg is not None:
                        reg.counter("rollout/engine_fallback").inc()
        assert engine in ("paged", "static"), engine
        self.engine_kind = engine
        if engine == "paged":
            # prefix sharing makes a GRPO group's common prompt prefill
            # once: generate() submits all group members to one engine,
            # the first admission indexes the prompt pages in the radix
            # cache and every sibling adopts them
            self.engine = PagedEngine(
                cfg, max_batch=max_batch, page_size=page_size,
                max_new_tokens=max_new_tokens, temperature=temperature,
                top_k=top_k, top_p=top_p, prefix_sharing=prefix_sharing,
                prefill_chunk=prefill_chunk)
        else:
            self.engine = Engine(cfg, max_new_tokens=max_new_tokens,
                                 temperature=temperature, top_k=top_k,
                                 top_p=top_p)
        self.key = jax.random.PRNGKey(seed + process_index)
        # fixed base key for the closed-loop act path: randomness is
        # derived per (cycle_step, env_id) by fold_in, NOT consumed
        # sequentially, so any chunking of the env batch (the hybrid
        # cycle realization) draws identical actions
        self._act_key = jax.random.PRNGKey(seed ^ 0x5EED)
        self.register_state("params", None)

    def bind_devices(self, devices: Sequence[int]) -> None:
        """Plan-driven rebinding must move the ENGINE's device state too:
        the paged KV pool (and any applied/pending weights) follows the
        worker onto its new mesh, or the jitted step would receive a
        cache and params committed to incompatible device sets."""
        old = self.devices
        super().bind_devices(devices)
        if self.devices != old and isinstance(self.engine, PagedEngine):
            mesh = self.device_mesh
            if mesh is not None:
                from repro.utils.sharding import replicated
                self.engine.rebind_devices(replicated(mesh))

    # weight sync (paper §2.1): trainer -> rollout.  On the paged engine
    # this is NOT a barrier — the update is enqueued and applied at the
    # next step boundary while requests stay in flight.
    def update_weights(self, params: Any,
                       version: Optional[int] = None) -> None:
        self.set_state("params", params)
        if isinstance(self.engine, PagedEngine):
            self.engine.update_weights(params, version)

    def generate(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        params = self.get_state("params")
        assert params is not None, "rollout weights not initialized"
        self.key, sub = jax.random.split(self.key)
        prompts = jnp.asarray(chunk["prompt_tokens"])
        res = self.engine.generate(params, prompts, key=sub)
        out = dict(chunk)
        out["tokens"] = np.asarray(res.tokens)
        out["logprobs"] = np.asarray(res.logprobs)
        out["lengths"] = np.asarray(res.lengths)
        if res.weight_versions is not None:
            out["weight_versions"] = np.asarray(res.weight_versions)
        return out

    def request_records(self):
        """(tokens, service_time) per completed request since last call
        (paged engine only) — feeds the profiler's measured tail factor."""
        if isinstance(self.engine, PagedEngine):
            return self.engine.pop_request_records()
        return []

    # closed-loop action path (the embodied sim<->generation cycle):
    # one constrained sampling step per env step, through the engine
    def _act_engine(self) -> Engine:
        if isinstance(self.engine, Engine):
            return self.engine
        # the paged engine has no single-step act path; acting is a
        # prefill-only op, so a static engine (explicit params, no
        # duplicated state) covers it
        if not hasattr(self, "_static_act_engine"):
            self._static_act_engine = Engine(self.cfg, max_new_tokens=1)
        return self._static_act_engine

    def act(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Per-step action sampling for the cycle executor.  Consumes
        ``prompt_tokens`` (B, S) plus the executor-injected
        ``cycle_step`` / ``env_ids``; emits ``action_tokens``,
        ``action_logprobs`` and env-space ``actions``."""
        assert self.action_range is not None, \
            "RolloutWorker.act needs action_range=(lo, hi)"
        params = self.get_state("params")
        assert params is not None, "rollout weights not initialized"
        lo, hi = self.action_range
        prompts = np.asarray(chunk["prompt_tokens"])
        if self.act_latency or self.act_latency_per_env:
            time.sleep(self.act_latency
                       + self.act_latency_per_env * prompts.shape[0])
        ids = np.asarray(chunk.get("env_ids", np.arange(prompts.shape[0])))
        step = int(chunk.get("cycle_step", 0))
        # key on (rollout_round, cycle_step, env_id): the round keeps
        # exploration noise FRESH across training iterations (cycle_step
        # restarts at 0 every rollout), while the per-env fold keeps
        # sampling invariant to how the env batch is chunked
        rnd = chunk.get("rollout_round", 0)
        rnd = int(np.asarray(rnd).reshape(-1)[0]) if np.ndim(rnd) else int(rnd)
        base = jax.random.fold_in(jax.random.fold_in(self._act_key, rnd),
                                  step)
        env_keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(
            jnp.asarray(ids, jnp.int32))
        tok, lp = self._act_engine().act(params, prompts, env_keys,
                                         action_lo=lo, action_hi=hi)
        out = dict(chunk)
        out["action_tokens"] = np.asarray(tok)
        out["action_logprobs"] = np.asarray(lp)
        out["actions"] = out["action_tokens"] - lo
        return out


class InferenceWorker(Worker):
    """Prefill-only logprob recompute (the paper's 'Inference' box)."""

    def __init__(self, name: str, *, cfg: ModelConfig,
                 devices: Sequence[int] = (), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.cfg = cfg
        self._step = jax.jit(make_prefill_step(cfg))
        self.register_state("params", None)

    def update_weights(self, params: Any) -> None:
        self.set_state("params", params)

    def compute_logprobs(self, chunk: Dict[str, np.ndarray],
                         key: str = "old_logprobs",
                         params: Optional[Any] = None
                         ) -> Dict[str, np.ndarray]:
        """Prefill recompute.  ``key`` lets the async consumer re-score a
        stale rollout at the CURRENT parameter version (e.g. into
        ``'target_logprobs'``) without clobbering the behavior reference;
        explicit ``params`` scores with those weights WITHOUT touching the
        worker's registered state (the producer thread owns that state —
        see GRPORunner._run_async_horizon)."""
        if params is None:
            params = self.get_state("params")
        out = dict(chunk)
        out[key] = np.asarray(
            self._step(params, {"tokens": jnp.asarray(chunk["tokens"])}))
        return out


class ActorWorker(Worker):
    """Trainable policy (actor) with AdamW state; GRPO/PPO loss."""

    def __init__(self, name: str, *, cfg: ModelConfig, hp: TrainHParams,
                 seed: int = 0, devices: Sequence[int] = (),
                 process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.cfg = cfg
        self.hp = hp
        params = init_model(jax.random.PRNGKey(seed), cfg)
        self.register_state("params", params)
        self.register_state("opt", init_adamw(params))
        self._step = jax.jit(make_train_step(cfg, hp))
        self.metrics_history = []

    def params(self) -> Any:
        return self.get_state("params")

    def train(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        params = self.get_state("params")
        opt = self.get_state("opt")
        batch = {
            "tokens": jnp.asarray(chunk["tokens"]),
            "old_logprobs": jnp.asarray(chunk["old_logprobs"]),
            "advantages": jnp.asarray(chunk["advantages"]),
            "loss_mask": jnp.asarray(chunk["loss_mask"]),
        }
        params, opt, metrics = self._step(params, opt, batch)
        self.set_state("params", params)
        self.set_state("opt", opt)
        m = {k: float(v) for k, v in metrics.items()}
        self.metrics_history.append(m)
        out = dict(chunk)
        out["metrics"] = m
        return out


class RewardWorker(Worker):
    """Rule-based reward + GRPO group advantage computation."""

    def __init__(self, name: str, *, prompt_len: int, group_size: int = 1,
                 devices: Sequence[int] = (), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.prompt_len = prompt_len
        self.group_size = group_size

    def score(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        toks = chunk["tokens"]
        rewards = math_reward(toks, chunk["answers"], self.prompt_len)
        B, S = toks.shape
        mask = np.zeros((B, S), np.float32)
        mask[:, self.prompt_len:] = (toks[:, self.prompt_len:] != 0)
        gs = min(self.group_size, B) if B % max(self.group_size, 1) == 0 else 1
        if gs == 1 and self.group_size > 1:
            warnings.warn(
                f"reward chunk of {B} rows is not a multiple of "
                f"group_size={self.group_size}; group-relative advantages "
                "degrade to 0 (no learning signal). Align the execution "
                "plan's chunk size (SchedulerConfig.chunk_multiple).",
                stacklevel=2)
        adv_seq = grpo_advantages(rewards, gs)
        out = dict(chunk)
        out["rewards"] = rewards
        out["loss_mask"] = mask
        out["advantages"] = broadcast_to_tokens(adv_seq, mask)
        return out


class SimulatorWorker(Worker):
    """Embodied simulator (CPU-bound, instance-replicated — Fig. 3)."""

    def __init__(self, name: str, *, env_cfg: EnvConfig, seed: int = 0,
                 devices: Sequence[int] = (), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.env = VecReachEnv(env_cfg, seed=seed + process_index)
        self.env_cfg = env_cfg

    def step_env(self, chunk: Dict[str, Any]) -> Dict[str, Any]:
        """Closed-loop per-step task for the cycle executor (replaces the
        old open-loop precomputed-actions ``rollout_steps``).

        Without ``actions`` in the chunk this is the loop's PRIME call:
        it returns the current observation only.  With ``actions``
        (B,) it steps the env subset named by ``env_ids`` (or all envs),
        returning the post-reset obs the next action must be computed
        from, the step's reward, and the terminated/truncated split plus
        ``terminal_obs`` that correct GAE bootstrapping needs."""
        out = dict(chunk)
        ids = chunk.get("env_ids")
        ids = np.asarray(ids) if ids is not None else None
        if "actions" not in chunk:
            out["obs"] = self.env.observe(ids)
            return out
        obs, rew, done, info = self.env.step(
            np.asarray(chunk["actions"]), ids)
        out["obs"] = obs
        out["rewards"] = rew
        out["dones"] = done
        out["terminated"] = info["terminated"].astype(np.float32)
        out["truncated"] = info["truncated"].astype(np.float32)
        out["terminal_obs"] = info["terminal_obs"]
        out["successes"] = int(info["success"].sum())
        return out

    def observe(self, _chunk: Optional[Dict] = None) -> Dict[str, Any]:
        return {"obs": self.env.observe()}
