"""RL component workers (paper Fig. 5a) built on the M2Flow Worker base.

Each worker owns its JAX state (registered for onload/offload context
switching) and exposes chunk-level task methods the Execution Flow
Manager drives at any granularity — the SPMD-over-any-batch property
elastic pipelining relies on (§3.3).
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DENSE, ModelConfig
from repro.core.worker import Worker
from repro.models import init_model
from repro.rl.advantage import broadcast_to_tokens, grpo_advantages
from repro.rl.env import EnvConfig, VecReachEnv
from repro.rl.reward import math_reward
from repro.serve.engine import Engine, PagedEngine
from repro.train.optimizer import init_adamw
from repro.train.trainer import (
    TrainHParams,
    make_prefill_step,
    make_train_step,
)


class RolloutWorker(Worker):
    """Generation engine (the paper's SGLang/vLLM role).

    ``engine="paged"`` (the default for dense stacks) generates through
    the continuous-batching :class:`~repro.serve.engine.PagedEngine`:
    requests join/leave the decode batch per step, KV lives in paged
    blocks, and trainer weight updates apply in flight with per-request
    version tags.  ``engine="static"`` keeps the legacy fixed-shape
    ``lax.scan`` engine (and is the fallback for non-dense or windowed
    architectures the paged cache does not cover yet).
    """

    def __init__(self, name: str, *, cfg: ModelConfig,
                 max_new_tokens: int = 16, temperature: float = 1.0,
                 top_k: int = 0, top_p: float = 1.0,
                 seed: int = 0, devices: Sequence[int] = (),
                 process_index: int = 0, engine: str = "auto",
                 max_batch: int = 8, page_size: int = 16):
        super().__init__(name, devices=devices, process_index=process_index)
        self.cfg = cfg
        if engine == "auto":
            engine = ("paged" if cfg.kind == DENSE
                      and not cfg.sliding_window else "static")
        assert engine in ("paged", "static"), engine
        self.engine_kind = engine
        if engine == "paged":
            self.engine = PagedEngine(
                cfg, max_batch=max_batch, page_size=page_size,
                max_new_tokens=max_new_tokens, temperature=temperature,
                top_k=top_k, top_p=top_p)
        else:
            self.engine = Engine(cfg, max_new_tokens=max_new_tokens,
                                 temperature=temperature, top_k=top_k,
                                 top_p=top_p)
        self.key = jax.random.PRNGKey(seed + process_index)
        self.register_state("params", None)

    def bind_devices(self, devices: Sequence[int]) -> None:
        """Plan-driven rebinding must move the ENGINE's device state too:
        the paged KV pool (and any applied/pending weights) follows the
        worker onto its new mesh, or the jitted step would receive a
        cache and params committed to incompatible device sets."""
        old = self.devices
        super().bind_devices(devices)
        if self.devices != old and isinstance(self.engine, PagedEngine):
            mesh = self.device_mesh
            if mesh is not None:
                from repro.utils.sharding import replicated
                self.engine.rebind_devices(replicated(mesh))

    # weight sync (paper §2.1): trainer -> rollout.  On the paged engine
    # this is NOT a barrier — the update is enqueued and applied at the
    # next step boundary while requests stay in flight.
    def update_weights(self, params: Any,
                       version: Optional[int] = None) -> None:
        self.set_state("params", params)
        if isinstance(self.engine, PagedEngine):
            self.engine.update_weights(params, version)

    def generate(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        params = self.get_state("params")
        assert params is not None, "rollout weights not initialized"
        self.key, sub = jax.random.split(self.key)
        prompts = jnp.asarray(chunk["prompt_tokens"])
        res = self.engine.generate(params, prompts, key=sub)
        out = dict(chunk)
        out["tokens"] = np.asarray(res.tokens)
        out["logprobs"] = np.asarray(res.logprobs)
        out["lengths"] = np.asarray(res.lengths)
        if res.weight_versions is not None:
            out["weight_versions"] = np.asarray(res.weight_versions)
        return out

    def request_records(self):
        """(tokens, service_time) per completed request since last call
        (paged engine only) — feeds the profiler's measured tail factor."""
        if isinstance(self.engine, PagedEngine):
            return self.engine.pop_request_records()
        return []


class InferenceWorker(Worker):
    """Prefill-only logprob recompute (the paper's 'Inference' box)."""

    def __init__(self, name: str, *, cfg: ModelConfig,
                 devices: Sequence[int] = (), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.cfg = cfg
        self._step = jax.jit(make_prefill_step(cfg))
        self.register_state("params", None)

    def update_weights(self, params: Any) -> None:
        self.set_state("params", params)

    def compute_logprobs(self, chunk: Dict[str, np.ndarray],
                         key: str = "old_logprobs",
                         params: Optional[Any] = None
                         ) -> Dict[str, np.ndarray]:
        """Prefill recompute.  ``key`` lets the async consumer re-score a
        stale rollout at the CURRENT parameter version (e.g. into
        ``'target_logprobs'``) without clobbering the behavior reference;
        explicit ``params`` scores with those weights WITHOUT touching the
        worker's registered state (the producer thread owns that state —
        see GRPORunner._run_async_horizon)."""
        if params is None:
            params = self.get_state("params")
        out = dict(chunk)
        out[key] = np.asarray(
            self._step(params, {"tokens": jnp.asarray(chunk["tokens"])}))
        return out


class ActorWorker(Worker):
    """Trainable policy (actor) with AdamW state; GRPO/PPO loss."""

    def __init__(self, name: str, *, cfg: ModelConfig, hp: TrainHParams,
                 seed: int = 0, devices: Sequence[int] = (),
                 process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.cfg = cfg
        self.hp = hp
        params = init_model(jax.random.PRNGKey(seed), cfg)
        self.register_state("params", params)
        self.register_state("opt", init_adamw(params))
        self._step = jax.jit(make_train_step(cfg, hp))
        self.metrics_history = []

    def params(self) -> Any:
        return self.get_state("params")

    def train(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        params = self.get_state("params")
        opt = self.get_state("opt")
        batch = {
            "tokens": jnp.asarray(chunk["tokens"]),
            "old_logprobs": jnp.asarray(chunk["old_logprobs"]),
            "advantages": jnp.asarray(chunk["advantages"]),
            "loss_mask": jnp.asarray(chunk["loss_mask"]),
        }
        params, opt, metrics = self._step(params, opt, batch)
        self.set_state("params", params)
        self.set_state("opt", opt)
        m = {k: float(v) for k, v in metrics.items()}
        self.metrics_history.append(m)
        out = dict(chunk)
        out["metrics"] = m
        return out


class RewardWorker(Worker):
    """Rule-based reward + GRPO group advantage computation."""

    def __init__(self, name: str, *, prompt_len: int, group_size: int = 1,
                 devices: Sequence[int] = (), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.prompt_len = prompt_len
        self.group_size = group_size

    def score(self, chunk: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        toks = chunk["tokens"]
        rewards = math_reward(toks, chunk["answers"], self.prompt_len)
        B, S = toks.shape
        mask = np.zeros((B, S), np.float32)
        mask[:, self.prompt_len:] = (toks[:, self.prompt_len:] != 0)
        gs = min(self.group_size, B) if B % max(self.group_size, 1) == 0 else 1
        if gs == 1 and self.group_size > 1:
            warnings.warn(
                f"reward chunk of {B} rows is not a multiple of "
                f"group_size={self.group_size}; group-relative advantages "
                "degrade to 0 (no learning signal). Align the execution "
                "plan's chunk size (SchedulerConfig.chunk_multiple).",
                stacklevel=2)
        adv_seq = grpo_advantages(rewards, gs)
        out = dict(chunk)
        out["rewards"] = rewards
        out["loss_mask"] = mask
        out["advantages"] = broadcast_to_tokens(adv_seq, mask)
        return out


class SimulatorWorker(Worker):
    """Embodied simulator (CPU-bound, instance-replicated — Fig. 3)."""

    def __init__(self, name: str, *, env_cfg: EnvConfig, seed: int = 0,
                 devices: Sequence[int] = (), process_index: int = 0):
        super().__init__(name, devices=devices, process_index=process_index)
        self.env = VecReachEnv(env_cfg, seed=seed + process_index)
        self.env_cfg = env_cfg

    def rollout_steps(self, chunk: Dict[str, Any]) -> Dict[str, Any]:
        """Step the sim with the provided per-step action callback results.

        chunk: {"actions": (T, num_envs) int} -> trajectories.
        """
        actions = chunk["actions"]
        T = actions.shape[0]
        obs_list, rew_list, done_list = [self.env.observe()], [], []
        succ = 0
        for t in range(T):
            obs, rew, done, info = self.env.step(actions[t])
            obs_list.append(obs)
            rew_list.append(rew)
            done_list.append(done)
            succ += int(info["success"].sum())
        out = dict(chunk)
        out["obs"] = np.stack(obs_list)  # (T+1, N, obs_dim)
        out["rewards"] = np.stack(rew_list)
        out["dones"] = np.stack(done_list)
        out["successes"] = succ
        return out

    def observe(self, _chunk: Optional[Dict] = None) -> Dict[str, Any]:
        return {"obs": self.env.observe()}
