"""Shared neural-net building blocks (pure JAX, dict-pytree params)."""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    angles = angles[..., None, :]  # (..., seq, 1, half) broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, (d_model, d_ff), dtype),
        "up": dense_init(k2, (d_model, d_ff), dtype),
        "down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embedding(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"tokens": embed_init(k1, (cfg.padded_vocab, cfg.d_model), dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.padded_vocab), dtype)
    return p


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return p["tokens"][tokens]


def unembed(p: Params, x: jax.Array) -> jax.Array:
    if "unembed" in p:
        return x @ p["unembed"]
    return x @ p["tokens"].T


# ---------------------------------------------------------------------------
# log-softmax helpers used by RL losses
# ---------------------------------------------------------------------------
def token_logprobs(logits: jax.Array, tokens: jax.Array,
                   vocab_size: int = 0) -> jax.Array:
    """Log-probability of each target token; logits (..., V), tokens (...).

    vocab_size > 0 masks the padded-vocab region (embedding tables are
    padded for sharding) so generation-time and recompute-time logprobs
    agree exactly.
    """
    logits = logits.astype(jnp.float32)
    if vocab_size:
        V = logits.shape[-1]
        logits = jnp.where(jnp.arange(V) < vocab_size, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, tokens[..., None], axis=-1)[..., 0]
    return picked - logz
