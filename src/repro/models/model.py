"""Unified model assembly for all six architecture kinds.

Layer stacks are *scan-stacked*: per-layer params carry a leading layer
axis and the forward pass is a ``lax.scan`` over it, keeping HLO size and
compile time independent of depth (critical for the 88–100 layer archs in
the 512-device dry-run).  Non-uniform stacks (hybrid, vlm) scan the
uniform majority and nest the periodic minority inside the scan body.

Public entry points (all pure functions):
  init_model(key, cfg, dtype)                          -> params
  forward(params, cfg, tokens, extra=None)             -> (logits, aux)
  prefill(params, cfg, tokens, cache, extra=None)      -> (logits, cache)
  init_decode_state(cfg, batch, cache_len, dtype)      -> cache
  decode_step(params, cfg, token, cache, pos, extra)   -> (logits, cache)
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DENSE, ENCDEC, HYBRID, MOE, SSM, VLM, ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import KVCache
from repro.models.layers import (
    Params,
    embed,
    init_embedding,
    init_mlp,
    init_rmsnorm,
    mlp,
    rmsnorm,
    unembed,
)

Extra = Optional[Dict[str, jax.Array]]


# ===========================================================================
# Per-layer-type init
# ===========================================================================
def _init_attn_layer(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    d_ff = cfg.d_ff if cfg.d_ff else 4 * cfg.d_model
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_attention(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, d_ff, dtype),
    }


def _init_moe_layer(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_attention(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "moe": moe_mod.init_moe(k2, cfg, dtype),
    }


def _init_ssm_layer(key, cfg: ModelConfig, dtype) -> Params:
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "mixer": ssm_mod.init_mamba2(key, cfg, dtype),
    }


def _init_cross_layer(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "xattn": attn.init_attention(k1, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff if cfg.d_ff else 4 * cfg.d_model, dtype),
        "gate": jnp.zeros((1,), dtype),  # llama3.2-style tanh gate
    }


def _init_encdec_dec_layer(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_attention(k1, cfg, dtype),
        "lnx": init_rmsnorm(cfg.d_model, dtype),
        "xattn": attn.init_attention(k2, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def _stack_init(init_fn, key, n: int, cfg: ModelConfig, dtype) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, cfg, dtype))(keys)


# ===========================================================================
# Model init
# ===========================================================================
def init_model(key, cfg: ModelConfig, dtype=jnp.float32) -> Params:
    cfg.validate()
    ke, kl, kx, kf = jax.random.split(key, 4)
    p: Params = {"embed": init_embedding(ke, cfg, dtype),
                 "ln_f": init_rmsnorm(cfg.d_model, dtype)}
    if cfg.kind == DENSE:
        p["layers"] = _stack_init(_init_attn_layer, kl, cfg.num_layers, cfg, dtype)
    elif cfg.kind == MOE:
        p["layers"] = _stack_init(_init_moe_layer, kl, cfg.num_layers, cfg, dtype)
    elif cfg.kind == SSM:
        p["layers"] = _stack_init(_init_ssm_layer, kl, cfg.num_layers, cfg, dtype)
    elif cfg.kind == HYBRID:
        n_groups, per = _hybrid_groups(cfg)
        flat = _stack_init(_init_ssm_layer, kl, n_groups * per, cfg, dtype)
        p["layers"] = jax.tree_util.tree_map(
            lambda x: x.reshape((n_groups, per) + x.shape[1:]), flat
        )
        p["shared_attn"] = _init_attn_layer(kx, cfg, dtype)
    elif cfg.kind == VLM:
        n_groups, per = _vlm_groups(cfg)
        flat = _stack_init(_init_attn_layer, kl, n_groups * per, cfg, dtype)
        p["layers"] = jax.tree_util.tree_map(
            lambda x: x.reshape((n_groups, per) + x.shape[1:]), flat
        )
        p["cross_layers"] = _stack_init(_init_cross_layer, kx, n_groups, cfg, dtype)
    elif cfg.kind == ENCDEC:
        p["enc_layers"] = _stack_init(
            _init_attn_layer, kx, cfg.num_encoder_layers, cfg, dtype
        )
        p["ln_enc"] = init_rmsnorm(cfg.d_model, dtype)
        p["layers"] = _stack_init(_init_encdec_dec_layer, kl, cfg.num_layers, cfg, dtype)
    else:
        raise ValueError(cfg.kind)
    return p


def _hybrid_groups(cfg: ModelConfig) -> Tuple[int, int]:
    per = cfg.attn_every
    assert cfg.num_layers % per == 0, (cfg.num_layers, per)
    return cfg.num_layers // per, per


def _vlm_groups(cfg: ModelConfig) -> Tuple[int, int]:
    """num_layers counts self+cross; each group = (per self) + 1 cross."""
    n_cross = cfg.num_layers // cfg.cross_attn_every
    n_self = cfg.num_layers - n_cross
    assert n_self % n_cross == 0, (n_self, n_cross)
    return n_cross, n_self // n_cross


# ===========================================================================
# Layer bodies (full-sequence)
# ===========================================================================
def _attn_layer_fwd(lp: Params, cfg: ModelConfig, x, *, causal=True,
                    window=0, use_kernel=False):
    h = attn.attention(
        lp["attn"], cfg, rmsnorm(lp["ln1"], x, cfg.norm_eps),
        causal=causal, window=window, use_kernel=use_kernel,
    )
    x = x + h
    x = x + mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
    return x


def _moe_layer_fwd(lp: Params, cfg: ModelConfig, x, *, window=0, use_kernel=False):
    h = attn.attention(
        lp["attn"], cfg, rmsnorm(lp["ln1"], x, cfg.norm_eps),
        causal=True, window=window, use_kernel=use_kernel,
    )
    x = x + h
    y, aux = moe_mod.moe_block(lp["moe"], cfg, rmsnorm(lp["ln2"], x, cfg.norm_eps))
    return x + y, aux


def _ssm_layer_fwd(lp: Params, cfg: ModelConfig, x, *, use_kernel=False):
    return x + ssm_mod.mamba2_block(
        lp["mixer"], cfg, rmsnorm(lp["ln1"], x, cfg.norm_eps), use_kernel=use_kernel
    )


def _cross_layer_fwd(lp: Params, cfg: ModelConfig, x, kv_src):
    g = jnp.tanh(lp["gate"].astype(jnp.float32)).astype(x.dtype)
    h = attn.cross_attention(lp["xattn"], cfg, rmsnorm(lp["ln1"], x, cfg.norm_eps), kv_src)
    x = x + g * h
    x = x + g * mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
    return x


# ===========================================================================
# Forward (training / inference logprobs) — full sequence
# ===========================================================================
def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, S) int32
    extra: Extra = None,
    *,
    use_kernel: bool = False,
    remat: bool = False,
    act_spec=None,
    return_hidden: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (B, S, padded_vocab), aux_loss scalar).

    remat=True checkpoints each scanned layer (activations recomputed in
    the backward pass) — required to fit the deep archs on 16 GB chips.
    act_spec: optional PartitionSpec for the (B, S, d) residual stream —
    Megatron-style sequence-parallel activation sharding between layers.
    """
    from repro.utils.sharding import shard_hint

    def ckpt(fn):
        return jax.checkpoint(fn) if remat else fn

    def hint(h):
        return shard_hint(h, act_spec) if act_spec is not None else h

    x = hint(embed(params["embed"], tokens))
    aux = jnp.zeros((), jnp.float32)
    w = cfg.sliding_window

    if cfg.kind == DENSE:
        def body(carry, lp):
            return hint(_attn_layer_fwd(lp, cfg, carry, window=w,
                                        use_kernel=use_kernel)), None
        x, _ = jax.lax.scan(ckpt(body), x, params["layers"])

    elif cfg.kind == MOE:
        def body(carry, lp):
            x, aux = carry
            x, a = _moe_layer_fwd(lp, cfg, x, window=w, use_kernel=use_kernel)
            return (hint(x), aux + a), None
        (x, aux), _ = jax.lax.scan(ckpt(body), (x, aux), params["layers"])

    elif cfg.kind == SSM:
        def body(carry, lp):
            return hint(_ssm_layer_fwd(lp, cfg, carry, use_kernel=use_kernel)), None
        x, _ = jax.lax.scan(ckpt(body), x, params["layers"])

    elif cfg.kind == HYBRID:
        shared = params["shared_attn"]

        def group(carry, group_params):
            def inner(c, lp):
                return _ssm_layer_fwd(lp, cfg, c, use_kernel=use_kernel), None
            c, _ = jax.lax.scan(inner, carry, group_params)
            c = _attn_layer_fwd(shared, cfg, c, window=w or 4096,
                                use_kernel=use_kernel)
            return hint(c), None
        x, _ = jax.lax.scan(ckpt(group), x, params["layers"])

    elif cfg.kind == VLM:
        assert extra is not None and "image_embeds" in extra, "VLM needs image_embeds"
        img = extra["image_embeds"].astype(x.dtype)

        def group(carry, gp):
            self_params, cross_params = gp
            def inner(c, lp):
                return _attn_layer_fwd(lp, cfg, c, window=w, use_kernel=use_kernel), None
            c, _ = jax.lax.scan(inner, carry, self_params)
            c = _cross_layer_fwd(cross_params, cfg, c, img)
            return hint(c), None
        x, _ = jax.lax.scan(ckpt(group), x, (params["layers"], params["cross_layers"]))

    elif cfg.kind == ENCDEC:
        assert extra is not None and "frame_embeds" in extra, "encdec needs frame_embeds"
        enc = encode(params, cfg, extra["frame_embeds"].astype(x.dtype),
                     use_kernel=use_kernel, remat=remat)

        def body(carry, lp):
            c = carry
            c = c + attn.attention(
                lp["attn"], cfg, rmsnorm(lp["ln1"], c, cfg.norm_eps),
                causal=True, window=w, use_kernel=use_kernel)
            c = c + attn.cross_attention(
                lp["xattn"], cfg, rmsnorm(lp["lnx"], c, cfg.norm_eps), enc)
            c = c + mlp(lp["mlp"], rmsnorm(lp["ln2"], c, cfg.norm_eps))
            return hint(c), None
        x, _ = jax.lax.scan(ckpt(body), x, params["layers"])

    else:
        raise ValueError(cfg.kind)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if return_hidden:
        return unembed(params["embed"], x), aux, x
    return unembed(params["embed"], x), aux


def encode(params: Params, cfg: ModelConfig, frame_embeds: jax.Array,
           *, use_kernel: bool = False, remat: bool = False) -> jax.Array:
    """Whisper-style encoder over precomputed (stub-frontend) frames."""
    def body(carry, lp):
        return _attn_layer_fwd(lp, cfg, carry, causal=False, use_kernel=use_kernel), None
    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, frame_embeds, params["enc_layers"])
    return rmsnorm(params["ln_enc"], x, cfg.norm_eps)


# ===========================================================================
# Decode state
# ===========================================================================
class DecodeState(NamedTuple):
    """Union cache across arch kinds; unused members are () placeholders."""
    kv: Any = ()          # stacked KVCache for self-attn layers
    ssm: Any = ()         # stacked SSMState
    cross_kv: Any = ()    # precomputed (k, v) for cross-attn layers
    shared_kv: Any = ()   # hybrid: per-application KVCache for the shared block


def _stack_kv(cfg: ModelConfig, shape0, B, W, dtype) -> KVCache:
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    def z(s):
        return jnp.zeros(shape0 + s, dtype)
    return KVCache(
        k=z((B, W, KV, hd)),
        v=z((B, W, KV, hd)),
        positions=jnp.full(shape0 + (B, W), -1, jnp.int32),
    )


def _stack_ssm_state(cfg: ModelConfig, shape0, B, dtype) -> ssm_mod.SSMState:
    s = cfg.ssm
    nh, p, n = cfg.num_ssm_heads, s.head_dim, s.state_size
    conv_ch = cfg.d_inner + 2 * n
    return ssm_mod.SSMState(
        ssm=jnp.zeros(shape0 + (B, nh, p, n), jnp.float32),
        conv=jnp.zeros(shape0 + (B, s.conv_width - 1, conv_ch), dtype),
    )


def init_decode_state(cfg: ModelConfig, B: int, cache_len: int,
                      dtype=jnp.float32) -> DecodeState:
    """cache_len: KV window (= min(seq, sliding_window) when windowed)."""
    w = cfg.sliding_window
    W = min(cache_len, w) if w else cache_len
    if cfg.kind in (DENSE, MOE):
        return DecodeState(kv=_stack_kv(cfg, (cfg.num_layers,), B, W, dtype))
    if cfg.kind == SSM:
        return DecodeState(ssm=_stack_ssm_state(cfg, (cfg.num_layers,), B, dtype))
    if cfg.kind == HYBRID:
        n_groups, per = _hybrid_groups(cfg)
        Wh = min(cache_len, w or 4096)
        return DecodeState(
            ssm=_stack_ssm_state(cfg, (n_groups, per), B, dtype),
            shared_kv=_stack_kv(cfg, (n_groups,), B, Wh, dtype),
        )
    if cfg.kind == VLM:
        n_groups, per = _vlm_groups(cfg)
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cross = (
            jnp.zeros((n_groups, B, cfg.num_image_tokens, KV, hd), dtype),
            jnp.zeros((n_groups, B, cfg.num_image_tokens, KV, hd), dtype),
        )
        return DecodeState(kv=_stack_kv(cfg, (n_groups, per), B, W, dtype),
                           cross_kv=cross)
    if cfg.kind == ENCDEC:
        KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        L = cfg.num_layers
        cross = (
            jnp.zeros((L, B, cfg.encoder_seq_len, KV, hd), dtype),
            jnp.zeros((L, B, cfg.encoder_seq_len, KV, hd), dtype),
        )
        return DecodeState(kv=_stack_kv(cfg, (L,), B, W, dtype), cross_kv=cross)
    raise ValueError(cfg.kind)


def precompute_cross_caches(params: Params, cfg: ModelConfig,
                            extra: Dict[str, jax.Array],
                            state: DecodeState) -> DecodeState:
    """Fill cross-attn K/V from image/frame embeddings (prefill-time)."""
    if cfg.kind == VLM:
        img = extra["image_embeds"]
        def per_layer(lp):
            return attn.precompute_cross_kv(lp["xattn"], img)
        ks, vs = jax.vmap(per_layer)(params["cross_layers"])
        return state._replace(cross_kv=(ks, vs))
    if cfg.kind == ENCDEC:
        enc = extra.get("encoder_out")
        if enc is None:
            enc = encode(params, cfg, extra["frame_embeds"])
        def per_layer(lp):
            return attn.precompute_cross_kv(lp["xattn"], enc)
        ks, vs = jax.vmap(per_layer)(params["layers"])
        return state._replace(cross_kv=(ks, vs))
    return state


# ===========================================================================
# Decode step (one token)
# ===========================================================================
def _attn_decode_layer(lp, cfg, x, cache: KVCache, pos, window):
    h, cache = attn.decode_attention(
        lp["attn"], cfg, rmsnorm(lp["ln1"], x, cfg.norm_eps), cache, pos,
        window=window)
    x = x + h
    x = x + mlp(lp["mlp"], rmsnorm(lp["ln2"], x, cfg.norm_eps))
    return x, cache


def decode_step(
    params: Params,
    cfg: ModelConfig,
    token: jax.Array,  # (B, 1) int32
    state: DecodeState,
    pos: jax.Array,  # scalar int32
    extra: Extra = None,
    *,
    unroll: int = 1,
    use_kernel: bool = False,
) -> Tuple[jax.Array, DecodeState]:
    """unroll>1 unrolls the layer scan — XLA can then update each layer's
    KV-cache slice in place instead of copying the cache through the
    loop's double-buffered carry (sweeps GiBs off decode temp memory at
    production cache sizes; see EXPERIMENTS.md §Perf).

    use_kernel routes the per-kind hot inner op through its Pallas
    implementation (MoE: grouped per-expert decode GEMM; SSM/hybrid: SSD
    state-update kernel); attention decode stays in XLA here."""
    x = embed(params["embed"], token)  # (B, 1, d)
    w = cfg.sliding_window

    if cfg.kind == DENSE:
        def body(carry, xs):
            lp, cache = xs
            x, c = _attn_decode_layer(lp, cfg, carry, cache, pos, w)
            return x, c
        x, kv = jax.lax.scan(body, x, (params["layers"], state.kv),
                             unroll=unroll)
        state = state._replace(kv=kv)

    elif cfg.kind == MOE:
        def body(carry, xs):
            lp, cache = xs
            x = carry
            h, cache = attn.decode_attention(
                lp["attn"], cfg, rmsnorm(lp["ln1"], x, cfg.norm_eps), cache, pos,
                window=w)
            x = x + h
            # exact top-k combine, NOT capacity dispatch: decode outputs
            # must not depend on batch composition (capacity drops do)
            y = moe_mod.moe_decode_exact(
                lp["moe"], cfg, rmsnorm(lp["ln2"], x, cfg.norm_eps),
                use_kernel=use_kernel)
            return x + y, cache
        x, kv = jax.lax.scan(body, x, (params["layers"], state.kv),
                             unroll=unroll)
        state = state._replace(kv=kv)

    elif cfg.kind == SSM:
        def body(carry, xs):
            lp, st = xs
            y, st = ssm_mod.mamba2_decode(
                lp["mixer"], cfg, rmsnorm(lp["ln1"], carry, cfg.norm_eps), st,
                use_kernel=use_kernel)
            return carry + y, st
        x, states = jax.lax.scan(body, x, (params["layers"], state.ssm))
        state = state._replace(ssm=states)

    elif cfg.kind == HYBRID:
        shared = params["shared_attn"]
        wh = w or 4096

        def group(carry, xs):
            gp, sts, kvc = xs
            def inner(c, ys):
                lp, st = ys
                y, st = ssm_mod.mamba2_decode(
                    lp["mixer"], cfg, rmsnorm(lp["ln1"], c, cfg.norm_eps), st,
                    use_kernel=use_kernel)
                return c + y, st
            c, sts = jax.lax.scan(inner, carry, (gp, sts))
            c, kvc = _attn_decode_layer(shared, cfg, c, kvc, pos, wh)
            return c, (sts, kvc)
        x, (ssm_states, shared_kv) = jax.lax.scan(
            group, x, (params["layers"], state.ssm, state.shared_kv))
        state = state._replace(ssm=ssm_states, shared_kv=shared_kv)

    elif cfg.kind == VLM:
        def group(carry, xs):
            sp, cp, kvc, (ck, cv) = xs
            def inner(c, ys):
                lp, cache = ys
                return _attn_decode_layer(lp, cfg, c, cache, pos, w)
            c, kvc = jax.lax.scan(inner, carry, (sp, kvc))
            g = jnp.tanh(cp["gate"].astype(jnp.float32)).astype(c.dtype)
            h = attn.cross_attention_cached(
                cp["xattn"], rmsnorm(cp["ln1"], c, cfg.norm_eps), ck, cv)
            c = c + g * h
            c = c + g * mlp(cp["mlp"], rmsnorm(cp["ln2"], c, cfg.norm_eps))
            return c, (kvc, (ck, cv))
        x, (kv, cross) = jax.lax.scan(
            group, x,
            (params["layers"], params["cross_layers"], state.kv, state.cross_kv))
        state = state._replace(kv=kv, cross_kv=cross)

    elif cfg.kind == ENCDEC:
        def body(carry, xs):
            lp, cache, (ck, cv) = xs
            c = carry
            h, cache = attn.decode_attention(
                lp["attn"], cfg, rmsnorm(lp["ln1"], c, cfg.norm_eps), cache, pos,
                window=w)
            c = c + h
            c = c + attn.cross_attention_cached(
                lp["xattn"], rmsnorm(lp["lnx"], c, cfg.norm_eps), ck, cv)
            c = c + mlp(lp["mlp"], rmsnorm(lp["ln2"], c, cfg.norm_eps))
            return c, (cache, (ck, cv))
        x, (kv, cross) = jax.lax.scan(
            body, x, (params["layers"], state.kv, state.cross_kv))
        state = state._replace(kv=kv, cross_kv=cross)

    else:
        raise ValueError(cfg.kind)

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x)  # (B, 1, V)
    return logits, state


# ===========================================================================
# Prefill: forward + build decode cache (used by the rollout engine)
# ===========================================================================
def prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,  # (B, S)
    state: DecodeState,
    extra: Extra = None,
) -> Tuple[jax.Array, DecodeState]:
    """Sequentially decode the prompt into the cache.

    A production engine would use a fused prefill; for the CPU-scale
    engine a ``lax.scan`` over positions is adequate and reuses the
    (well-tested) decode path.  Returns logits at the last position.
    """
    if extra is not None:
        state = precompute_cross_caches(params, cfg, extra, state)
    B, S = tokens.shape

    def step(carry, t):
        st, pos = carry
        logits, st = decode_step(params, cfg, t[:, None], st, pos, extra=None)
        return (st, pos + 1), logits[:, 0]

    (state, _), logits_seq = jax.lax.scan(
        step, (state, jnp.int32(0)), jnp.moveaxis(tokens, 1, 0)
    )
    return logits_seq[-1][:, None, :], state
