"""Attention: GQA/MHA, causal/bidirectional/sliding-window/cross + decode.

Layouts:
  hidden      (B, S, d_model)
  q           (B, S, H, hd)
  k/v         (B, S, KV, hd)
  kv cache    (B, W, KV, hd) with a parallel ``positions`` array (B, W)
              recording the absolute position held by each slot (ring
              buffer when sliding_window > 0).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    Params,
    apply_rope,
    dense_init,
    init_rmsnorm,
    rmsnorm,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(k1, (cfg.d_model, cfg.num_heads, hd), dtype),
        "wk": dense_init(k2, (cfg.d_model, cfg.num_kv_heads, hd), dtype),
        "wv": dense_init(k3, (cfg.d_model, cfg.num_kv_heads, hd), dtype),
        "wo": dense_init(k4, (cfg.num_heads, hd, cfg.d_model), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def qkv_project(
    p: Params, cfg: ModelConfig, x: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


# ---------------------------------------------------------------------------
# Core scaled-dot-product with GQA
# ---------------------------------------------------------------------------
def sdpa(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, KV, hd)
    v: jax.Array,  # (B, Sk, KV, hd)
    mask: Optional[jax.Array] = None,  # (B, 1|H, Sq, Sk) or (Sq, Sk), additive
) -> jax.Array:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    groups = H // KV
    if k.dtype != q.dtype:  # e.g. fp8-quantized KV cache storage
        k = k.astype(q.dtype)
        v = v.astype(q.dtype)
    qg = q.reshape(B, Sq, KV, groups, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None, None, None]
        elif mask.ndim == 4:  # (B, 1|H, Sq, Sk) -> (B, KV, groups, Sq, Sk)
            mask = mask.reshape(B, -1, 1, Sq, mask.shape[-1])
        scores = scores + mask
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H, hd)


def chunked_sdpa(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 512,
) -> jax.Array:
    """Query-block-chunked attention: XLA analogue of the flash kernel.

    Never materializes the (S, S) score matrix — peak score memory is
    (B, block_q, H, S).  Used for long sequences (prefill_32k, train_4k);
    exact same math as :func:`sdpa` (tests assert allclose).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    assert S % block_q == 0, (S, block_q)
    nb = S // block_q
    qb = q.reshape(B, nb, block_q, KV, G, hd)
    qb = jnp.moveaxis(qb, 1, 0)  # (nb, B, blk, KV, G, hd)
    kpos = jnp.arange(S)
    scale = jnp.sqrt(jnp.float32(hd))

    def body(_, inp):
        qi, i = inp  # (B, blk, KV, G, hd), scalar block index
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qi, k).astype(jnp.float32)
        scores = scores / scale
        if causal:
            qpos = i * block_q + jnp.arange(block_q)
            ok = kpos[None, :] <= qpos[:, None]
            if window > 0:
                ok &= kpos[None, :] > qpos[:, None] - window
            scores = scores + jnp.where(ok, 0.0, NEG_INF)[None, None, None]
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
        return None, out

    _, outs = jax.lax.scan(body, None, (qb, jnp.arange(nb)))
    outs = jnp.moveaxis(outs, 0, 1)  # (B, nb, blk, KV, G, hd)
    return outs.reshape(B, S, H, hd)


# sequences at least this long use the chunked path
CHUNKED_THRESHOLD = 2048


def causal_mask(Sq: int, Sk: int, window: int = 0) -> jax.Array:
    """Additive (Sq, Sk) mask. Assumes queries are the last Sq of Sk keys."""
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    ok = kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Full attention block (prefill / training)
# ---------------------------------------------------------------------------
def attention(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    causal: bool = True,
    positions: Optional[jax.Array] = None,
    use_rope: bool = True,
    window: int = 0,
    use_kernel: bool = False,
) -> jax.Array:
    B, S, _ = x.shape
    q, k, v = qkv_project(p, cfg, x)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if use_kernel:
        from repro.kernels import ops as kops

        out = kops.flash_attention(
            q, k, v, causal=causal, window=window
        )
    elif causal and S >= CHUNKED_THRESHOLD and S % 512 == 0:
        out = chunked_sdpa(q, k, v, causal=True, window=window)
    else:
        mask = causal_mask(S, S, window) if causal else None
        out = sdpa(q, k, v, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_attention(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    kv_src: jax.Array,
) -> jax.Array:
    """x attends to kv_src (e.g. decoder->encoder, text->image tokens)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    out = sdpa(q, k, v, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_attention_cached(
    p: Params,
    x: jax.Array,  # (B, 1, d)
    ck: jax.Array,  # (B, Senc, KV, hd) precomputed
    cv: jax.Array,
) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    out = sdpa(q, ck, cv, None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def precompute_cross_kv(p: Params, kv_src: jax.Array) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


# ---------------------------------------------------------------------------
# Decode with KV cache (ring buffer when windowed)
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jax.Array  # (B, W, KV, hd)
    v: jax.Array  # (B, W, KV, hd)
    positions: jax.Array  # (B, W) absolute position per slot, -1 = empty


def init_kv_cache(B: int, W: int, KV: int, hd: int, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((B, W, KV, hd), dtype),
        v=jnp.zeros((B, W, KV, hd), dtype),
        positions=jnp.full((B, W), -1, jnp.int32),
    )


def decode_attention(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, 1, d)
    cache: KVCache,
    pos: jax.Array,  # scalar int32 — current absolute position
    *,
    window: int = 0,
    use_rope: bool = True,
) -> Tuple[jax.Array, KVCache]:
    B = x.shape[0]
    q, k, v = qkv_project(p, cfg, x)  # (B, 1, H/KV, hd)
    posb = jnp.broadcast_to(pos, (B, 1))
    if use_rope:
        q = apply_rope(q, posb, cfg.rope_theta)
        k = apply_rope(k, posb, cfg.rope_theta)
    W = cache.k.shape[1]
    # ring-buffer slot; when un-windowed W == max_seq so pos % W == pos
    slot = pos % W
    newk = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
    newv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
    newpos = jax.lax.dynamic_update_slice(
        cache.positions, jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32), (0, slot)
    )
    # additive mask from slot validity
    valid = (newpos >= 0) & (newpos <= pos)
    if window > 0:
        valid &= newpos > pos - window
    mask = jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]  # (B,1,1,W)
    out = sdpa(q, newk, newv, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, KVCache(newk, newv, newpos)
