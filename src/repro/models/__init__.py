from repro.models.model import (  # noqa: F401
    DecodeState,
    decode_step,
    forward,
    init_decode_state,
    init_model,
    precompute_cross_caches,
    prefill,
)
