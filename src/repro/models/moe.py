"""Mixture-of-Experts with capacity-based dispatch (Switch/MaxText style).

Dispatch is scatter-based rather than one-hot-einsum based so compiled
FLOPs stay ~proportional to *active* parameters (top_k · capacity_factor),
which the roofline "useful FLOPs" ratio checks.

Expert-parallel sharding: the expert buffer (E, C, d) is annotated with a
sharding hint — E over "model" when divisible (llama4: 16e/16 = 1 expert
per group → all-to-all dispatch), otherwise C over "data".
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init, init_mlp, mlp
from repro.utils.sharding import DATA, MODEL, POD, get_active_mesh, shard_hint


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    assert cfg.moe is not None
    m = cfg.moe
    k_router, k_gate, k_up, k_down, k_shared = jax.random.split(key, 5)
    E, d, f = m.num_experts, cfg.d_model, m.expert_d_ff
    p: Params = {
        "router": dense_init(k_router, (d, E), jnp.float32),
        "gate": dense_init(k_gate, (E, d, f), dtype),
        "up": dense_init(k_up, (E, d, f), dtype),
        "down": dense_init(k_down, (E, f, d), dtype),
    }
    if m.shared_expert_d_ff:
        p["shared"] = init_mlp(k_shared, d, m.shared_expert_d_ff, dtype)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(tokens * m.top_k / m.num_experts * m.capacity_factor)
    # keep MXU-aligned when large (round UP so alignment never adds drops)
    if c >= 128:
        c = ((c + 127) // 128) * 128
    return max(c, 1)


def moe_block(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,  # (B, S, d)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux load-balance loss scalar)."""
    m = cfg.moe
    # Expert-buffer sharding (measured in EXPERIMENTS.md §Perf A1/A4):
    # capacity ("token") dim over the batch axes + d_model over "model".
    # The classic expert-parallel layout (E over "model") is also
    # supported (REPRO_MOE_EXPERT_PARALLEL=1) but measured 3x worse on
    # peak memory at 32k prefill: the scatter from token-sharded inputs
    # into an expert-sharded buffer lowers to all-to-alls whose XLA
    # implementation materializes replicated intermediates.
    import os as _os
    mesh = get_active_mesh()
    msize = mesh.shape.get(MODEL, 1) if mesh is not None else 1
    if (_os.environ.get("REPRO_MOE_EXPERT_PARALLEL")
            and m.num_experts % max(msize, 1) == 0):
        buf_spec = P(MODEL, (POD, DATA), None)
    else:
        buf_spec = P(None, (POD, DATA), MODEL)
    B, S, d = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    C = _capacity(T, cfg)

    xf = x.reshape(T, d)
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # ---- auxiliary load-balance loss (Switch eq. 4) ----
    me = jnp.mean(probs, axis=0)  # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32), axis=1), axis=0
    )
    aux = m.aux_loss_weight * E * jnp.sum(me * ce)

    # ---- capacity dispatch ----
    flat_expert = expert_idx.reshape(T * k)  # row-major: token-major order
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)  # (Tk, E)
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot  # position before me
    my_pos = jnp.sum(pos_in_expert * onehot, axis=-1)  # (Tk,)
    keep = my_pos < C
    slot = flat_expert * C + my_pos  # (Tk,) flat index into (E*C)
    slot = jnp.where(keep, slot, E * C)  # overflow bucket (dropped)

    token_ids = jnp.repeat(jnp.arange(T), k)  # (Tk,)
    buf = jnp.zeros((E * C + 1, d), x.dtype)
    buf = buf.at[slot].add(xf[token_ids] * keep[:, None].astype(x.dtype))
    buf = buf[: E * C].reshape(E, C, d)
    buf = shard_hint(buf, buf_spec)

    # ---- expert FFN (grouped matmul) ----
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["up"]
    )
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["down"])
    out_buf = shard_hint(out_buf, buf_spec)

    # ---- combine ----
    out_flat = out_buf.reshape(E * C, d)
    gathered = jnp.where(
        keep[:, None], out_flat[jnp.clip(slot, 0, E * C - 1)], 0.0
    )  # (Tk, d)
    weighted = gathered * gate_vals.reshape(T * k, 1).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[token_ids].add(weighted)

    if "shared" in p:
        y = y + mlp(p["shared"], xf)
    return y.reshape(B, S, d), aux


def moe_decode_exact(
    p: Params, cfg: ModelConfig, x: jax.Array, *, use_kernel: bool = False
) -> jax.Array:
    """Exact top-k expert combine for the serving/decode path (no aux).

    Capacity-based dispatch (:func:`moe_block`) drops tokens as a
    function of *who else is in the batch* — fine for training, fatal
    for serving, where sampling must be invariant to how the scheduler
    composed the decode batch and bit-identical to the static engine at
    temperature 0.  This path computes the exact per-token top-k
    combine: gating math identical to :func:`moe_block`, combine
    identical to :func:`moe_block_dense_ref`.  ``use_kernel`` routes the
    expert FFNs through the grouped per-expert decode GEMM
    (``kernels.ops.moe_decode``, token→expert gather layout) instead of
    the dense all-experts einsum.
    """
    m = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / jnp.clip(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    if use_kernel:
        from repro.kernels import ops as kops

        y = kops.moe_decode(xf, expert_idx, gate_vals, p["gate"], p["up"],
                            p["down"]).astype(x.dtype)
    else:
        h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["gate"])) * jnp.einsum(
            "td,edf->tef", xf, p["up"]
        )
        all_out = jnp.einsum("tef,efd->ted", h, p["down"])  # (T, E, d)
        combine = jnp.zeros(probs.shape, jnp.float32)
        combine = jax.vmap(lambda c, idx, g: c.at[idx].set(g))(
            combine, expert_idx, gate_vals)
        y = jnp.einsum("te,ted->td", combine.astype(x.dtype), all_out)
    if "shared" in p:
        y = y + mlp(p["shared"], xf)
    return y.reshape(B, S, d)


def moe_block_dense_ref(p: Params, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Oracle: dense all-experts compute, exact top-k combine (no capacity drops).

    Used by tests to validate the dispatch path (with capacity_factor high
    enough that nothing drops, outputs must match).
    """
    m = cfg.moe
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / jnp.clip(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    # all experts for all tokens
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, p["gate"])) * jnp.einsum(
        "td,edf->tef", xf, p["up"]
    )
    all_out = jnp.einsum("tef,efd->ted", h, p["down"])  # (T, E, d)
    combine = jnp.zeros(probs.shape, jnp.float32)
    combine = jax.vmap(lambda c, idx, g: c.at[idx].set(g))(combine, expert_idx, gate_vals)
    y = jnp.einsum("te,ted->td", combine.astype(x.dtype), all_out)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, m.num_experts, dtype=jnp.float32), axis=1),
        axis=0,
    )
    aux = m.aux_loss_weight * m.num_experts * jnp.sum(me * ce)
    if "shared" in p:
        y = y + mlp(p["shared"], xf)
    return y.reshape(B, S, d), aux
