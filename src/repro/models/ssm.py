"""Mamba2 (SSD — state-space duality) block: chunked train/prefill + decode.

Follows the minimal SSD formulation of arXiv:2405.21060 (single B/C group):

    h_i = exp(dt_i * A) h_{i-1} + dt_i * (B_i ⊗ x_i)
    y_i = C_i · h_i + D * x_i

Chunked algorithm: intra-chunk quadratic (attention-like) term + inter-chunk
state recurrence (lax.scan over chunks).  The perf-critical chunk kernel has
a Pallas TPU implementation in ``repro.kernels.ssd_scan``.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Params, dense_init, init_rmsnorm, rmsnorm


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def init_mamba2(key, cfg: ModelConfig, dtype) -> Params:
    s = cfg.ssm
    assert s is not None
    d, di, n = cfg.d_model, cfg.d_inner, s.state_size
    nh = cfg.num_ssm_heads
    conv_ch = di + 2 * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # in_proj -> [z(di), x(di), B(n), C(n), dt(nh)]
        "in_proj": dense_init(k1, (d, 2 * di + 2 * n + nh), dtype),
        "conv_w": dense_init(k2, (s.conv_width, conv_ch), dtype, scale=0.5),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ),  # A = -exp(A_log)
        "D": jnp.ones((nh,), jnp.float32),
        "norm": init_rmsnorm(di, dtype),
        "out_proj": dense_init(k3, (di, d), dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    di, n, nh = cfg.d_inner, cfg.ssm.state_size, cfg.num_ssm_heads
    z = proj[..., :di]
    xs = proj[..., di : 2 * di]
    B = proj[..., 2 * di : 2 * di + n]
    C = proj[..., 2 * di + n : 2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n :]
    return z, xs, B, C, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B, L, ch), w (width, ch)."""
    width = w.shape[0]
    xpad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xpad,
        w[:, None, :],  # (width, 1, ch) IO feature grouping
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


# ---------------------------------------------------------------------------
# Chunked SSD (training / prefill)
# ---------------------------------------------------------------------------
def ssd_chunked(
    x: jax.Array,  # (B, L, H, P)
    dt: jax.Array,  # (B, L, H) — post-softplus
    A: jax.Array,  # (H,) negative
    Bm: jax.Array,  # (B, L, N)
    Cm: jax.Array,  # (B, L, N)
    D: jax.Array,  # (H,)
    chunk: int,
    init_state: jax.Array = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    xc = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = Bm.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cc = Cm.reshape(b, nc, chunk, n).astype(jnp.float32)

    a = dtc * A  # (b, nc, s, h) log-decay
    a_cum = jnp.cumsum(a, axis=2)

    # intra-chunk (quadratic) term.  Mask BEFORE exp (with a large negative
    # value) so the masked upper triangle neither overflows in the forward
    # pass nor poisons the backward pass with inf·0 = NaN cotangents.
    diff = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # (b,nc,i,j,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    L = jnp.exp(jnp.where(tri, diff, -1e30))
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    y_diag = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp", CB, L, dtc, xc)

    # end-of-chunk states from within-chunk inputs
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (b,nc,s,h)
    states = jnp.einsum("bcsh,bcsh,bcsn,bcshp->bchpn", decay_to_end, dtc, Bc, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # (b, nc, h)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def scan_fn(s_prev, inp):
        dec, st = inp  # (b,h), (b,h,p,n)
        s_new = s_prev * dec[:, :, None, None] + st
        return s_new, s_prev  # emit state at chunk START

    (final_state, prev_states) = jax.lax.scan(
        scan_fn,
        init_state.astype(jnp.float32),
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b, nc, h, p, n)

    # contribution of the chunk-start state to each position
    state_decay = jnp.exp(a_cum)  # (b,nc,s,h)
    y_off = jnp.einsum("bcsn,bchpn,bcsh->bcshp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, l, h, p)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y, final_state


# ---------------------------------------------------------------------------
# Block state for decode
# ---------------------------------------------------------------------------
class SSMState(NamedTuple):
    ssm: jax.Array  # (B, H, P, N) f32
    conv: jax.Array  # (B, width-1, conv_ch)


def init_ssm_state(cfg: ModelConfig, B: int, dtype) -> SSMState:
    s = cfg.ssm
    nh, p, n = cfg.num_ssm_heads, s.head_dim, s.state_size
    conv_ch = cfg.d_inner + 2 * n
    return SSMState(
        ssm=jnp.zeros((B, nh, p, n), jnp.float32),
        conv=jnp.zeros((B, s.conv_width - 1, conv_ch), dtype),
    )


# ---------------------------------------------------------------------------
# Full block: train/prefill forward
# ---------------------------------------------------------------------------
def mamba2_block(
    p: Params, cfg: ModelConfig, x: jax.Array, *, use_kernel: bool = False
) -> jax.Array:
    """x: (B, L, d_model) -> (B, L, d_model)."""
    s = cfg.ssm
    B_, L, _ = x.shape
    di, n, nh = cfg.d_inner, s.state_size, cfg.num_ssm_heads
    proj = x @ p["in_proj"]
    z, xs, Bm, Cm, dt = _split_proj(cfg, proj)
    xBC = jnp.concatenate([xs, Bm, Cm], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
    xs, Bm, Cm = xBC[..., :di], xBC[..., di : di + n], xBC[..., di + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B_, L, nh, s.head_dim)
    # pad sequence to a chunk multiple
    chunk = min(s.chunk_size, L) if L % s.chunk_size else s.chunk_size
    pad = (-L) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    if use_kernel:
        from repro.kernels import ops as kops

        y = kops.ssd_scan(xh, dt, A, Bm, Cm, p["D"], chunk)
    else:
        y, _ = ssd_chunked(xh, dt, A, Bm, Cm, p["D"], chunk)
    y = y[:, :L].reshape(B_, L, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return y @ p["out_proj"]


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------
def mamba2_decode(
    p: Params, cfg: ModelConfig, x: jax.Array, state: SSMState, *,
    use_kernel: bool = False
) -> Tuple[jax.Array, SSMState]:
    """x: (B, 1, d_model); O(1) state update.  ``use_kernel`` routes the
    SSD state update (decay + rank-1 bump + readout) through the Pallas
    kernel ``kernels.ops.ssm_state_update``; the conv window and
    projections stay in XLA either way."""
    s = cfg.ssm
    B_ = x.shape[0]
    di, n, nh = cfg.d_inner, s.state_size, cfg.num_ssm_heads
    proj = x[:, 0] @ p["in_proj"]  # (B, ...)
    z, xs, Bm, Cm, dt = _split_proj(cfg, proj)
    xBC = jnp.concatenate([xs, Bm, Cm], axis=-1)  # (B, conv_ch)
    # conv over [conv_state, xBC]
    window = jnp.concatenate([state.conv, xBC[:, None, :]], axis=1)  # (B, w, ch)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    xs, Bm, Cm = (
        conv_out[:, :di],
        conv_out[:, di : di + n],
        conv_out[:, di + n :],
    )
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B_, nh, s.head_dim).astype(jnp.float32)
    if use_kernel:
        from repro.kernels import ops as kops

        y, new_ssm = kops.ssm_state_update(
            state.ssm, xh, dt, A, Bm.astype(jnp.float32),
            Cm.astype(jnp.float32), p["D"])
    else:
        decay = jnp.exp(dt * A)  # (B, nh)
        upd = (dt[:, :, None, None] * xh[:, :, :, None]) * Bm.astype(
            jnp.float32)[:, None, None, :]
        new_ssm = state.ssm * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", new_ssm, Cm.astype(jnp.float32))
        y = y + xh * p["D"][None, :, None]
    y = y.reshape(B_, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, SSMState(ssm=new_ssm, conv=window[:, 1:])


# ---------------------------------------------------------------------------
# Sequential oracle (for tests)
# ---------------------------------------------------------------------------
def ssd_sequential_ref(x, dt, A, Bm, Cm, D):
    """Step-by-step recurrence; slow but obviously correct."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    x, dt, Bm, Cm = (t.astype(jnp.float32) for t in (x, dt, Bm, Cm))

    def step(hstate, inp):
        xi, dti, Bi, Ci = inp
        decay = jnp.exp(dti * A)  # (b,h)
        upd = dti[:, :, None, None] * xi[:, :, :, None] * Bi[:, None, None, :]
        hstate = hstate * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", hstate, Ci)
        return hstate, y

    init = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(
        step,
        init,
        (
            jnp.moveaxis(x, 1, 0),
            jnp.moveaxis(dt, 1, 0),
            jnp.moveaxis(Bm, 1, 0),
            jnp.moveaxis(Cm, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1)
    return y + x * D[None, None, :, None]
