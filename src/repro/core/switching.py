"""Managed context switching for Temporal plan transitions (paper §3.3/§4).

A Temporal cut means two stages time-share the same accelerators; the
transition between them is a *context switch*: the outgoing stage's
state moves to host memory while the incoming stage's state moves back.
The executor used to do this with an ad-hoc ``offload()`` and no cost
feedback; this module makes the transition first-class:

  * **per-key offload** — optimizer state is colder than params, so it
    leaves the device first (``OFFLOAD_KEY_ORDER``); params move last.
    Each key is timed separately, so the records show where switch time
    actually goes.
  * **prefetch-onload** — when the incoming side's placement does not
    conflict with the running stage, its state is restored on a
    background thread (:meth:`prefetch`) overlapped with the stage's
    tail; at the cut itself the incoming side moves in only after the
    outgoing side has freed the shared devices' memory.
  * **measured feedback** — every switch is timed and the observed
    on/offload seconds are blended into the worker's :class:`CostModel`
    (``onload_time`` / ``offload_time``), so after the first executed
    iteration the Scheduler's ``_switch_cost`` charges measured reality
    instead of the profiling estimate.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence

from repro.obs import trace as _trace

# Cold keys leave the device first; anything unlisted (e.g. "params")
# follows in registration order.
OFFLOAD_KEY_ORDER = ("opt",)


@dataclass
class SwitchRecord:
    worker: str
    kind: str  # "offload" | "onload"
    key: str
    seconds: float


class ContextSwitcher:
    """Drives (and measures) the offload/onload traffic of Temporal cuts.

    ``workers`` maps plan worker names to :class:`~repro.core.worker.Worker`
    objects; ``profiles`` maps the same names to :class:`CostModel`s that
    receive the measured switch times (shared with the Scheduler, so a
    replan after iteration 1 uses measured costs)."""

    def __init__(self, workers: Dict[str, Any],
                 profiles: Optional[Dict[str, Any]] = None,
                 blend: float = 0.5):
        self.workers = workers
        self.profiles = profiles if profiles is not None else {}
        self.blend = blend
        self.records: List[SwitchRecord] = []
        # worker -> {"onload_time"|"offload_time": blended measured seconds}
        self.measured: Dict[str, Dict[str, float]] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def offload_worker(self, name: str) -> float:
        """Per-key offload of one worker; returns measured seconds."""
        w = self.workers.get(name)
        if w is None or not hasattr(w, "offload"):
            return 0.0
        state_keys = list(getattr(w, "_state", {}) or {})
        keys = [k for k in OFFLOAD_KEY_ORDER if k in state_keys]
        keys += [k for k in state_keys if k not in keys]
        total, moved_any = 0.0, False
        tr = _trace.active()
        for k in keys:
            t0 = time.perf_counter()
            moved = w.offload(keys=(k,))
            t1 = time.perf_counter()
            dt = t1 - t0
            if moved:
                moved_any = True
                total += dt
                with self._lock:
                    self.records.append(
                        SwitchRecord(name, "offload", k, dt))
                if tr is not None:
                    tr.add(f"offload:{name}", "switch", t0, t1,
                           worker=name, key=k)
        if moved_any:
            self._feedback(name, "offload_time", total)
        return total

    def onload_worker(self, name: str) -> float:
        """Restore one worker's host state; returns measured seconds."""
        w = self.workers.get(name)
        if w is None or not hasattr(w, "onload"):
            return 0.0
        t0 = time.perf_counter()
        moved = w.onload()
        t1 = time.perf_counter()
        dt = t1 - t0
        if not moved:
            return 0.0
        with self._lock:
            self.records.append(
                SwitchRecord(name, "onload", "+".join(moved), dt))
        tr = _trace.active()
        if tr is not None:
            tr.add(f"onload:{name}", "switch", t0, t1,
                   worker=name, key="+".join(moved))
        self._feedback(name, "onload_time", dt)
        return dt

    # ------------------------------------------------------------------
    def prefetch(self, names: Iterable[str]) -> threading.Thread:
        """Onload ``names`` on a background thread (overlap with the tail
        of whatever is still running); join the returned thread before
        dispatching work to these workers."""
        names = list(names)

        def run():
            tr = _trace.active()
            if tr is not None:
                # outer span marks the whole overlapped window; per-worker
                # onload spans nest inside it on the ctx-prefetch lane
                with tr.span("prefetch", "switch", workers=names):
                    for n in names:
                        self.onload_worker(n)
            else:
                for n in names:
                    self.onload_worker(n)

        th = threading.Thread(target=run, daemon=True,
                              name="ctx-prefetch")
        th.start()
        return th

    def switch(self, outgoing: Sequence[str],
               incoming: Sequence[str]) -> None:
        """One Temporal transition: offload ``outgoing``, then onload
        ``incoming``.  A Temporal cut exists precisely because the two
        sides time-share devices whose memory cannot hold both working
        sets, so the incoming side's state moves in only AFTER the
        outgoing side has freed its memory (overlapping them would peak
        at the sum of both working sets).  Safe overlap with a running
        stage's tail — when placements do not conflict — is the
        executor's :meth:`prefetch` path, not this one."""
        for n in outgoing:
            if n in incoming:
                continue  # worker survives the cut; keep it resident
            self.offload_worker(n)
        for n in incoming:
            if getattr(self.workers.get(n), "offloaded", False):
                self.onload_worker(n)

    # ------------------------------------------------------------------
    def _feedback(self, name: str, attr: str, seconds: float) -> None:
        with self._lock:
            m = self.measured.setdefault(name, {})
            prev = m.get(attr)
            val = seconds if prev is None else (
                (1.0 - self.blend) * prev + self.blend * seconds)
            m[attr] = val
            cm = self.profiles.get(name)
            if cm is not None:
                setattr(cm, attr, val)
