"""Load-balancing data channel + distributed device lock (paper §3.3/§3.5).

The channel decouples producer/consumer control flow (the foundation of
elastic pipelining) and carries the *device lock* that realizes automatic
context switching: workers sharing devices acquire the lock before using
them; acquisition priority follows the channel's data-dependency order
(producers before consumers), which rules out deadlock; onload/offload
hooks run automatically around acquisition.
"""
from __future__ import annotations

import heapq
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclass(order=True)
class _Item:
    sort_key: float
    seq: int
    data: Any = field(compare=False)
    weight: float = field(default=1.0, compare=False)


class ChannelClosed(Exception):
    pass


class Channel:
    """FIFO queue with per-item weights and pluggable load balancing.

    * ``put(data, weight=...)`` — weight drives consumer balancing.
    * ``get()`` — default FIFO; a consumer with a custom policy
      (``policy(items) -> index``) picks among queued items.
    * ``get_batch(min_items / min_weight)`` — granularity coalescing used
      by the Execution Flow Manager (elastic pipelining).
    * ``device_lock`` — see :class:`DeviceLock`.
    """

    _registry: Dict[str, "Channel"] = {}

    def __init__(self, name: str, *, capacity: int = 0,
                 offload_to_host: bool = False):
        self.name = name
        self.capacity = capacity
        self.offload_to_host = offload_to_host
        self._q: List[_Item] = []
        self._seq = 0
        self._closed = False
        self._cv = threading.Condition()
        self.device_lock = DeviceLock(f"lock[{name}]")
        # consumer-side accounting for weighted balancing
        self._consumer_load: Dict[str, float] = {}
        self.total_put = 0
        self.total_get = 0

    # -- creation ---------------------------------------------------------
    @classmethod
    def create(cls, name: str, **kw) -> "Channel":
        ch = cls(name, **kw)
        cls._registry[name] = ch
        return ch

    @classmethod
    def get_channel(cls, name: str) -> "Channel":
        return cls._registry[name]

    @classmethod
    def reset_all(cls) -> None:
        cls._registry.clear()

    # -- producer ----------------------------------------------------------
    def put(self, data: Any, weight: float = 1.0) -> None:
        with self._cv:
            if self._closed:
                raise ChannelClosed(self.name)
            while self.capacity and len(self._q) >= self.capacity:
                self._cv.wait()
            item = _Item(sort_key=self._seq, seq=self._seq, data=data,
                         weight=weight)
            self._seq += 1
            heapq.heappush(self._q, item)
            self.total_put += 1
            self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    # -- consumer ----------------------------------------------------------
    def get(self, *, consumer: str = "default",
            policy: Optional[Callable[[List[Any]], int]] = None,
            timeout: Optional[float] = None) -> Any:
        deadline = time.time() + timeout if timeout else None
        with self._cv:
            while not self._q:
                if self._closed:
                    raise ChannelClosed(self.name)
                remaining = (deadline - time.time()) if deadline else None
                if remaining is not None and remaining <= 0:
                    raise queue.Empty()
                self._cv.wait(timeout=remaining)
            if policy is not None:
                datas = [it.data for it in sorted(self._q)]
                idx = policy(datas)
                chosen = sorted(self._q)[idx]
                self._q.remove(chosen)
                heapq.heapify(self._q)
            else:
                chosen = heapq.heappop(self._q)
            self.total_get += 1
            self._consumer_load[consumer] = (
                self._consumer_load.get(consumer, 0.0) + chosen.weight)
            self._cv.notify_all()
            return chosen.data

    def get_batch(self, *, min_items: int = 1,
                  consumer: str = "default",
                  timeout: Optional[float] = None) -> List[Any]:
        """Coalesce ``min_items`` items (blocking) — granularity control."""
        out = [self.get(consumer=consumer, timeout=timeout)]
        while len(out) < min_items:
            try:
                out.append(self.get(consumer=consumer, timeout=timeout))
            except ChannelClosed:
                break
        return out

    def balanced_consumer(self) -> str:
        """Least-loaded consumer so far (weighted load balancing)."""
        if not self._consumer_load:
            return "default"
        return min(self._consumer_load, key=self._consumer_load.get)

    def qsize(self) -> int:
        with self._cv:
            return len(self._q)

    @property
    def closed(self) -> bool:
        return self._closed


class DeviceLock:
    """Distributed device lock with data-dependency acquisition priority.

    Workers register a *priority rank* derived from the workflow graph's
    topological order (parents/producers rank lower = acquire first).
    ``acquire(worker)`` blocks until the lock is free AND no lower-rank
    worker is waiting — children can only grab devices after their
    producers released them, which avoids both contention and deadlock
    (paper §3.3).  onload/offload hooks fire automatically; the lock skips
    hooks when the two workers are placed on disjoint devices (placement
    information from the Controller).
    """

    def __init__(self, name: str):
        self.name = name
        self._cv = threading.Condition()
        self._holder: Optional[str] = None
        self._waiting: Dict[str, int] = {}
        self._rank: Dict[str, int] = {}
        self._devices: Dict[str, Tuple[int, ...]] = {}
        self.acquisitions = 0
        self.switches = 0  # onload/offload pairs actually performed
        self._last_holder: Optional[str] = None

    def set_priority(self, worker: str, rank: int,
                     devices: Tuple[int, ...] = ()) -> None:
        with self._cv:
            self._rank[worker] = rank
            self._devices[worker] = tuple(devices)

    def _shares_devices(self, a: Optional[str], b: str) -> bool:
        if a is None:
            return False
        da, db = set(self._devices.get(a, ())), set(self._devices.get(b, ()))
        if not da or not db:
            return True  # unknown placement -> be safe, switch
        return bool(da & db)

    def acquire(self, worker: str, *, onload: Optional[Callable] = None,
                timeout: Optional[float] = None) -> bool:
        deadline = time.time() + timeout if timeout else None
        with self._cv:
            self._waiting[worker] = self._rank.get(worker, 0)
            try:
                while True:
                    lowest = min(self._waiting.values())
                    if (self._holder is None
                            and self._waiting[worker] == lowest):
                        break
                    remaining = (deadline - time.time()) if deadline else None
                    if remaining is not None and remaining <= 0:
                        return False
                    self._cv.wait(timeout=remaining)
                self._holder = worker
                self.acquisitions += 1
                needs_switch = (
                    self._last_holder != worker
                    and self._shares_devices(self._last_holder, worker)
                )
            finally:
                self._waiting.pop(worker, None)
        # hooks run outside the lock's critical section
        if needs_switch and onload is not None:
            onload()
            with self._cv:
                self.switches += 1
        return True

    def release(self, worker: str, *, offload: Optional[Callable] = None,
                next_shares_devices: bool = True) -> None:
        if offload is not None and next_shares_devices:
            offload()
        with self._cv:
            assert self._holder == worker, (self._holder, worker)
            self._last_holder = worker
            self._holder = None
            self._cv.notify_all()

    def __enter__(self):  # bare context-manager use (tests)
        self.acquire("anonymous")
        return self

    def __exit__(self, *exc):
        self.release("anonymous")
