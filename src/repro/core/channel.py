"""Load-balancing data channel + distributed device lock (paper §3.3/§3.5).

The channel decouples producer/consumer control flow (the foundation of
elastic pipelining) and carries the *device lock* that realizes automatic
context switching: workers sharing devices acquire the lock before using
them; acquisition priority follows the channel's data-dependency order
(producers before consumers), which rules out deadlock; onload/offload
hooks run automatically around acquisition.
"""
from __future__ import annotations

import heapq
import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace


def _chan_family(name: str) -> str:
    """Metric key for a channel: anonymous per-run channels (pipe-…,
    cycle-…) collapse onto their family so the registry stays bounded."""
    head = name.split("-", 1)[0]
    return head if head in ("pipe", "cycle") else name


def _record_block(kind: str, name: str, t0: float, t1: float,
                  depth: int) -> None:
    """One blocked put/get: span (cat=channel-wait, feeds the report's
    gap attribution) + block-seconds counter + depth gauge."""
    tr = _trace.active()
    if tr is None:
        return
    tr.add(f"{kind}-wait", "channel-wait", t0, t1, channel=name)
    reg = _metrics.active()
    if reg is not None:
        fam = _chan_family(name)
        reg.counter(f"channel/{fam}/{kind}_block_s").inc(t1 - t0)
        reg.histogram(f"channel/{fam}/{kind}_block_s_hist").observe(t1 - t0)
        reg.gauge(f"channel/{fam}/depth").set(depth)


@dataclass(order=True)
class _Item:
    sort_key: float
    seq: int
    data: Any = field(compare=False)
    weight: float = field(default=1.0, compare=False)


class ChannelClosed(Exception):
    pass


class Channel:
    """FIFO queue with per-item weights and pluggable load balancing.

    * ``put(data, weight=...)`` — weight drives consumer balancing.
    * ``get()`` — default FIFO; a consumer with a custom policy
      (``policy(items) -> index``) picks among queued items.
    * ``get_batch(min_items / min_weight)`` — granularity coalescing used
      by the Execution Flow Manager (elastic pipelining).
    * ``device_lock`` — see :class:`DeviceLock`.
    """

    _registry: Dict[str, "Channel"] = {}

    def __init__(self, name: str, *, capacity: int = 0,
                 offload_to_host: bool = False):
        self.name = name
        self.capacity = capacity
        self.offload_to_host = offload_to_host
        self._q: List[_Item] = []
        self._seq = 0
        self._closed = False
        self._cv = threading.Condition()
        self.device_lock = DeviceLock(f"lock[{name}]")
        # consumer-side accounting for weighted balancing
        self._consumer_load: Dict[str, float] = {}
        self.total_put = 0
        self.total_get = 0

    # -- creation ---------------------------------------------------------
    @classmethod
    def create(cls, name: str, **kw) -> "Channel":
        ch = cls(name, **kw)
        cls._registry[name] = ch
        return ch

    @classmethod
    def get_channel(cls, name: str) -> "Channel":
        return cls._registry[name]

    @classmethod
    def reset_all(cls) -> None:
        """Close every live channel, then drop the registry.  Closing
        first wakes any getter still blocked on an orphaned channel
        (ChannelClosed) — merely clearing the registry would leave it
        parked forever with nothing able to reach the channel again."""
        for ch in cls._registry.values():
            ch.close()
        cls._registry.clear()

    # -- producer ----------------------------------------------------------
    def put(self, data: Any, weight: float = 1.0) -> None:
        with self._cv:
            if self._closed:
                raise ChannelClosed(self.name)
            if self.capacity and len(self._q) >= self.capacity:
                # back-pressure path: time the wait only when we block
                tr = _trace.active()
                t0 = tr.clock() if tr is not None else 0.0
                while self.capacity and len(self._q) >= self.capacity:
                    self._cv.wait()
                if tr is not None:
                    _record_block("put", self.name, t0, tr.clock(),
                                  len(self._q))
            item = _Item(sort_key=self._seq, seq=self._seq, data=data,
                         weight=weight)
            self._seq += 1
            heapq.heappush(self._q, item)
            self.total_put += 1
            self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    # -- consumer ----------------------------------------------------------
    def get(self, *, consumer: str = "default",
            policy: Optional[Callable[[List[Any]], int]] = None,
            timeout: Optional[float] = None) -> Any:
        deadline = time.time() + timeout if timeout else None
        with self._cv:
            if not self._q:
                tr = _trace.active()
                t0 = tr.clock() if tr is not None else 0.0
                try:
                    while not self._q:
                        if self._closed:
                            raise ChannelClosed(self.name)
                        remaining = ((deadline - time.time())
                                     if deadline else None)
                        if remaining is not None and remaining <= 0:
                            raise queue.Empty()
                        self._cv.wait(timeout=remaining)
                finally:
                    # starvation on a closed/empty channel is still wait
                    # time the consumer paid — record it either way
                    if tr is not None:
                        _record_block("get", self.name, t0, tr.clock(),
                                      len(self._q))
            if policy is not None:
                datas = [it.data for it in sorted(self._q)]
                idx = policy(datas)
                chosen = sorted(self._q)[idx]
                self._q.remove(chosen)
                heapq.heapify(self._q)
            else:
                chosen = heapq.heappop(self._q)
            self.total_get += 1
            self._consumer_load[consumer] = (
                self._consumer_load.get(consumer, 0.0) + chosen.weight)
            self._cv.notify_all()
            return chosen.data

    def get_batch(self, *, min_items: int = 1,
                  consumer: str = "default",
                  timeout: Optional[float] = None) -> List[Any]:
        """Coalesce ``min_items`` items (blocking) — granularity control."""
        out = [self.get(consumer=consumer, timeout=timeout)]
        while len(out) < min_items:
            try:
                out.append(self.get(consumer=consumer, timeout=timeout))
            except ChannelClosed:
                break
        return out

    def balanced_consumer(self) -> str:
        """Least-loaded consumer so far (weighted load balancing)."""
        if not self._consumer_load:
            return "default"
        return min(self._consumer_load, key=self._consumer_load.get)

    def qsize(self) -> int:
        with self._cv:
            return len(self._q)

    @property
    def closed(self) -> bool:
        return self._closed


@dataclass
class VersionedItem:
    """Payload tagged with the producer's parameter version (off-policy
    asynchrony, §3.3 extension): staleness of a sample at consumption time
    is ``consumer_version - version``."""
    data: Any
    version: int
    seq: int


class StalenessExceeded(Exception):
    """A sample older than the staleness bound reached a strict consumer."""


class AsyncQueue:
    """Bounded, weight-versioned channel for cross-iteration pipelining.

    The queue realizes *bounded-staleness asynchrony* between a producer
    stage (generation, running with parameters at version ``v``) and a
    consumer stage (training, advancing the parameters to ``v+1, v+2, …``):

    * every ``put`` tags the payload with the producer's current parameter
      version; versions must be monotone non-decreasing;
    * capacity equals the staleness bound ``K`` (in flight ≤ K batches), so
      a producer that syncs weights after each put can never fall more than
      K versions behind the trainer — the producer *blocks* instead of
      racing ahead;
    * the consumer side tracks its own parameter version
      (:meth:`advance_consumer`); a ``get`` returning a sample with
      ``staleness > K`` either raises (``stale_policy='strict'``) or drops
      the sample and returns the next one (``stale_policy='drop'``).

    ``K = 0`` degenerates to fully synchronous on-policy execution: the
    producer blocks until the consumer has drained and caught up, and every
    consumed sample has staleness 0.
    """

    def __init__(self, name: str, *, staleness_bound: int = 1,
                 stale_policy: str = "strict"):
        assert staleness_bound >= 0, staleness_bound
        assert stale_policy in ("strict", "drop"), stale_policy
        self.name = name
        self.staleness_bound = staleness_bound
        self.stale_policy = stale_policy
        self._q: List[VersionedItem] = []
        self._seq = 0
        self._closed = False
        self._cv = threading.Condition()
        self._producer_version = -1
        self._consumer_version = 0
        self.total_put = 0
        self.total_get = 0
        self.dropped_stale = 0
        self.max_observed_staleness = 0

    # -- producer ----------------------------------------------------------
    def put(self, data: Any, version: int,
            timeout: Optional[float] = None) -> None:
        deadline = time.time() + timeout if timeout is not None else None
        with self._cv:
            if self._closed:
                raise ChannelClosed(self.name)
            if version < self._producer_version:
                raise ValueError(
                    f"{self.name}: version tags must be monotone "
                    f"({version} < {self._producer_version})")
            # back-pressure: block while accepting this item could let the
            # consumer observe staleness > K.  The in-flight count bounds
            # how far the trainer can advance before this sample is used:
            # capacity = max(K, 1) items (K=0 still needs one slot to hand
            # the sync batch over, freshness is enforced on the get side).
            cap = max(self.staleness_bound, 1)
            while len(self._q) >= cap and not self._closed:
                remaining = (deadline - time.time()) if deadline else None
                if remaining is not None and remaining <= 0:
                    raise queue.Full()
                self._cv.wait(timeout=remaining)
            if self._closed:
                raise ChannelClosed(self.name)
            self._q.append(VersionedItem(data=data, version=version,
                                         seq=self._seq))
            self._seq += 1
            self._producer_version = version
            self.total_put += 1
            self._cv.notify_all()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    # -- consumer ----------------------------------------------------------
    def advance_consumer(self, version: int) -> None:
        """The trainer publishes its new parameter version after an update."""
        with self._cv:
            assert version >= self._consumer_version, (
                version, self._consumer_version)
            self._consumer_version = version
            self._cv.notify_all()

    def wait_for_version(self, min_version: int,
                         timeout: Optional[float] = None) -> bool:
        """Producer gate: block until the consumer's parameter version is
        at least ``min_version``.  Generating item ``i`` only after the
        consumer reached version ``i - K`` guarantees the staleness of
        item ``i`` at training time is at most ``K``."""
        deadline = time.time() + timeout if timeout is not None else None
        with self._cv:
            while self._consumer_version < min_version and not self._closed:
                remaining = (deadline - time.time()) if deadline else None
                if remaining is not None and remaining <= 0:
                    return False
                self._cv.wait(timeout=remaining)
            return self._consumer_version >= min_version

    def get(self, timeout: Optional[float] = None) -> VersionedItem:
        """Pop the oldest item; enforce the staleness bound at hand-off."""
        deadline = time.time() + timeout if timeout is not None else None
        with self._cv:
            while True:
                while not self._q:
                    if self._closed:
                        raise ChannelClosed(self.name)
                    remaining = (deadline - time.time()) if deadline else None
                    if remaining is not None and remaining <= 0:
                        raise queue.Empty()
                    self._cv.wait(timeout=remaining)
                item = self._q.pop(0)
                self._cv.notify_all()
                staleness = self._consumer_version - item.version
                if staleness > self.staleness_bound:
                    if self.stale_policy == "drop":
                        self.dropped_stale += 1
                        continue
                    raise StalenessExceeded(
                        f"{self.name}: sample v{item.version} is "
                        f"{staleness} versions stale (bound "
                        f"{self.staleness_bound})")
                self.total_get += 1
                self.max_observed_staleness = max(
                    self.max_observed_staleness, max(staleness, 0))
                return item

    def qsize(self) -> int:
        with self._cv:
            return len(self._q)

    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def producer_version(self) -> int:
        return self._producer_version

    @property
    def consumer_version(self) -> int:
        return self._consumer_version


# Optional observer for DeviceLock wait/grant/release events (an object
# with .record(kind, lock_name, worker, rank)).  Armed by tests through
# set_lock_observer(analysis.LockOrderRecorder()) to validate the static
# concurrency model against the real interleaving; None in production.
_lock_observer: Optional[Any] = None


def set_lock_observer(observer: Optional[Any]) -> Optional[Any]:
    """Install (or clear, with None) the global DeviceLock observer.
    Returns the previous observer so callers can restore it."""
    global _lock_observer
    prev = _lock_observer
    _lock_observer = observer
    return prev


def _notify_lock(kind: str, lock: str, worker: str, rank: int) -> None:
    obs = _lock_observer
    if obs is not None:
        obs.record(kind, lock, worker, rank)


class DeviceLock:
    """Distributed device lock with data-dependency acquisition priority.

    Workers register a *priority rank* derived from the workflow graph's
    topological order (parents/producers rank lower = acquire first).
    ``acquire(worker)`` blocks until the lock is free AND no lower-rank
    worker is waiting — children can only grab devices after their
    producers released them, which avoids both contention and deadlock
    (paper §3.3).  onload/offload hooks fire automatically; the lock skips
    hooks when the two workers are placed on disjoint devices (placement
    information from the Controller).
    """

    def __init__(self, name: str):
        self.name = name
        self._cv = threading.Condition()
        self._holder: Optional[str] = None
        self._waiting: Dict[str, int] = {}
        self._rank: Dict[str, int] = {}
        self._devices: Dict[str, Tuple[int, ...]] = {}
        self.acquisitions = 0
        self.switches = 0  # onload/offload pairs actually performed
        self._last_holder: Optional[str] = None

    def set_priority(self, worker: str, rank: int,
                     devices: Tuple[int, ...] = ()) -> None:
        with self._cv:
            self._rank[worker] = rank
            self._devices[worker] = tuple(devices)

    def _shares_devices(self, a: Optional[str], b: str) -> bool:
        if a is None:
            return False
        da, db = set(self._devices.get(a, ())), set(self._devices.get(b, ()))
        if not da or not db:
            return True  # unknown placement -> be safe, switch
        return bool(da & db)

    def acquire(self, worker: str, *, onload: Optional[Callable] = None,
                timeout: Optional[float] = None) -> bool:
        deadline = time.time() + timeout if timeout else None
        with self._cv:
            self._waiting[worker] = self._rank.get(worker, 0)
            _notify_lock("wait", self.name, worker, self._waiting[worker])
            try:
                while True:
                    lowest = min(self._waiting.values())
                    if (self._holder is None
                            and self._waiting[worker] == lowest):
                        break
                    remaining = (deadline - time.time()) if deadline else None
                    if remaining is not None and remaining <= 0:
                        _notify_lock("leave", self.name, worker,
                                     self._waiting[worker])
                        return False
                    self._cv.wait(timeout=remaining)
                self._holder = worker
                self.acquisitions += 1
                _notify_lock("grant", self.name, worker,
                             self._rank.get(worker, 0))
                needs_switch = (
                    self._last_holder != worker
                    and self._shares_devices(self._last_holder, worker)
                )
            finally:
                self._waiting.pop(worker, None)
        # hooks run outside the lock's critical section
        if needs_switch and onload is not None:
            onload()
            with self._cv:
                self.switches += 1
        return True

    def release(self, worker: str, *, offload: Optional[Callable] = None,
                next_shares_devices: bool = True) -> None:
        if offload is not None and next_shares_devices:
            offload()
        with self._cv:
            assert self._holder == worker, (self._holder, worker)
            self._last_holder = worker
            self._holder = None
            _notify_lock("release", self.name, worker,
                         self._rank.get(worker, 0))
            self._cv.notify_all()

    def __enter__(self):  # bare context-manager use (tests)
        self.acquire("anonymous")
        return self

    def __exit__(self, *exc):
        self.release("anonymous")
