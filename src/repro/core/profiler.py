"""Profiler → per-worker cost models feeding the scheduler (paper §3.4).

The profiler measures each component's execution time and memory at a few
batch granularities and fits

    t(batch, devices) = base + slope · batch / devices        (SPMD workers)
    t(batch, devices) = base + slope · batch / instances      (replicated)

Simulators (Fig. 3a/3b) are captured by the same form: runtime nearly flat
in the number of environments (slope ≈ 0, large base), memory linear.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class CostModel:
    name: str
    base_time: float = 0.0  # s, per-invocation overhead
    slope_time: float = 0.0  # s per item per device
    base_mem: float = 0.0  # bytes
    mem_per_item: float = 0.0  # bytes per item
    onload_time: float = 0.0
    offload_time: float = 0.0
    # measured weight-sync cost (comm.resharding.timed_weight_sync): the
    # seconds/bytes this worker pays to refresh trainer weights when it
    # comes (back) online — charged with its onload on a Temporal cut.
    # 0 for workers that never receive synced weights.
    sync_time: float = 0.0
    sync_bytes: float = 0.0
    scalable: bool = True  # time /devices (SPMD); else replication-only
    min_devices: int = 1
    max_useful_devices: int = 10**9
    # long-tail multiplier for generation-like workers (paper Fig. 2):
    # a FULL-batch stage takes tail_factor × the mean-throughput time
    # (devices idle while the slowest responses finish).  When the stage is
    # chunked for pipelining, each chunk exposes only its share of the tail
    # (continuous-batching semantics: finished responses leave the batch,
    # downstream work overlaps the stall) — so the tail term scales with
    # `frac`, the chunk's fraction of the total batch.
    tail_factor: float = 1.0
    # serve cache layout the records were measured under ("paged-kv",
    # "paged-kv-moe", "state", ...): per-token cost curves differ by
    # layout (KV-gather attention vs constant-size state update), so a
    # fit is only transferable between workers serving the same layout
    layout: str = ""

    def time(self, batch: float, devices: int, frac: float = 1.0) -> float:
        d = max(min(devices, self.max_useful_devices), self.min_devices)
        if not self.scalable:
            d = min(d, self.max_useful_devices)
        per = self.slope_time * batch / d
        tail = per * (self.tail_factor - 1.0) * frac
        return self.base_time + per + max(tail, 0.0)

    def memory(self, batch: float) -> float:
        return self.base_mem + self.mem_per_item * batch

    def switch_cost(self) -> float:
        return self.onload_time + self.offload_time


class Profiler:
    """Measures callables at several granularities and fits CostModels."""

    def __init__(self, *, warmup: int = 1, repeats: int = 2):
        self.warmup = warmup
        self.repeats = repeats
        self.records: Dict[str, List[Tuple[int, float]]] = {}

    def measure(self, name: str, fn: Callable[[int], Any],
                batch_sizes: Sequence[int]) -> CostModel:
        pts: List[Tuple[int, float]] = []
        for b in batch_sizes:
            for _ in range(self.warmup):
                fn(b)
            ts = []
            for _ in range(self.repeats):
                t0 = time.perf_counter()
                fn(b)
                ts.append(time.perf_counter() - t0)
            pts.append((b, min(ts)))
        self.records[name] = pts
        return self.fit(name, pts)

    @staticmethod
    def fit(name: str, pts: Sequence[Tuple[int, float]],
            **kw) -> CostModel:
        xs = np.array([p[0] for p in pts], dtype=np.float64)
        ys = np.array([p[1] for p in pts], dtype=np.float64)
        if len(pts) >= 2 and np.ptp(xs) > 0:
            slope, base = np.polyfit(xs, ys, 1)
            slope = max(float(slope), 0.0)
            base = max(float(base), 0.0)
        else:
            base, slope = float(ys.mean()), 0.0
        return CostModel(name=name, base_time=base, slope_time=slope, **kw)


def fit_tail_factor(service_times: Sequence[float]) -> float:
    """Measured long-tail multiplier from per-request completion times.

    A static-batched stage lasts as long as its slowest request while
    useful throughput tracks the mean, so the stall multiplier is
    ``max / mean`` (same definition as
    ``benchmarks.common.tail_factor_from_lengths``, but measured from an
    engine's request log instead of assumed from a length model).
    """
    arr = np.asarray(list(service_times), dtype=np.float64)
    arr = arr[arr > 0]
    if arr.size == 0 or arr.mean() <= 0:
        return 1.0
    return float(arr.max() / arr.mean())


def engine_cost_model(name: str,
                      records: Sequence[Tuple[int, float]],
                      **kw) -> CostModel:
    """Fit a CostModel from a serving engine's per-request records.

    ``records``: (tokens_generated, service_seconds) per completed
    request, e.g. ``PagedEngine.pop_request_records()``.  base/slope
    come from the tokens-vs-time fit; ``tail_factor`` is *measured* from
    the completion-time spread rather than assumed.
    """
    recs = [(int(n), float(t)) for n, t in records if t > 0]
    if not recs:
        return CostModel(name=name, **kw)
    cm = Profiler.fit(name, recs, **kw)
    cm.tail_factor = fit_tail_factor([t for _, t in recs])
    return cm


def measure_onoffload(worker) -> Tuple[float, float]:
    """Time a real offload/onload round-trip of a worker's state."""
    t0 = time.perf_counter()
    worker.offload()
    t_off = time.perf_counter() - t0
    t0 = time.perf_counter()
    worker.onload()
    t_on = time.perf_counter() - t0
    return t_on, t_off


# ---------------------------------------------------------------------------
# Reference analytic profiles mirroring the paper's measurements — used by
# the event-simulator benchmarks (Figs. 2, 3, 8–13 analogues).
# ---------------------------------------------------------------------------
def paper_like_profiles(*, gen_tail: float = 8.0) -> Dict[str, CostModel]:
    """Shapes (not absolute values) follow the paper:
      generation: memory-bandwidth bound, long-tailed, scales with devices
      inference:  prefill-only, compute bound, cheaper than generation
      training:   ~1/3 of generation time (§2.2), heavy memory
      simulator:  runtime ~flat in #envs, low utilization, memory linear
      reward:     trivial rule-based
    """
    return {
        "rollout": CostModel("rollout", base_time=0.5, slope_time=0.04,
                             base_mem=30e9, mem_per_item=40e6,
                             onload_time=2.0, offload_time=1.5,
                             tail_factor=gen_tail),
        "inference": CostModel("inference", base_time=0.2, slope_time=0.008,
                               base_mem=25e9, mem_per_item=15e6,
                               onload_time=1.5, offload_time=1.0),
        "training": CostModel("training", base_time=0.8, slope_time=0.013,
                              base_mem=60e9, mem_per_item=25e6,
                              onload_time=3.0, offload_time=2.5),
        "simulator": CostModel("simulator", base_time=1.2, slope_time=0.0008,
                               base_mem=2e9, mem_per_item=50e6,
                               onload_time=0.5, offload_time=0.4,
                               scalable=False, max_useful_devices=8),
        "reward": CostModel("reward", base_time=0.02, slope_time=1e-4,
                            base_mem=1e8, mem_per_item=1e4),
    }
