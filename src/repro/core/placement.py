"""Cluster + flexible device allocation (paper §4).

Ray only offers packed/spread placement; RLinf lets any worker claim any
device(s) by global ID.  We model the cluster as a flat list of global
device IDs (node i, local device j -> global id i*devices_per_node + j)
with explicit allocate/free and an occupancy map so temporal multiplexing
(two workers on the same device at different times) is expressible.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple


@dataclass
class Cluster:
    num_nodes: int = 1
    devices_per_node: int = 8
    _allocations: Dict[str, List[int]] = field(default_factory=dict)
    # device id -> owner for devices held EXCLUSIVELY; persisted so later
    # allocations (exclusive or not) cannot land on them
    _exclusive: Dict[int, str] = field(default_factory=dict)
    _cursor: int = 0

    @property
    def num_devices(self) -> int:
        return self.num_nodes * self.devices_per_node

    def node_of(self, global_id: int) -> int:
        return global_id // self.devices_per_node

    # -- liveness -----------------------------------------------------------
    def device_alive(self, global_id: int) -> bool:
        """Whether a device can host new allocations.  The base cluster
        never loses devices; SimulatedCluster overrides this to model
        host failure (launch.cluster)."""
        return True

    def available_devices(self) -> List[int]:
        """Global IDs of live devices — the universe planning and
        allocation draw from after a failure shrinks the cluster."""
        return [i for i in range(self.num_devices) if self.device_alive(i)]

    # -- allocation ---------------------------------------------------------
    def allocate(self, owner: str, count: int,
                 *, device_ids: Optional[Sequence[int]] = None,
                 exclusive: bool = False) -> List[int]:
        """Allocate ``count`` devices; arbitrary global IDs may be pinned.

        Non-exclusive allocations may overlap each other (temporal
        multiplexing), but exclusivity is enforced in BOTH directions: an
        exclusive request rejects devices with any current occupant, and
        every request rejects devices already held exclusively.  Auto
        assignment (``device_ids=None``) skips ineligible devices instead
        of failing on them.
        """
        occ = self.occupancy()

        def _reject(i: int) -> Optional[str]:
            if not self.device_alive(i):
                return f"device {i} is on a failed host"
            if i in self._exclusive and self._exclusive[i] != owner:
                return (f"device {i} is exclusively held by "
                        f"'{self._exclusive[i]}'")
            if exclusive and occ.get(i):
                return (f"device {i} already occupied by "
                        f"{occ[i]} (exclusive requested)")
            return None

        if device_ids is not None:
            ids = list(device_ids)
            assert len(ids) == count
            for i in ids:
                msg = _reject(i)
                if msg:
                    raise ValueError(msg)
        else:
            ids = []
            for off in range(self.num_devices):
                i = (self._cursor + off) % self.num_devices
                if _reject(i) is None:
                    ids.append(i)
                    if len(ids) == count:
                        break
            if len(ids) < count:
                raise ValueError(
                    f"cannot allocate {count} device(s) for '{owner}': "
                    f"only {len(ids)} eligible")
            self._cursor = (ids[-1] + 1) % self.num_devices
        if exclusive:
            for i in ids:
                self._exclusive[i] = owner
        self._allocations.setdefault(owner, []).extend(ids)
        return ids

    def free(self, owner: str) -> None:
        self._allocations.pop(owner, None)
        self._exclusive = {i: o for i, o in self._exclusive.items()
                           if o != owner}

    def occupancy(self) -> Dict[int, List[str]]:
        occ: Dict[int, List[str]] = {}
        for owner, ids in self._allocations.items():
            for i in ids:
                occ.setdefault(i, []).append(owner)
        return occ

    def collocated(self, a: str, b: str) -> bool:
        da = set(self._allocations.get(a, ()))
        db = set(self._allocations.get(b, ()))
        return bool(da & db)


class PlacementManager:
    """Realizes an ExecutionPlan's placement on a Cluster (paper §4).

    The plan's placement column used to be advisory — workers kept the
    device slices hard-coded at construction.  This manager makes it
    binding: :meth:`apply` diffs the planned placement against the
    cluster's current allocations, frees owners whose slices changed (or
    who left the plan), allocates the planned slices, and rebinds each
    live worker via ``Worker.bind_devices`` (rebuilding its mesh and
    re-placing its state through the resharding data plane).

    Invariants:
      * idempotent — applying the same plan twice is a no-op;
      * no stale entries — after ``apply``, every managed owner's
        ``Cluster._allocations`` entry equals the plan's slice exactly;
        owners managed by a previous plan but absent from the new one
        are freed;
      * foreign owners (never placed by this manager and not named in
        the plan) are left untouched.
    """

    def __init__(self, cluster: Cluster):
        self.cluster = cluster
        self._managed: Set[str] = set()

    def apply(self, plan, workers: Optional[Dict[str, object]] = None
              ) -> Dict[str, List[int]]:
        """Diff + rebind; returns {worker: new_devices} for every worker
        whose binding actually changed."""
        placement: Dict[str, List[int]] = dict(
            plan.placement if hasattr(plan, "placement") else plan)
        workers = workers or {}
        # Scope: everything this manager ever placed, plus the plan's
        # names (adopting same-named construction-time allocations).
        scope = self._managed | set(placement)
        for owner in list(self.cluster._allocations):
            if owner not in scope:
                continue
            cur = sorted(self.cluster._allocations.get(owner, []))
            if cur != sorted(placement.get(owner, [])):
                self.cluster.free(owner)
        changed: Dict[str, List[int]] = {}
        for name, devs in placement.items():
            if devs and name not in self.cluster._allocations:
                self.cluster.allocate(name, len(devs),
                                      device_ids=list(devs))
            w = workers.get(name)
            if w is not None and tuple(devs) != tuple(
                    getattr(w, "devices", ())):
                w.bind_devices(devs)
                changed[name] = list(devs)
        self._managed = {n for n, d in placement.items() if d}
        return changed

    def release_all(self) -> None:
        """Free every allocation this manager placed — the teardown half
        of failure recovery, guaranteeing no stale entries survive into
        the re-placement."""
        for owner in self._managed:
            self.cluster.free(owner)
        self._managed = set()


def split_devices(n_devices: int, shares: Sequence[int]) -> List[List[int]]:
    """Partition [0..n) into contiguous groups of the given sizes."""
    assert sum(shares) <= n_devices, (shares, n_devices)
    out, cur = [], 0
    for s in shares:
        out.append(list(range(cur, cur + s)))
        cur += s
    return out
