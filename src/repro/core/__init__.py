from repro.core.channel import (  # noqa: F401
    AsyncQueue,
    Channel,
    ChannelClosed,
    DeviceLock,
    StalenessExceeded,
    VersionedItem,
)
from repro.core.controller import Controller, ExecutionPlan  # noqa: F401
from repro.core.faults import (  # noqa: F401
    FaultInjector,
    FaultSpec,
    HeartbeatMonitor,
    InjectedFault,
)
from repro.core.flowgraph import (  # noqa: F401
    FlowGraph,
    GraphTracer,
    TraceEvent,
    cycle_node_name,
)
from repro.core.pipeline import (  # noqa: F401
    AsyncPipelineDriver,
    CycleSpec,
    ExecutionFlowManager,
    coalesce,
    merge_cycle_chunks,
    split_batch,
    stack_cycle_steps,
)
from repro.core.placement import Cluster, PlacementManager, split_devices  # noqa: F401
from repro.core.profiler import CostModel, Profiler, paper_like_profiles  # noqa: F401
from repro.core.scheduler import (  # noqa: F401
    Async,
    Leaf,
    Pipelined,
    Scheduler,
    SchedulerConfig,
    Temporal,
    async_makespan,
    collocated_schedule,
    disaggregated_schedule,
)
from repro.core.simulator import SimResult, Simulator  # noqa: F401
from repro.core.switching import ContextSwitcher, SwitchRecord  # noqa: F401
from repro.core.worker import FutureHandle, Worker, WorkerFailure, WorkerGroup  # noqa: F401
