"""Profiling-guided scheduling policy — Algorithm 1 of the paper.

Recursively partitions the (cycle-collapsed) workflow DAG along s-t cuts,
evaluating for each cut:

  temporal (shared devices):   T = T_s + T_t + context-switch overhead
  spatial  (disjoint devices): T = T_critical + (M/m − 1) · T_bottleneck
                               over device splits N_s + N_t = N and data
                               granularities m | M

memoized on (subgraph, devices, batch).  Leaves return the profiled cost
model's time.  The result is a Schedule tree that the executor/simulator
can run directly.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.flowgraph import FlowGraph
from repro.core.profiler import CostModel


# ---------------------------------------------------------------------------
# Schedule tree
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Leaf:
    worker: str
    devices: int
    batch: int
    # Collapsed-cycle realization, RECORDED on the plan (paper §3.4) so
    # the simulator and the executor honor what the scheduler chose
    # instead of re-deriving it (and possibly disagreeing):
    #   None          — plain single-worker leaf;
    #   "collocated"  — cycle members alternate per step on the leaf's
    #                   shared devices;
    #   "hybrid"      — members pinned to disjoint device shares
    #                   (member_devices, ordered like the sorted member
    #                   tuple) and fine-grained-pipelined per step over
    #                   `cycle_chunks` env chunks (double-buffering).
    cycle_mode: Optional[str] = None
    member_devices: Optional[Tuple[int, ...]] = None
    cycle_chunks: int = 2

    def pretty(self, indent: str = "") -> str:
        extra = ""
        if self.cycle_mode:
            share = ("+".join(map(str, self.member_devices))
                     if self.member_devices else "shared")
            extra = f", cycle={self.cycle_mode}:{share}"
        return f"{indent}{self.worker}[n={self.devices}, b={self.batch}{extra}]"


@dataclass(frozen=True)
class Temporal:
    """G_s then G_t on the SAME devices (context switch between)."""
    s: "Schedule"
    t: "Schedule"
    switch_cost: float = 0.0

    def pretty(self, indent: str = "") -> str:
        return (f"{indent}Temporal(switch={self.switch_cost:.2f}s)\n"
                f"{self.s.pretty(indent + '  ')}\n"
                f"{self.t.pretty(indent + '  ')}")


@dataclass(frozen=True)
class Pipelined:
    """G_s and G_t on DISJOINT devices, chunked at granularity m."""
    s: "Schedule"
    t: "Schedule"
    granularity: int
    n_s: int
    n_t: int

    def pretty(self, indent: str = "") -> str:
        return (f"{indent}Pipelined(m={self.granularity}, "
                f"N={self.n_s}+{self.n_t})\n"
                f"{self.s.pretty(indent + '  ')}\n"
                f"{self.t.pretty(indent + '  ')}")


@dataclass(frozen=True)
class Async:
    """Cross-ITERATION overlap (bounded-staleness off-policy pipelining).

    ``s`` (producer: generation side) and ``t`` (consumer: training side)
    run on DISJOINT device shares; iteration ``i``'s producer may start as
    soon as the consumer has finished iteration ``i - depth - 1``, so
    rollouts are generated with parameters up to ``depth`` versions stale.
    ``depth = 0`` degenerates to strictly synchronous execution (producer
    waits for every update).  Costed over an ``iterations`` horizon — the
    steady-state increment is the bottleneck side, not the sum.
    """
    s: "Schedule"
    t: "Schedule"
    depth: int        # staleness bound K (versions)
    iterations: int   # horizon the schedule was costed over
    n_s: int
    n_t: int

    def pretty(self, indent: str = "") -> str:
        return (f"{indent}Async(K={self.depth}, iters={self.iterations}, "
                f"N={self.n_s}+{self.n_t})\n"
                f"{self.s.pretty(indent + '  ')}\n"
                f"{self.t.pretty(indent + '  ')}")


Schedule = object  # Leaf | Temporal | Pipelined | Async


def leaves(s: Schedule) -> List[Leaf]:
    if isinstance(s, Leaf):
        return [s]
    return leaves(s.s) + leaves(s.t)


def cycle_hybrid_time(profiles, members: Sequence[str],
                      split: Sequence[int], batch: float, frac: float,
                      chunks: int) -> float:
    """Cost of the HYBRID realization of a collapsed cycle: members on
    disjoint device shares, fine-grained-pipelined over ``chunks`` env
    chunks.  Each member executes every chunk every step, so its device
    occupancy per step is ``chunks * t(batch/chunks)`` — a member whose
    cost is FLAT in the chunk size (a CPU-bound sim, Fig. 3) pays the
    chunk count, which is exactly why collocation wins the LIBERO-like
    regime; a member whose cost scales with envs (GPU-parallel sim,
    generation) keeps its total and hides behind the slower side.
    Steady-state throughput is the slowest member's occupancy; the other
    members' one-chunk fill is the (tiny) warmup term.  The single cost
    semantics shared by Scheduler._leaf and Simulator._leaf_time."""
    C = max(chunks, 1)
    tc = [profiles[m].time(batch / C, n, frac / C)
          for m, n in zip(members, split)]
    occupancy = max(C * t for t in tc)
    warmup = (sum(tc) - max(tc)) * min(1.0 / max(batch, 1), 1.0)
    return occupancy + warmup


def async_makespan(t_s: float, t_t: float, depth: int,
                   iterations: int) -> float:
    """Analytic horizon makespan of an Async schedule — the recurrence the
    event simulator replays span-by-span (they must agree exactly):

        s_end[i] = max(s_end[i-1], t_end[i-depth-1]) + t_s
        t_end[i] = max(s_end[i], t_end[i-1]) + t_t

    The ``t_end[i-depth-1]`` term is the staleness back-pressure: the
    producer may run at most ``depth`` updates ahead of the trainer.
    """
    if iterations < 1:
        raise ValueError(f"iterations must be >= 1, got {iterations}")
    s_end = [0.0] * iterations
    t_end = [0.0] * iterations
    for i in range(iterations):
        gate = t_end[i - depth - 1] if i - depth - 1 >= 0 else 0.0
        s_prev = s_end[i - 1] if i >= 1 else 0.0
        s_end[i] = max(s_prev, gate) + t_s
        t_prev = t_end[i - 1] if i >= 1 else 0.0
        t_end[i] = max(s_end[i], t_prev) + t_t
    return t_end[-1]


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------
@dataclass
class SchedulerConfig:
    total_batch: int = 256
    # candidate data granularities as fractions of the total batch
    granularity_divisors: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    # candidate device splits are multiples of this quantum (e.g. a node
    # of 8 GPUs); 1 = any split
    device_quantum: int = 1
    # pipeline chunk sizes must be multiples of this — the data atomicity
    # unit (e.g. a GRPO group: group-relative advantages are undefined if
    # a chunk boundary splits a group); 1 = any chunk size
    chunk_multiple: int = 1
    # memory capacity per device (bytes); 0 disables feasibility checks
    device_memory: float = 0.0
    # force the realization of collapsed cycle nodes: None = cheaper of
    # the two, "collocated" = members alternate on shared devices,
    # "hybrid" = members on disjoint shares, fine-grained-pipelined
    # (falls back to collocated when the leaf has fewer devices than
    # members).  The fixed settings are the paper's Fig.-9 baselines.
    cycle_mode: Optional[str] = None
    # env-chunk count of the hybrid realization's per-step pipeline
    # (2 = double-buffered obs/action queues); priced by
    # cycle_hybrid_time and recorded on the Leaf for the executor
    cycle_chunks: int = 2
    # --- async off-policy dimension (cross-iteration overlap) ---
    # candidate staleness bounds K searched by schedule_async; 0 = sync
    async_depths: Tuple[int, ...] = (0, 1, 2, 4)
    # freshness cost: stale samples need importance correction and carry
    # less learning signal per sample; modeled as a fractional throughput
    # tax per version of staleness (cost *= 1 + penalty * K).
    staleness_penalty: float = 0.03
    # --- hierarchical planning (scale-out) ---
    # Partition the device pool into host groups and plan inter-group
    # splits coarsely (whole host groups, geometrically spaced) while
    # any subproblem that fits inside one host group is still planned
    # exactly.  None = auto: hierarchical kicks in once n_devices
    # exceeds `hierarchical_threshold`; True/False force it.
    hierarchical: Optional[bool] = None
    host_group_size: int = 8
    hierarchical_threshold: int = 64


class Scheduler:
    def __init__(self, profiles: Dict[str, CostModel],
                 cfg: Optional[SchedulerConfig] = None):
        self.profiles = profiles
        self.cfg = cfg or SchedulerConfig()
        self._memo: Dict[Tuple, Tuple[float, Schedule]] = {}
        # per-subgraph cut decompositions (s_set, t_set, gs, gt): st_cuts
        # enumeration + subgraph copies are independent of (n, batch), so
        # they are computed once per distinct node set, not once per state
        self._cuts: Dict[FrozenSet[str], List[Tuple]] = {}
        self._work: Dict[Tuple, float] = {}
        self.evaluated_cuts = 0
        self._hier = bool(self.cfg.hierarchical)

    def _set_hierarchical(self, n_devices: int) -> None:
        """Resolve the hierarchical flag for one planning call: forced by
        cfg.hierarchical, else auto once the pool outgrows the threshold."""
        if self.cfg.hierarchical is None:
            self._hier = n_devices > self.cfg.hierarchical_threshold
        else:
            self._hier = bool(self.cfg.hierarchical)

    # -- public -----------------------------------------------------------
    def schedule(self, graph: FlowGraph, n_devices: int,
                 total_batch: Optional[int] = None
                 ) -> Tuple[float, Schedule]:
        """Algorithm 1 entry point: collapse cycles then recurse."""
        M = total_batch or self.cfg.total_batch
        self._total = M
        self._set_hierarchical(n_devices)
        dag, members = graph.condense()
        self._members = members
        return self._find(dag, n_devices, M)

    def schedule_async(self, graph: FlowGraph, n_devices: int,
                       total_batch: Optional[int] = None,
                       iterations: int = 8,
                       depths: Optional[Sequence[int]] = None
                       ) -> Tuple[float, Schedule]:
        """Extended search over (temporal, spatial, async_depth).

        For ``K = 0`` the candidate is the plain Algorithm-1 schedule run
        ``iterations`` times back-to-back.  For ``K >= 1`` every s-t cut
        and device split becomes an :class:`Async` candidate: the producer
        side keeps generating under stale parameters while the consumer
        side trains, gated so staleness never exceeds K.  Candidates are
        SELECTED by ``async_makespan * (1 + staleness_penalty * K)`` — the
        freshness tax makes ever-larger K unattractive once the bottleneck
        stage is saturated — but the RETURNED time is always the untaxed
        horizon makespan, directly comparable to ``schedule()`` times and
        to the event simulator's replay.  The schedule is an
        :class:`Async` node when some K >= 1 wins, otherwise the plain
        Algorithm-1 schedule (run ``iterations`` times back-to-back).
        """
        if iterations < 1:
            raise ValueError(f"iterations must be >= 1, got {iterations}")
        M = total_batch or self.cfg.total_batch
        depths = tuple(depths if depths is not None
                       else self.cfg.async_depths)
        self._total = M
        self._set_hierarchical(n_devices)
        dag, members = graph.condense()
        self._members = members

        # K = 0 baseline: the unconstrained Algorithm-1 plan, repeated.
        t_sync, s_sync = self._find(dag, n_devices, M)
        best_obj: float = t_sync * iterations  # selection objective
        best_t: float = t_sync * iterations    # untaxed makespan
        best_s: Schedule = s_sync
        for K in depths:
            if K < 1:
                continue
            for s_set, t_set in dag.st_cuts():
                gs, gt = dag.subgraph(s_set), dag.subgraph(t_set)
                for n_s in self._device_splits(n_devices, gs, gt, M):
                    n_t = n_devices - n_s
                    if not self._fits(s_set, n_s, M) or \
                       not self._fits(t_set, n_t, M):
                        continue
                    ts, ss = self._find(gs, n_s, M)
                    tt, st = self._find(gt, n_t, M)
                    span = async_makespan(ts, tt, K, iterations)
                    cand = span * (1.0 + self.cfg.staleness_penalty * K)
                    if cand < best_obj:
                        best_obj = cand
                        best_t = span
                        best_s = Async(ss, st, K, iterations, n_s, n_t)
        return best_t, best_s

    # -- Algorithm 1: FindSchedule -----------------------------------------
    def _find(self, g: FlowGraph, n: int, batch: int
              ) -> Tuple[float, Schedule]:
        key = (g.key(), n, batch, self._hier)
        if key in self._memo:
            return self._memo[key]

        nodes = g.nodes
        if len(nodes) == 1:
            out = self._leaf(nodes[0], n, batch)
            self._memo[key] = out
            return out

        cuts = self._cuts.get(key[0])
        if cuts is None:
            cuts = [(s_set, t_set, g.subgraph(s_set), g.subgraph(t_set))
                    for s_set, t_set in g.st_cuts()]
            self._cuts[key[0]] = cuts

        best_t, best_s = math.inf, None
        for s_set, t_set, gs, gt in cuts:
            self.evaluated_cuts += 1

            # --- temporal: same devices, sequential, context switch ---
            ts, ss = self._find(gs, n, batch)
            tt, st = self._find(gt, n, batch)
            switch = self._switch_cost(gs, gt)
            cand = ts + tt + switch
            if cand < best_t:
                best_t, best_s = cand, Temporal(ss, st, switch)

            # --- spatial: disjoint devices, pipelined ---
            for n_s in self._device_splits(n, gs, gt, batch):
                n_t = n - n_s
                for m in self._granularities(batch):
                    ts_m, ss_m = self._find(gs, n_s, m)
                    tt_m, st_m = self._find(gt, n_t, m)
                    if not self._fits(s_set, n_s, m) or \
                       not self._fits(t_set, n_t, m):
                        continue
                    chunks = batch // m
                    t_crit = ts_m + tt_m  # warmup + cooldown
                    t_bot = max(ts_m, tt_m)
                    cand = t_crit + (chunks - 1) * t_bot
                    if cand < best_t:
                        best_t = cand
                        best_s = Pipelined(ss_m, st_m, m, n_s, n_t)

        self._memo[key] = (best_t, best_s)
        return best_t, best_s

    # -- leaves -------------------------------------------------------------
    def _leaf(self, node: str, n: int, batch: int) -> Tuple[float, Schedule]:
        members = getattr(self, "_members", {}).get(node, (node,))
        frac = batch / max(getattr(self, "_total", batch), 1)
        if len(members) == 1:
            prof = self.profiles[node]
            return prof.time(batch, n, frac), Leaf(node, n, batch)
        # Collapsed cycle (paper §3.4): two realizations are costed and the
        # cheaper chosen (unless cfg.cycle_mode forces one) —
        #  (a) shared devices, members alternate (collocated cycle):
        #      costs add, each member sees all n devices;
        #  (b) disjoint devices, members pipeline against each other
        #      (the paper's hybrid mode for sim<->generation): the cycle
        #      iterates, so throughput is set by the slowest member on its
        #      own device share; cost ~= max_i t_i + warmup of the others.
        # The winning realization (and its device split) is RECORDED on
        # the Leaf so the simulator and the executor run exactly what was
        # costed.
        t_shared = sum(self.profiles[m].time(batch, n, frac)
                       for m in members)
        C = self.cfg.cycle_chunks
        t_hybrid, hybrid_split = math.inf, None
        if len(members) >= 2 and n >= len(members):
            for split in self._member_splits(members, n):
                cand = cycle_hybrid_time(self.profiles, members, split,
                                         batch, frac, C)
                if cand < t_hybrid:
                    t_hybrid, hybrid_split = cand, tuple(split)
        forced = self.cfg.cycle_mode
        if hybrid_split is not None and (
                forced == "hybrid" or (forced is None and t_hybrid < t_shared)):
            return t_hybrid, Leaf(node, n, batch, cycle_mode="hybrid",
                                  member_devices=hybrid_split,
                                  cycle_chunks=C)
        return t_shared, Leaf(node, n, batch, cycle_mode="collocated")

    def _member_splits(self, members, n: int):
        """Small search over device partitions among cycle members."""
        k = len(members)
        if k == 2:
            caps = [self.profiles[m].max_useful_devices for m in members]
            for a in {max(n // 4, 1), max(n // 2, 1), min(caps[0], n - 1),
                      max(n - caps[1], 1)}:
                if 1 <= a < n:
                    yield (a, n - a)
        else:
            even = max(n // k, 1)
            yield tuple(even for _ in members)

    def _switch_cost(self, gs: FlowGraph, gt: FlowGraph) -> float:
        """Only the workers at the boundary actually swap at the cut: the
        sinks of G_s offload, the sources of G_t onload — interior nodes'
        switches are charged by the nested recursion.  A source that
        receives trainer weights also pays its measured weight-sync cost
        (``CostModel.sync_time``) when it comes online."""
        sinks = [n for n in gs.nodes if not list(gs.g.successors(n))]
        sources = [n for n in gt.nodes if not list(gt.g.predecessors(n))]
        off = sum(self.profiles[w].offload_time
                  for n_ in sinks for w in self._members.get(n_, (n_,)))
        on = sum(self.profiles[w].onload_time + self.profiles[w].sync_time
                 for n_ in sources for w in self._members.get(n_, (n_,)))
        return off + on

    def _device_splits(self, n: int, gs: Optional[FlowGraph] = None,
                       gt: Optional[FlowGraph] = None,
                       batch: Optional[int] = None) -> List[int]:
        if self._hier and n > self.cfg.host_group_size:
            return self._coarse_splits(n, gs, gt, batch)
        q = self.cfg.device_quantum
        return [k for k in range(q, n, q)]

    def _coarse_splits(self, n: int, gs: Optional[FlowGraph],
                       gt: Optional[FlowGraph],
                       batch: Optional[int]) -> List[int]:
        """Inter-group split candidates for hierarchical planning.

        Devices are partitioned in whole host groups at an adaptive
        quantum q (the group size G doubled until at most ~8 group-sized
        candidates remain), and only a handful of splits are tried: the
        work-proportional point between the two sides (near-optimal for
        a pipeline), its two grid neighbours, the even split, and the
        two extremes.  All candidates lie on a closed nested grid of
        group multiples, so the memoized recursion reaches O(log n)
        levels of a few device counts each instead of O(n) — that is
        what keeps `schedule()` sub-second at 256-1024 devices.  Once a
        subproblem's pool drops to <= one host group, `_device_splits`
        falls back to the exact enumeration (intra-group planning at
        `device_quantum`)."""
        q = max(self.cfg.host_group_size, self.cfg.device_quantum, 1)
        while n > 8 * q:
            q *= 2
        cands = {q, n - q, (n // (2 * q)) * q}
        if gs is not None and gt is not None:
            b = batch if batch is not None else self.cfg.total_batch
            ws = self._graph_work(gs, b)
            wt = self._graph_work(gt, b)
            prop = int(round(n * ws / max(ws + wt, 1e-12) / q)) * q
            cands.update((prop - q, prop, prop + q))
        return sorted(c for c in cands if 0 < c < n)

    def _graph_work(self, g: FlowGraph, batch: int) -> float:
        """Single-device total work of a subgraph — the proportionality
        weight the coarse split candidates are centred on."""
        key = (g.key(), batch)
        if key not in self._work:
            frac = batch / max(getattr(self, "_total", batch), 1)
            self._work[key] = sum(
                self.profiles[w].time(batch, 1, frac)
                for node in g.nodes
                for w in getattr(self, "_members", {}).get(node, (node,)))
        return self._work[key]

    def _granularities(self, batch: int) -> List[int]:
        out = []
        for d in self.cfg.granularity_divisors:
            if batch % d == 0 and batch // d >= 1 \
                    and (batch // d) % self.cfg.chunk_multiple == 0:
                out.append(batch // d)
        return sorted(set(out))

    def _fits(self, node_set, n: int, batch: int) -> bool:
        if not self.cfg.device_memory:
            return True
        for node in node_set:
            for w in self._members.get(node, (node,)):
                if self.profiles[w].memory(batch) / max(n, 1) > \
                        self.cfg.device_memory:
                    return False
        return True


# ---------------------------------------------------------------------------
# Fixed-mode baselines (veRL-style collocated / AReaL-style disaggregated)
# ---------------------------------------------------------------------------
def collocated_schedule(graph: FlowGraph, profiles, n: int, batch: int
                        ) -> Tuple[float, Schedule]:
    """All workers share all devices, executed phase-by-phase."""
    import networkx as nx
    dag, members = graph.condense()
    order = list(nx.topological_sort(dag.g))

    def build(i: int) -> Tuple[float, Schedule]:
        node = order[i]
        ms = members.get(node, (node,))
        t = sum(profiles[m].time(batch, max(n // len(ms), 1), 1.0)
                for m in ms)
        leaf = Leaf(node, n, batch,
                    cycle_mode="collocated" if len(ms) > 1 else None)
        if i == len(order) - 1:
            return t, leaf
        t_rest, rest = build(i + 1)
        switch = (sum(profiles[m].offload_time for m in ms)
                  + sum(profiles[mm].onload_time + profiles[mm].sync_time
                        for mm in members.get(order[i + 1], (order[i + 1],))))
        return t + t_rest + switch, Temporal(leaf, rest, switch)

    return build(0)


def disaggregated_schedule(graph: FlowGraph, profiles, n: int, batch: int,
                           granularity: Optional[int] = None
                           ) -> Tuple[float, Schedule]:
    """Fully spatial (AReaL-style): every component gets a proportional
    device slice and the whole workflow pipelines at one granularity.
    Like the real baseline, the pipeline granularity is tuned (best of a
    small sweep) — the *mode* is fixed, not the knob."""
    if granularity is None:
        best = None
        for div in (2, 4, 8, 16, 32):
            if batch % div:
                continue
            cand = disaggregated_schedule(graph, profiles, n, batch,
                                          granularity=batch // div)
            if best is None or cand[0] < best[0]:
                best = cand
        if best is None:
            # batch divisible by none of the candidate divisors (e.g. a
            # prime batch like 7): degenerate to one full-batch chunk
            # instead of returning None (which TypeErrors on unpack)
            best = disaggregated_schedule(graph, profiles, n, batch,
                                          granularity=batch)
        return best
    import networkx as nx
    dag, members = graph.condense()
    order = list(nx.topological_sort(dag.g))
    m = granularity

    # device shares proportional to work
    works = []
    for node in order:
        ms = members.get(node, (node,))
        works.append(sum(profiles[w].time(batch, 1) for w in ms))
    total_work = sum(works)
    shares = [max(int(round(w / total_work * n)), 1) for w in works]
    # fix rounding to sum exactly n
    while sum(shares) > n:
        shares[shares.index(max(shares))] -= 1
    while sum(shares) < n:
        shares[shares.index(min(shares))] += 1

    stage_ts = []
    for node, share in zip(order, shares):
        ms = members.get(node, (node,))
        stage_ts.append(sum(
            profiles[w].time(m, max(share // len(ms), 1), m / batch)
            for w in ms))

    def build(i: int) -> Schedule:
        ms_i = members.get(order[i], (order[i],))
        leaf = Leaf(order[i], shares[i], m,
                    cycle_mode="collocated" if len(ms_i) > 1 else None)
        if i == len(order) - 1:
            return leaf
        return Pipelined(leaf, build(i + 1), m, shares[i],
                         sum(shares[i + 1:]))

    t_crit = sum(stage_ts)
    t_bot = max(stage_ts)
    total = t_crit + (batch // m - 1) * t_bot
    return total, build(0)
