"""Failure injection + detection harness (scale-out fault tolerance).

The recovery story has three parts spread over three modules:

  * **inject** (here) — :class:`FaultInjector` wraps the workflow's task
    fns and kills a chosen worker at a configurable (iteration,
    invocation) point, optionally taking its whole host down
    (``SimulatedCluster.fail_host``).  Invocation index is the phase
    boundary: invocation 0 is the worker's first task call of the
    iteration, k is its k-th chunk/loop step;
  * **detect** — the ExecutionFlowManager wraps every task death as a
    typed :class:`~repro.core.worker.WorkerFailure` (worker name + step)
    and reports it to ``Controller.report_failure``; the
    :class:`HeartbeatMonitor` here covers the complementary silent-hang
    case (no exception, no progress);
  * **recover** — ``WorkflowRunner.recover`` tears the run down, rebuilds
    workers, re-plans over ``Cluster.available_devices`` and resumes from
    the last checkpoint, which makes recovery ≡ a fresh run resumed from
    that checkpoint *by construction* (the determinism the fault tests
    assert).

Death is marked on the worker OBJECT (``_injected_dead``), not the
injector, so a rebuilt worker of the same name starts clean while any
straggler call into the dead instance keeps failing — exactly a real
dead process's behaviour.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional


class InjectedFault(RuntimeError):
    """The synthetic death raised inside a killed worker's task."""


@dataclass(frozen=True)
class FaultSpec:
    """WHERE and WHEN to kill: ``worker`` dies at its ``invocation``-th
    task call of iteration ``iteration``; ``kill_host`` additionally
    fails the host its devices live on (needs a SimulatedCluster)."""
    worker: str
    iteration: int
    invocation: int = 0
    kill_host: bool = False


class FaultInjector:
    """One-shot kill switch threaded through the task-fn layer.

    Usage (what WorkflowRunner does when given an injector)::

        task_fns = injector.arm(task_fns)       # once, after build
        injector.set_iteration(it)              # every run_iteration
        ... controller.execute(...)             # raises WorkerFailure
                                                # wrapping InjectedFault

    Wrapping the task fns — rather than worker methods — catches every
    execution path (Temporal direct calls, Pipelined threads, cycle
    member threads) at the single choke point they share.
    """

    def __init__(self, spec: FaultSpec, cluster: Optional[Any] = None):
        self.spec = spec
        self.cluster = cluster
        self.fired = False
        self._iteration: Optional[int] = None
        self._counts: Dict[str, int] = {}
        self._lock = threading.Lock()

    def set_iteration(self, it: int) -> None:
        """Mark the current training iteration; invocation counts reset
        (they index phase boundaries WITHIN one iteration)."""
        with self._lock:
            self._iteration = it
            self._counts = {}

    def arm(self, task_fns: Dict[str, Callable[[Any, Dict], Dict]]
            ) -> Dict[str, Callable[[Any, Dict], Dict]]:
        """Return task fns with the kill switch spliced in front."""
        return {name: self._wrap(name, fn) for name, fn in task_fns.items()}

    def _wrap(self, name: str, fn: Callable) -> Callable:
        def wrapped(w: Any, chunk: Dict) -> Dict:
            self._maybe_fire(name, w)
            return fn(w, chunk)

        wrapped.__name__ = getattr(fn, "__name__", name)
        return wrapped

    def _maybe_fire(self, name: str, w: Any) -> None:
        if getattr(w, "_injected_dead", False):
            # a dead instance stays dead until recovery rebuilds it
            raise InjectedFault(f"worker {name!r} is dead")
        with self._lock:
            if (self.fired or name != self.spec.worker
                    or self._iteration != self.spec.iteration):
                return
            c = self._counts.get(name, 0)
            self._counts[name] = c + 1
            if c != self.spec.invocation:
                return
            self.fired = True
        w._injected_dead = True
        if self.spec.kill_host and self.cluster is not None:
            devs = list(getattr(w, "devices", ()) or ())
            if devs and hasattr(self.cluster, "fail_host"):
                self.cluster.fail_host(self.cluster.node_of(devs[0]))
        raise InjectedFault(
            f"injected fault: worker {name!r} killed at iteration "
            f"{self.spec.iteration}, invocation {self.spec.invocation}"
            + (" (host down)" if self.spec.kill_host else ""))


class HeartbeatMonitor:
    """Liveness by progress: every task call beats; silence past
    ``timeout`` marks the worker suspect.  Covers the failure mode typed
    exceptions cannot — a hung worker that never raises.

    ``clock`` is injectable so tests advance time explicitly instead of
    sleeping.

    Straggler detection rides on the same beats: every beat records the
    interval since the worker's previous beat (bounded history), and
    :meth:`suspects` surfaces workers whose *current* silence already
    dwarfs their own recorded cadence — slow-but-alive workers, long
    before the hard ``timeout`` declares them dead.
    """

    # beat intervals kept per worker for the straggler percentile
    HISTORY = 256

    def __init__(self, timeout: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout
        self._clock = clock
        self._last: Dict[str, float] = {}
        self._intervals: Dict[str, deque] = {}
        self._lock = threading.Lock()

    def beat(self, worker: str) -> None:
        with self._lock:
            now = self._clock()
            prev = self._last.get(worker)
            if prev is not None:
                self._intervals.setdefault(
                    worker, deque(maxlen=self.HISTORY)).append(now - prev)
            self._last[worker] = now

    def last_beat(self, worker: str) -> Optional[float]:
        with self._lock:
            return self._last.get(worker)

    def intervals(self, worker: str) -> List[float]:
        with self._lock:
            return list(self._intervals.get(worker, ()))

    def interval_percentile(self, worker: str,
                            percentile: float = 95.0) -> Optional[float]:
        """The ``percentile``-th recorded beat interval for ``worker``
        (None with no history) — the per-worker cadence number run_loop
        publishes as a straggler gauge each iteration."""
        with self._lock:
            hist = self._intervals.get(worker)
            if not hist:
                return None
            ordered = sorted(hist)
            k = min(len(ordered) - 1,
                    int(len(ordered) * percentile / 100.0))
            return ordered[k]

    def suspects(self, percentile: float = 95.0,
                 factor: float = 3.0,
                 min_history: int = 3) -> List[str]:
        """Workers whose current silence exceeds ``factor`` times their
        own ``percentile``-th beat interval — stragglers, surfaced while
        still under the hard ``timeout``.  Workers with fewer than
        ``min_history`` recorded intervals have no cadence to compare
        against and are never suspected."""
        now = self._clock()
        out = []
        with self._lock:
            for w, last in self._last.items():
                hist = self._intervals.get(w)
                if hist is None or len(hist) < min_history:
                    continue
                ordered = sorted(hist)
                k = min(len(ordered) - 1,
                        int(len(ordered) * percentile / 100.0))
                typical = ordered[k]
                if now - last > factor * max(typical, 1e-9):
                    out.append(w)
        return sorted(out)

    def silent(self) -> List[str]:
        """Workers whose last beat is older than ``timeout``."""
        now = self._clock()
        with self._lock:
            return sorted(w for w, t in self._last.items()
                          if now - t > self.timeout)

    def check(self) -> None:
        """Raise if any tracked worker has gone silent."""
        dead = self.silent()
        if dead:
            raise TimeoutError(
                f"no heartbeat from {dead} for > {self.timeout}s")

    def reset(self) -> None:
        with self._lock:
            self._last = {}
            self._intervals = {}
