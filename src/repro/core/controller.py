"""Controller: ties profiler → scheduler → executor together (Fig. 4).

Responsibilities (paper §3.1): assign workers to accelerators, manage
inter-worker connections (via the Router), orchestrate the execution flow
by dispatching function invocations, monitor failures, and expose the
worker-group-level timers.

``Controller.plan()`` is the M2Flow transformation entry point: it takes
the traced logical flow + profiles, runs Algorithm 1, and returns an
execution plan (Schedule tree + placement) that ``execute()`` runs.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.comm.primitives import global_router, reset_router
from repro.core.channel import Channel
from repro.core.flowgraph import FlowGraph, GraphTracer
from repro.core.pipeline import ExecutionFlowManager
from repro.core.placement import Cluster, PlacementManager, split_devices
from repro.core.profiler import CostModel, Profiler
from repro.core.scheduler import (
    Async,
    Leaf,
    Pipelined,
    Scheduler,
    SchedulerConfig,
    Temporal,
    collocated_schedule,
    disaggregated_schedule,
    leaves,
)
from repro.core.simulator import Simulator
from repro.core.switching import ContextSwitcher
from repro.core.worker import Worker, WorkerFailure, WorkerGroup
from repro.obs import trace as _trace


@dataclass
class ExecutionPlan:
    schedule: Any
    est_time: float
    placement: Dict[str, List[int]]
    mode: str  # "auto" | "collocated" | "disaggregated"
    # collapsed-cycle membership: {collapsed node name: member workers}
    # (only nodes with >= 2 members).  Recorded at plan time so the
    # executor can run the cycle's members without re-condensing the
    # graph, and so the placement column binds the MEMBER workers (the
    # real ones) instead of the synthetic collapsed name.
    members: Dict[str, Tuple[str, ...]] = field(default_factory=dict)
    # the graph this plan was derived from, carried so strict-mode
    # analysis (and tooling) can lint plan + graph together
    graph: Optional[Any] = field(default=None, repr=False)

    def pretty(self) -> str:
        lines = [f"mode={self.mode} est={self.est_time:.2f}s"]
        lines.append(self.schedule.pretty())
        for w, devs in self.placement.items():
            span = f"{devs[0]}..{devs[-1]}" if devs else "-"
            lines.append(f"  {w}: devices [{span}] ({len(devs)})")
        return "\n".join(lines)


class Controller:
    def __init__(self, cluster: Cluster,
                 profiles: Optional[Dict[str, CostModel]] = None,
                 scheduler_cfg: Optional[SchedulerConfig] = None,
                 heartbeat: Optional[Any] = None,
                 strict: bool = False):
        self.cluster = cluster
        self.profiles = profiles or {}
        self.scheduler_cfg = scheduler_cfg or SchedulerConfig()
        # strict=True runs flowlint Pass 1-2 on every plan inside
        # execute() and rejects it (FlowLintError) BEFORE any worker is
        # bound or run
        self.strict = strict
        self.tracer = GraphTracer()
        self.router = global_router()
        self.placement_manager = PlacementManager(cluster)
        # optional core.faults.HeartbeatMonitor — beaten around every task
        # call by the executor so a silent hang is detectable
        self.heartbeat = heartbeat
        self._switcher: Optional[ContextSwitcher] = None
        self._failed: List[WorkerFailure] = []
        self._kill = threading.Event()

    # ------------------------------------------------------------------
    # failure monitoring (paper §4)
    # ------------------------------------------------------------------
    def report_failure(self, failure: WorkerFailure) -> None:
        self._failed.append(failure)
        # kill the whole system quickly to avoid cascading timeout noise
        self._kill.set()

    @property
    def failed(self) -> List[WorkerFailure]:
        return self._failed

    def check_alive(self) -> None:
        if self._kill.is_set():
            raise self._failed[0]
        if self.heartbeat is not None:
            self.heartbeat.check()

    def reset_failures(self) -> None:
        """Clear failure state after recovery re-established the run."""
        self._failed = []
        self._kill.clear()
        if self.heartbeat is not None:
            self.heartbeat.reset()

    # ------------------------------------------------------------------
    # M2Flow planning
    # ------------------------------------------------------------------
    def plan(self, graph: FlowGraph, *, total_batch: int,
             mode: str = "auto") -> ExecutionPlan:
        # plan over LIVE devices only: after a host failure the surviving
        # devices are the whole universe (recovery re-plans through here)
        avail = self.cluster.available_devices()
        n = len(avail)
        if mode == "collocated":
            t, sched = collocated_schedule(graph, self.profiles, n, total_batch)
        elif mode == "disaggregated":
            t, sched = disaggregated_schedule(graph, self.profiles, n,
                                              total_batch)
        else:
            sch = Scheduler(self.profiles, self.scheduler_cfg)
            t, sched = sch.schedule(graph, n, total_batch)
        members = self._cycle_members(graph)
        placement = self._place(sched, avail, members)
        return ExecutionPlan(schedule=sched, est_time=t, placement=placement,
                             mode=mode, members=members, graph=graph)

    def plan_async(self, graph: FlowGraph, *, total_batch: int,
                   iterations: int = 8,
                   depths: Optional[List[int]] = None) -> ExecutionPlan:
        """M2Flow planning with the async off-policy dimension: searches
        temporal/spatial/async_depth and returns the horizon-optimal plan.
        ``est_time`` is the estimated wall-clock makespan of the whole
        ``iterations`` horizon (schedule_async selects with a freshness
        tax but always returns the untaxed time)."""
        avail = self.cluster.available_devices()
        n = len(avail)
        sch = Scheduler(self.profiles, self.scheduler_cfg)
        t, sched = sch.schedule_async(graph, n, total_batch,
                                      iterations=iterations, depths=depths)
        mode = (f"async-{sched.depth}" if isinstance(sched, Async)
                else "auto")
        members = self._cycle_members(graph)
        placement = self._place(sched, avail, members)
        return ExecutionPlan(schedule=sched, est_time=t, placement=placement,
                             mode=mode, members=members, graph=graph)

    @staticmethod
    def _cycle_members(graph: FlowGraph) -> Dict[str, Tuple[str, ...]]:
        _, members = graph.condense()
        return {name: ms for name, ms in members.items() if len(ms) > 1}

    def _place(self, sched, devices: List[int],
               members: Optional[Dict[str, Tuple[str, ...]]] = None
               ) -> Dict[str, List[int]]:
        """Spatial stages get disjoint device slices; temporal stages
        share.  A collapsed-cycle leaf binds its MEMBER workers: the
        hybrid realization pins each member to its recorded disjoint
        share (Leaf.member_devices); the collocated realization gives
        every member the leaf's full (time-shared) slice."""
        out: Dict[str, List[int]] = {}
        members = members or {}
        if isinstance(sched, Leaf):
            devs = devices[: sched.devices] or devices
            ms = members.get(sched.worker, ())
            if len(ms) > 1:
                if sched.cycle_mode == "hybrid" and sched.member_devices:
                    cur = 0
                    for m, share in zip(ms, sched.member_devices):
                        out[m] = devs[cur:cur + share] or list(devs)
                        cur += share
                else:
                    for m in ms:
                        out[m] = list(devs)
            else:
                out[sched.worker] = devs
            return out
        if isinstance(sched, Temporal):
            out.update(self._place(sched.s, devices, members))
            out.update(self._place(sched.t, devices, members))
            return out
        if isinstance(sched, (Pipelined, Async)):
            # both sides own disjoint device slices, split exactly as the
            # scheduler recorded (summing leaf counts instead would
            # double-count time-shared Temporal stages within one side
            # and starve the other side's slice)
            out.update(self._place(sched.s, devices[:sched.n_s], members))
            out.update(self._place(sched.t, devices[sched.n_s:], members))
            return out
        raise TypeError(type(sched))

    # ------------------------------------------------------------------
    def simulate(self, plan: ExecutionPlan, total_batch: int):
        sim = Simulator(self.profiles)
        return sim.run(plan.schedule, total_batch)

    def bind_placement(self, plan: ExecutionPlan,
                       workers: Dict[str, Any]) -> Dict[str, List[int]]:
        """Make the plan's placement binding: diff against the cluster's
        current allocations and rebind every worker's device slice (and
        mesh/shardings) to what the plan assigns."""
        return self.placement_manager.apply(plan, workers)

    @property
    def switch_stats(self) -> Dict[str, Dict[str, float]]:
        """Measured context-switch costs (worker -> onload/offload s)."""
        return self._switcher.measured if self._switcher else {}

    def _lint(self, plan: ExecutionPlan,
              cycle_specs: Optional[Dict[str, Any]]) -> None:
        """Strict mode: flowlint Pass 1-2 over the plan (and the graph
        it was derived from, if it carries one).  Raises FlowLintError
        on any error-severity finding — before bind_placement, so a
        corrupted plan never touches a worker or a device.  Imported
        lazily: analysis depends on core, never the reverse."""
        from repro.analysis import analyze, filter_findings
        from repro.analysis.findings import FlowLintError
        findings = analyze(getattr(plan, "graph", None), plan,
                           cluster=self.cluster, cfg=self.scheduler_cfg,
                           cycle_specs=cycle_specs)
        errors = filter_findings(findings, "error")
        if errors:
            raise FlowLintError(errors)

    def execute(self, plan: ExecutionPlan, workers: Dict[str, Any],
                task_fns: Dict[str, Callable], batch,
                cycle_specs: Optional[Dict[str, Any]] = None) -> Any:
        if self.strict:
            self._lint(plan, cycle_specs)
        self.bind_placement(plan, workers)
        # one switcher per (workers, profiles) pair so measured switch
        # costs accumulate (and keep feeding the CostModels) across
        # iterations
        if (self._switcher is None or self._switcher.workers is not workers
                or self._switcher.profiles is not self.profiles):
            self._switcher = ContextSwitcher(workers, profiles=self.profiles)
        mgr = ExecutionFlowManager(workers, task_fns,
                                   switcher=self._switcher,
                                   members=plan.members,
                                   cycle_specs=cycle_specs,
                                   heartbeat=self.heartbeat,
                                   on_failure=self.report_failure)
        tr = _trace.active()
        if tr is not None:
            with tr.span("execute", "phase", mode=plan.mode,
                         est_time=plan.est_time):
                out = mgr.run(plan.schedule, batch)
        else:
            out = mgr.run(plan.schedule, batch)
        self.last_timeline = mgr.timeline
        self.last_time = mgr.total_time
        self.last_cycle_log = mgr.cycle_log
        return out
