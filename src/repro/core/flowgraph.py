"""Workflow graph: JIT extraction from traced communication + s-t cuts.

The graph is extracted just-in-time during the profiling run: every
channel ``put``/``get`` is traced as (producer → channel → consumer), and
weight-update synchronization edges are added by the runner.  Cycles
(embodied sim ↔ generation, deep-research tool loops) are collapsed into
single nodes before scheduling (paper Algorithm 1 line 2).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Set, Tuple

import networkx as nx


@dataclass(frozen=True)
class TraceEvent:
    kind: str  # "put" | "get"
    worker: str
    channel: str
    t: float
    nbytes: int = 0


def cycle_node_name(members: Iterable[str]) -> str:
    """Canonical name of a collapsed cycle node — the single place the
    naming convention lives (condense, cycle-spec registration, tests)."""
    ms = tuple(sorted(members))
    return ms[0] if len(ms) == 1 else "cycle(" + "+".join(ms) + ")"


class FlowGraph:
    """Directed workflow graph over worker (group) names."""

    def __init__(self):
        self.g = nx.DiGraph()
        self._key: Optional[FrozenSet[str]] = None

    # -- construction ------------------------------------------------------
    def add_worker(self, name: str, **attrs) -> None:
        self.g.add_node(name, **attrs)
        self._key = None

    def add_edge(self, src: str, dst: str, *, channel: str = "",
                 nbytes: int = 0) -> None:
        self.g.add_edge(src, dst, channel=channel, nbytes=nbytes)
        self._key = None

    @classmethod
    def from_trace(cls, events: Sequence[TraceEvent]) -> "FlowGraph":
        fg = cls()
        producers: Dict[str, Set[str]] = {}
        consumers: Dict[str, Set[str]] = {}
        traffic: Dict[str, int] = {}
        for ev in events:
            fg.add_worker(ev.worker)
            d = producers if ev.kind == "put" else consumers
            d.setdefault(ev.channel, set()).add(ev.worker)
            traffic[ev.channel] = traffic.get(ev.channel, 0) + ev.nbytes
        for ch in set(producers) | set(consumers):
            for p in producers.get(ch, ()):
                for c in consumers.get(ch, ()):
                    if p != c:
                        fg.add_edge(p, c, channel=ch,
                                    nbytes=traffic.get(ch, 0))
        return fg

    # -- properties ----------------------------------------------------------
    @property
    def nodes(self) -> List[str]:
        return list(self.g.nodes)

    def edges(self) -> List[Tuple[str, str]]:
        return list(self.g.edges)

    def successors(self, n: str) -> List[str]:
        return list(self.g.successors(n))

    # -- cycle collapse (ConvertCircleToNode) ---------------------------------
    def condense(self) -> Tuple["FlowGraph", Dict[str, Tuple[str, ...]]]:
        """Collapse strongly-connected components into single nodes.

        Returns (dag, members) where members maps the collapsed node name
        to its original workers.  Collapsed nodes are scheduled as a unit
        (paper §3.4 last paragraph) and executed as a closed loop by the
        ExecutionFlowManager (Leaf.cycle_mode realization).
        """
        comp = nx.condensation(self.g)
        dag = FlowGraph()
        members: Dict[str, Tuple[str, ...]] = {}
        names: Dict[int, str] = {}
        for cid, data in comp.nodes(data=True):
            ms = tuple(sorted(data["members"]))
            name = cycle_node_name(ms)
            names[cid] = name
            members[name] = ms
            dag.add_worker(name)
        for a, b in comp.edges:
            dag.add_edge(names[a], names[b])
        return dag, members

    # -- s-t cuts ---------------------------------------------------------------
    def st_cuts(self) -> Iterable[Tuple[FrozenSet[str], FrozenSet[str]]]:
        """Enumerate ordered 2-partitions (G_s, G_t) with every edge going
        s→t (i.e. G_s is a down-set of the DAG) — the s-t cuts of
        Algorithm 1 line 12.  Exponential in nodes; workflow graphs have
        ≤ ~8 components."""
        nodes = list(nx.topological_sort(self.g))
        n = len(nodes)
        ancestors = {v: nx.ancestors(self.g, v) for v in nodes}
        seen = set()
        for r in range(1, n):
            for combo in itertools.combinations(nodes, r):
                s = frozenset(combo)
                if s in seen:
                    continue
                seen.add(s)
                # closed under ancestors?
                if any(not ancestors[v] <= s for v in s):
                    continue
                t = frozenset(set(nodes) - s)
                yield s, t

    def subgraph(self, nodes: Iterable[str]) -> "FlowGraph":
        fg = FlowGraph()
        fg.g = self.g.subgraph(nodes).copy()
        return fg

    def key(self) -> FrozenSet[str]:
        # cached: the scheduler's memoized recursion calls key() on every
        # lookup, and the node set only changes through the mutators above
        if self._key is None:
            self._key = frozenset(self.g.nodes)
        return self._key

    def __repr__(self) -> str:
        return f"FlowGraph({list(self.g.nodes)}, edges={list(self.g.edges)})"


class GraphTracer:
    """Collects TraceEvents during a profiling execution of the workflow."""

    def __init__(self):
        self.events: List[TraceEvent] = []

    def record(self, kind: str, worker: str, channel: str, t: float,
               nbytes: int = 0) -> None:
        self.events.append(TraceEvent(kind, worker, channel, t, nbytes))

    def graph(self) -> FlowGraph:
        return FlowGraph.from_trace(self.events)
