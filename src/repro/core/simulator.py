"""Discrete-event executor for Schedule trees.

Replays a schedule against the cost models, producing a timeline of
(worker, devices, t_start, t_end, chunk) — the Gantt data behind the
paper's Figs. 11–13 analogues — and a makespan that validates the
scheduler's analytic estimate (tests assert they agree).

The simulation models:
  * pipelined stages with chunk granularity m (stage s processes chunk i,
    hands it downstream; stage occupancy respects the bottleneck);
  * temporal context switches with onload/offload latency;
  * the long-tail effect inside generation-like stages (tail_factor).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.profiler import CostModel
from repro.core.scheduler import (
    Async,
    Leaf,
    Pipelined,
    Temporal,
    cycle_hybrid_time,
)


@dataclass
class Span:
    worker: str
    devices: int
    start: float
    end: float
    chunk: int = -1
    kind: str = "compute"  # compute | switch


@dataclass
class SimResult:
    makespan: float
    spans: List[Span] = field(default_factory=list)

    def busy_time(self, worker: str) -> float:
        return sum(s.end - s.start for s in self.spans
                   if s.worker == worker and s.kind == "compute")

    def breakdown(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for s in self.spans:
            key = s.worker if s.kind == "compute" else f"{s.worker}:switch"
            out[key] = out.get(key, 0.0) + (s.end - s.start)
        return out

    def gantt(self) -> str:
        lines = []
        for s in sorted(self.spans, key=lambda x: (x.worker, x.start)):
            lines.append(
                f"{s.worker:24s} [{s.start:8.2f} -> {s.end:8.2f}] "
                f"n={s.devices:3d} chunk={s.chunk} {s.kind}")
        return "\n".join(lines)


class Simulator:
    def __init__(self, profiles: Dict[str, CostModel],
                 members: Optional[Dict[str, Tuple[str, ...]]] = None):
        self.profiles = profiles
        self.members = members or {}

    def _leaf_time(self, leaf: Leaf, batch: int) -> float:
        frac = batch / max(self._total, 1)
        ms = self.members.get(leaf.worker, (leaf.worker,))
        if len(ms) == 1:
            return self.profiles[leaf.worker].time(batch, leaf.devices, frac)
        # Collapsed cycle: replay the realization RECORDED on the Leaf
        # (Leaf.cycle_mode / member_devices) — the simulator used to
        # re-derive the scheduler's cheaper-of-two costing here and could
        # disagree with what would actually run.
        n = leaf.devices
        t_shared = sum(self.profiles[m].time(batch, n, frac) for m in ms)
        if leaf.cycle_mode == "collocated":
            return t_shared
        if leaf.cycle_mode == "hybrid" and leaf.member_devices:
            return cycle_hybrid_time(self.profiles, ms, leaf.member_devices,
                                     batch, frac, leaf.cycle_chunks)
        # legacy leaf with no recorded realization: cheaper-of-two over
        # an even split (pre-recording behaviour)
        best = t_shared
        if len(ms) >= 2 and n >= len(ms):
            even = max(n // len(ms), 1)
            ts = [self.profiles[m].time(batch, even, frac) for m in ms]
            best = min(best, max(ts) + (sum(ts) - max(ts)) / max(batch, 1))
        return best

    # ------------------------------------------------------------------
    def run(self, sched, total_batch: int, t0: float = 0.0) -> SimResult:
        self._total = total_batch
        spans: List[Span] = []
        end = self._run(sched, total_batch, t0, spans)
        return SimResult(makespan=end - t0, spans=spans)

    def run_iterations(self, sched, total_batch: int, iterations: int,
                       t0: float = 0.0) -> SimResult:
        """Horizon replay: an Async schedule embeds its own iteration
        count (which must agree with ``iterations`` — a silent mismatch
        would skew any tokens/makespan throughput the caller derives);
        any other schedule runs back-to-back (the sync baseline)."""
        if isinstance(sched, Async):
            if sched.iterations != iterations:
                raise ValueError(
                    f"Async schedule was built for {sched.iterations} "
                    f"iterations, asked to replay {iterations}")
            return self.run(sched, total_batch, t0)
        self._total = total_batch
        spans: List[Span] = []
        t = t0
        for _ in range(iterations):
            t = self._run(sched, total_batch, t, spans)
        return SimResult(makespan=t - t0, spans=spans)

    def _run(self, sched, batch: int, t0: float, spans: List[Span]) -> float:
        if isinstance(sched, Leaf):
            t = self._leaf_time(sched, batch)
            spans.append(Span(sched.worker, sched.devices, t0, t0 + t))
            return t0 + t

        if isinstance(sched, Temporal):
            mid = self._run(sched.s, batch, t0, spans)
            if sched.switch_cost:
                spans.append(Span("context-switch", 0, mid,
                                  mid + sched.switch_cost, kind="switch"))
                mid += sched.switch_cost
            return self._run(sched.t, batch, mid, spans)

        if isinstance(sched, Pipelined):
            m = sched.granularity
            chunks = max(batch // m, 1)
            # per-chunk completion recursion: stage s chunk i can start when
            # (a) chunk i's upstream is done, (b) stage finished chunk i-1
            s_end = [0.0] * chunks
            t_end = [0.0] * chunks
            prev_s = t0
            for i in range(chunks):
                start = prev_s
                dur_s = self._stage_time(sched.s, m)
                s_spans: List[Span] = []
                self._run_stage(sched.s, m, start, s_spans, i)
                spans.extend(s_spans)
                s_end[i] = start + dur_s
                prev_s = s_end[i]
            prev_t = t0
            for i in range(chunks):
                start = max(s_end[i], prev_t)
                dur_t = self._stage_time(sched.t, m)
                t_spans: List[Span] = []
                self._run_stage(sched.t, m, start, t_spans, i)
                spans.extend(t_spans)
                t_end[i] = start + dur_t
                prev_t = t_end[i]
            return t_end[-1]

        if isinstance(sched, Async):
            # Cross-iteration overlap with bounded staleness K: iteration
            # i's producer starts once (a) its own previous iteration and
            # (b) the consumer's iteration i-K-1 have finished — the exact
            # recurrence of scheduler.async_makespan, replayed with spans
            # (chunk = iteration index).
            I, K = sched.iterations, sched.depth
            dur_s = self._stage_time(sched.s, batch)
            dur_t = self._stage_time(sched.t, batch)
            s_end = [0.0] * I
            t_end = [0.0] * I
            for i in range(I):
                gate = t_end[i - K - 1] if i - K - 1 >= 0 else t0
                start_s = max(s_end[i - 1] if i >= 1 else t0, gate)
                self._run_stage(sched.s, batch, start_s, spans, i)
                s_end[i] = start_s + dur_s
                start_t = max(s_end[i], t_end[i - 1] if i >= 1 else t0)
                self._run_stage(sched.t, batch, start_t, spans, i)
                t_end[i] = start_t + dur_t
            return t_end[-1]

        raise TypeError(type(sched))

    def _stage_time(self, sched, m: int) -> float:
        if isinstance(sched, Leaf):
            return self._leaf_time(sched, m)
        if isinstance(sched, Temporal):
            return (self._stage_time(sched.s, m) + sched.switch_cost
                    + self._stage_time(sched.t, m))
        if isinstance(sched, Pipelined):
            # nested pipeline over this chunk: the inner pipeline may
            # re-chunk at a finer granularity m' — same formula as the
            # scheduler: t_crit + (chunks-1) * t_bottleneck
            g = sched.granularity
            chunks = max(m // g, 1)
            ts = self._stage_time(sched.s, g)
            tt = self._stage_time(sched.t, g)
            return ts + tt + (chunks - 1) * max(ts, tt)
        raise TypeError(type(sched))

    def _run_stage(self, sched, m: int, t0: float, spans: List[Span],
                   chunk: int) -> float:
        if isinstance(sched, Leaf):
            t = self._leaf_time(sched, m)
            spans.append(Span(sched.worker, sched.devices, t0, t0 + t,
                              chunk=chunk))
            return t0 + t
        if isinstance(sched, Temporal):
            mid = self._run_stage(sched.s, m, t0, spans, chunk)
            if sched.switch_cost:
                spans.append(Span("context-switch", 0, mid,
                                  mid + sched.switch_cost, kind="switch",
                                  chunk=chunk))
                mid += sched.switch_cost
            return self._run_stage(sched.t, m, mid, spans, chunk)
        if isinstance(sched, Pipelined):
            mid = self._run_stage(sched.s, sched.granularity, t0, spans, chunk)
            return self._run_stage(sched.t, sched.granularity, mid, spans,
                                   chunk)
        raise TypeError(type(sched))
