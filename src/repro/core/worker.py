"""Worker abstraction + WorkerGroup SPMD dispatch (paper §3.2).

A Worker encapsulates one RL component (rollout, inference, actor train,
simulator, reward...).  The base class provides:

  * ``send/recv`` — adaptive point-to-point comm via the global Router;
  * ``onload/offload`` — resource management hooks; the default
    implementation moves the worker's registered state pytrees between
    device and host memory (the CPU↔GPU swap of the paper, realized as
    ``jax.device_put`` / ``jax.device_get``).  Both accept a ``keys``
    subset so a context switch can move the optimizer state separately
    from the parameters (the ContextSwitcher exploits this);
  * ``bind_devices`` — plan-driven placement: the controller rebinds a
    worker to the device slice its ExecutionPlan assigns, rebuilding the
    worker's mesh and re-placing registered state through the
    resharding data plane;
  * built-in per-call timing, feeding the profiler/scheduler.

``WorkerGroup`` launches N worker processes (threads here; Ray actors in
the paper) and dispatches public method calls to all or a subset of them,
returning asynchronous :class:`FutureHandle` s whose ``wait()`` is the
synchronization barrier of the programming model (Fig. 5b).
"""
from __future__ import annotations

import threading
import time
import traceback
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.comm.primitives import global_router


class WorkerFailure(RuntimeError):
    """Typed worker-death signal: carries the worker name, the original
    exception and (when raised from the executor) the pipeline step /
    chunk index at which the task died — so failure detection is
    testable instead of string-matching thread tracebacks."""

    def __init__(self, worker: str, exc: BaseException, tb: str,
                 step: Optional[int] = None):
        at = f" at step {step}" if step is not None else ""
        super().__init__(f"worker {worker} failed{at}: {exc!r}\n{tb}")
        self.worker = worker
        self.original = exc
        self.step = step


@dataclass
class TimerRecord:
    fn: str
    start: float
    elapsed: float
    meta: Dict[str, Any] = field(default_factory=dict)


class Worker:
    """Base RL component. Subclasses implement task methods that read from
    in-channels and write to out-channels (see repro.rl.workers)."""

    def __init__(self, name: str, *, devices: Sequence[int] = (),
                 process_index: int = 0):
        self.name = name
        self.devices = tuple(devices)
        self.process_index = process_index
        self.router = global_router()
        self.router.register(name, devices=list(devices))
        self._state: Dict[str, Any] = {}  # registered device state
        self._host_state: Dict[str, Any] = {}
        self._offloaded: set = set()  # keys currently living on the host
        self._state_lock = threading.RLock()
        self._mesh = None  # lazily built from `devices`
        self.timers: List[TimerRecord] = []
        self._timer_lock = threading.Lock()

    # ------------------------------------------------------------------
    # communication (paper: send/recv primitives)
    # ------------------------------------------------------------------
    def send(self, obj: Any, dst: str, async_op: bool = True):
        return self.router.send(self.name, dst, obj, async_op=async_op)

    def recv(self, src: str, timeout: Optional[float] = None) -> Any:
        return self.router.recv(self.name, src, timeout=timeout)

    # ------------------------------------------------------------------
    # placement (plan-driven binding)
    # ------------------------------------------------------------------
    @property
    def device_mesh(self):
        """1-D mesh over the local jax devices backing this worker's
        cluster device slice; None when the worker owns no devices."""
        if self._mesh is None and self.devices:
            from repro.launch.mesh import mesh_for_devices
            self._mesh = mesh_for_devices(self.devices)
        return self._mesh

    def state_shardings(self, tree: Any) -> Any:
        """Replicated destination shardings on this worker's mesh — the
        dst side of a ``comm.resharding.timed_weight_sync``.  None when
        the worker has no devices (host-only workers)."""
        mesh = self.device_mesh
        if mesh is None or tree is None:
            return None
        from repro.utils.sharding import tree_replicated
        return tree_replicated(tree, mesh)

    def bind_devices(self, devices: Sequence[int]) -> None:
        """Rebind this worker to a new device slice (plan-driven
        placement).  Rebuilds the mesh, refreshes the router registration
        (placement-aware backend choice must see the new devices), and
        re-places on-device state through the resharding data plane."""
        devices = tuple(devices)
        if devices == self.devices:
            return
        self.devices = devices
        self._mesh = None
        self.router.register(self.name, devices=list(devices))
        mesh = self.device_mesh
        if mesh is None:
            return
        from repro.comm.resharding import reshard
        with self._state_lock:
            for k, tree in self._state.items():
                if tree is None or k in self._offloaded:
                    continue
                shardings = self.state_shardings(tree)
                if shardings is not None:
                    self._state[k] = reshard(tree, shardings)

    # ------------------------------------------------------------------
    # resource management (paper: onload/offload for context switching)
    # ------------------------------------------------------------------
    def register_state(self, key: str, tree: Any) -> None:
        self._state[key] = tree

    def get_state(self, key: str) -> Any:
        with self._state_lock:
            if key in self._offloaded:
                self.onload(keys=(key,))
            return self._state[key]

    def set_state(self, key: str, tree: Any) -> None:
        # a fresh write supersedes any offloaded copy of this key —
        # otherwise the next onload() would clobber it with stale state
        # (e.g. weight sync into an offloaded rollout/inference worker)
        with self._state_lock:
            self._state[key] = tree
            self._host_state.pop(key, None)
            self._offloaded.discard(key)

    def state_bytes(self) -> int:
        total = 0
        for tree in self._state.values():
            for l in jax.tree_util.tree_leaves(tree):
                if hasattr(l, "nbytes"):
                    total += int(l.nbytes)
        return total

    @property
    def offloaded(self) -> bool:
        """True when any registered key currently lives on the host."""
        return bool(self._offloaded)

    def offloaded_keys(self) -> Tuple[str, ...]:
        return tuple(sorted(self._offloaded))

    def offload(self, keys: Optional[Sequence[str]] = None) -> Tuple[str, ...]:
        """Move registered device state to host memory (frees accelerator).

        ``keys`` selects a subset — e.g. the optimizer state separately
        from the params during a context switch.  Returns the keys that
        actually moved."""
        moved = []
        with self._state_lock:
            ks = list(keys) if keys is not None else list(self._state)
            for k in ks:
                tree = self._state.get(k)
                if k in self._offloaded or tree is None:
                    continue
                self._host_state[k] = jax.tree_util.tree_map(
                    lambda x: np.asarray(x) if isinstance(x, jax.Array) else x,
                    tree,
                )
                self._state[k] = None
                self._offloaded.add(k)
                moved.append(k)
        return tuple(moved)

    def onload(self, keys: Optional[Sequence[str]] = None) -> Tuple[str, ...]:
        """Restore host state onto THIS WORKER'S devices; returns the keys
        moved.  Placement goes through the worker's mesh, so state that
        sat offloaded across a ``bind_devices`` rebind still lands on the
        new slice (a bare ``device_put`` would commit it to the default
        device — incompatible with the worker's other committed state on
        a multi-device backend)."""
        moved = []
        sharding = None
        mesh = self.device_mesh
        if mesh is not None:
            from repro.utils.sharding import replicated
            sharding = replicated(mesh)

        def put(x):
            if not isinstance(x, np.ndarray):
                return x
            return jax.device_put(x) if sharding is None \
                else jax.device_put(x, sharding)

        with self._state_lock:
            ks = list(keys) if keys is not None else list(self._offloaded)
            for k in ks:
                if k not in self._offloaded:
                    continue
                tree = self._host_state.pop(k)
                self._state[k] = jax.tree_util.tree_map(put, tree)
                self._offloaded.discard(k)
                moved.append(k)
        return tuple(moved)

    # ------------------------------------------------------------------
    def _timed(self, fn_name: str, fn: Callable, *args, **kw):
        t0 = time.perf_counter()
        try:
            out = fn(*args, **kw)
            return out
        finally:
            el = time.perf_counter() - t0
            with self._timer_lock:
                self.timers.append(TimerRecord(fn=fn_name, start=t0, elapsed=el))

    def timer_values(self, fn: Optional[str] = None) -> List[float]:
        with self._timer_lock:
            return [t.elapsed for t in self.timers if fn is None or t.fn == fn]

    def shutdown(self) -> None:
        self.router.deregister(self.name)


class FutureHandle:
    """Async result of a WorkerGroup dispatch; ``wait()`` = barrier."""

    def __init__(self, futures: List[Future], group: "WorkerGroup",
                 fn_name: str):
        self._futures = futures
        self._group = group
        self._fn = fn_name
        self._t0 = time.perf_counter()

    def wait(self, timeout: Optional[float] = None) -> List[Any]:
        out = []
        for f in self._futures:
            out.append(f.result(timeout=timeout))
        return out

    def done(self) -> bool:
        return all(f.done() for f in self._futures)

    # worker-group-level timer (paper §4 Performance Profiling): reduced
    # over processes with a chosen reduction
    def timing(self, reduce: str = "max") -> float:
        self.wait()
        vals = []
        for w in self._group.workers:
            ts = w.timer_values(self._fn)
            if ts:
                vals.append(ts[-1])
        if not vals:
            return 0.0
        return {"max": max, "min": min,
                "mean": lambda v: sum(v) / len(v)}[reduce](vals)


class WorkerGroup:
    """All processes of one worker, dispatched collectively (paper §3.2)."""

    def __init__(self, workers: List[Worker]):
        assert workers
        self.workers = workers
        self.name = workers[0].name.rsplit("/", 1)[0]
        self._pool = ThreadPoolExecutor(
            max_workers=len(workers),
            thread_name_prefix=f"wg-{self.name}")
        self._failure_handlers: List[Callable[[WorkerFailure], None]] = []

    @classmethod
    def launch(cls, worker_cls, cluster, *, count: int = 1,
               devices_per_worker: Optional[List[Sequence[int]]] = None,
               **worker_kw) -> "WorkerGroup":
        """SPMD launch on a cluster; placement may be decided by the
        scheduler or specified manually (paper §4 device allocation)."""
        workers = []
        for i in range(count):
            devs = (devices_per_worker[i]
                    if devices_per_worker is not None else
                    cluster.allocate(worker_cls.__name__, 1))
            w = worker_cls(
                name=f"{worker_cls.__name__}/{i}",
                devices=devs, process_index=i, **worker_kw)
            workers.append(w)
        return cls(workers)

    def on_failure(self, handler: Callable[[WorkerFailure], None]) -> None:
        self._failure_handlers.append(handler)

    def _wrap(self, w: Worker, fn_name: str, args, kw):
        """Failure handler wrapper (paper §4 failure monitoring): catches
        exceptions, reports, and re-raises so the controller can kill the
        whole workflow instead of hanging on timeouts."""
        def run():
            try:
                fn = getattr(w, fn_name)
                return w._timed(fn_name, fn, *args, **kw)
            except BaseException as e:  # noqa: BLE001
                failure = WorkerFailure(w.name, e, traceback.format_exc())
                for h in self._failure_handlers:
                    h(failure)
                raise failure from e
        return run

    def call(self, fn_name: str, *args, subset: Optional[List[int]] = None,
             per_worker_args: Optional[List[tuple]] = None,
             **kw) -> FutureHandle:
        targets = (self.workers if subset is None
                   else [self.workers[i] for i in subset])
        futures = []
        for i, w in enumerate(targets):
            a = per_worker_args[i] if per_worker_args is not None else args
            futures.append(self._pool.submit(self._wrap(w, fn_name, a, kw)))
        return FutureHandle(futures, self, fn_name)

    def __getattr__(self, item: str):
        # dispatch public worker methods: group.generate(...) etc.
        if item.startswith("_"):
            raise AttributeError(item)
        probe = getattr(type(self.workers[0]), item, None)
        if probe is None or not callable(probe):
            raise AttributeError(item)

        def dispatch(*args, **kw):
            return self.call(item, *args, **kw)

        return dispatch

    def offload_all(self) -> None:
        for w in self.workers:
            w.offload()

    def onload_all(self) -> None:
        for w in self.workers:
            w.onload()

    def shutdown(self) -> None:
        for w in self.workers:
            w.shutdown()
        self._pool.shutdown(wait=False)
