"""Execution Flow Manager: M2Flow transformation of a logical task stream.

Given the schedule chosen by the scheduler, this module re-chunks worker
tasks to the scheduled data granularity (elastic pipelining, §3.3) and
drives the real workers through channels:

  * ``split``  — a task over batch B becomes B/m sub-tasks of size m,
    letting downstream workers start earlier;
  * ``coalesce`` — sub-results are re-assembled when a consumer needs a
    coarser granularity (e.g. the trainer's global batch for the update);
  * temporal stages run under the channel's device lock so context
    switching is automatic and deadlock-free.

This is the *real* executor (threads + JAX on this host); the discrete-
event Simulator mirrors its behaviour at production scale.
"""
from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.channel import AsyncQueue, Channel, ChannelClosed
from repro.core.scheduler import Async, Leaf, Pipelined, Temporal, leaves
from repro.core.worker import WorkerFailure
from repro.obs import trace as _trace


# Bound on every executor-internal join: a worker thread that has not
# finished within this window is wedged, and we want a typed error, not
# a silent hang (or a daemon thread leaking across recoveries).
JOIN_TIMEOUT = 120.0

# thread-name prefixes the executor owns (leak detection scans these)
_THREAD_PREFIXES = ("pipe-prod", "pipe-cons", "cycle-member-",
                    "async-producer", "ctx-prefetch")


class ThreadLeakError(RuntimeError):
    """An executor thread outlived its join window — a wedged producer/
    consumer/cycle-member (or one leaked across a recovery teardown)."""

    def __init__(self, names: Sequence[str], context: str):
        self.thread_names = list(names)
        super().__init__(
            f"{context}: thread(s) {sorted(self.thread_names)} still "
            f"alive after {JOIN_TIMEOUT:.0f}s join timeout")


def _join_all(threads: Sequence[threading.Thread],
              timeout: float = JOIN_TIMEOUT) -> List[threading.Thread]:
    """Join every thread within one shared ``timeout`` budget; returns
    the ones still alive (empty = clean join)."""
    deadline = time.monotonic() + timeout
    for th in threads:
        th.join(timeout=max(deadline - time.monotonic(), 0.0))
    return [th for th in threads if th.is_alive()]


def assert_no_leaked_threads(grace: float = 1.0) -> None:
    """Post-teardown hygiene check (WorkflowRunner.teardown): no
    executor-owned thread may survive the run.  Each suspect gets a
    short grace join (it may be mid-exit); anything still alive raises
    :class:`ThreadLeakError`."""
    suspects = [th for th in threading.enumerate()
                if th.is_alive()
                and any(th.name.startswith(p) for p in _THREAD_PREFIXES)]
    for th in suspects:
        th.join(timeout=grace)
    leaked = [th.name for th in suspects if th.is_alive()]
    if leaked:
        raise ThreadLeakError(leaked, "teardown leaked executor threads")


def leading_leaves(sched) -> List[Leaf]:
    """The leaves that run FIRST under a schedule node — the set a
    context switch must onload at a Temporal cut.  Nested temporal
    stages deeper in the tree onload at their own cuts (onloading the
    whole subtree at once would make sibling temporal stages
    co-resident, peaking memory at the sum of their working sets);
    spatial (Pipelined/Async) sides sit on disjoint devices, so both
    sides' leading stages count."""
    if isinstance(sched, Leaf):
        return [sched]
    if isinstance(sched, Temporal):
        return leading_leaves(sched.s)
    return leading_leaves(sched.s) + leading_leaves(sched.t)


def split_batch(batch: Dict[str, np.ndarray], m: int) -> List[Dict[str, np.ndarray]]:
    """Split a dict-of-arrays batch into chunks of size m along dim 0."""
    B = next(iter(batch.values())).shape[0]
    assert B % m == 0, (B, m)
    out = []
    for i in range(0, B, m):
        out.append({k: v[i:i + m] for k, v in batch.items()})
    return out


def _is_integral_counter(x: Any) -> bool:
    """An int-typed scalar (Python int, np.integer, or 0-d integer
    array) — the only values it is safe to SUM across chunks.  Float
    scalars are typically means/ratios/losses where summing corrupts the
    statistic, and bools are flags; both keep last-chunk semantics."""
    if isinstance(x, (bool, np.bool_)):
        return False
    if isinstance(x, (int, np.integer)):
        return True
    return (isinstance(x, np.ndarray) and x.ndim == 0
            and np.issubdtype(x.dtype, np.integer))


def coalesce(chunks: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    """Re-assemble chunk results.  Batch arrays concatenate along dim 0;
    integral scalar counters (e.g. a simulator's ``successes``) are
    SUMMED across chunks, since each chunk counted only its own share;
    everything else (metrics dicts, float statistics, flags, strings)
    keeps the last chunk's value."""
    out: Dict[str, Any] = {}
    for k in chunks[0].keys():
        vals = [c[k] for c in chunks]
        first = vals[0]
        if isinstance(first, np.ndarray) and first.ndim >= 1:
            out[k] = np.concatenate(vals, axis=0)
        elif _is_integral_counter(first):
            out[k] = sum(vals) if len(vals) > 1 else first
        else:
            out[k] = vals[-1]
    return out


@dataclass
class StagePlan:
    """One executable stage: a worker task at a data granularity."""
    worker: str
    fn: str
    granularity: int
    devices: int
    shares_devices_with_next: bool = False


# ---------------------------------------------------------------------------
# Collapsed-cycle execution (paper §3.4: the embodied sim<->generation
# loop is ONE schedulable node; the executor realizes it as a closed loop)
# ---------------------------------------------------------------------------
_CYCLE_BOOKKEEPING = ("cycle_step", "env_ids", "rollout_round")


def stack_cycle_steps(step_outs: Sequence[Dict[str, Any]]) -> Dict[str, Any]:
    """Default trajectory assembly: per-step arrays stack to (T, ...);
    integral scalar counters (e.g. the simulator's ``successes``) sum
    across steps; everything else keeps the last step's value.  Loop
    bookkeeping keys are dropped."""
    out: Dict[str, Any] = {}
    for k in step_outs[0].keys():
        if k in _CYCLE_BOOKKEEPING:
            continue
        vals = [s[k] for s in step_outs if k in s]
        if len(vals) != len(step_outs):
            continue
        first = vals[0]
        if isinstance(first, np.ndarray) and first.ndim >= 1:
            out[k] = np.stack(vals)  # (T, N, ...)
        elif _is_integral_counter(first):
            out[k] = sum(vals) if len(vals) > 1 else first
        else:
            out[k] = vals[-1]
    return out


def merge_cycle_chunks(chunk_results: Sequence[Dict[str, Any]]
                       ) -> Dict[str, Any]:
    """Re-join per-chunk trajectories from the hybrid realization along
    the env axis (axis 1 of the (T, N, ...) stacks)."""
    out: Dict[str, Any] = {}
    for k in chunk_results[0].keys():
        vals = [r[k] for r in chunk_results]
        first = vals[0]
        if isinstance(first, np.ndarray) and first.ndim >= 2:
            out[k] = np.concatenate(vals, axis=1)
        elif _is_integral_counter(first):
            out[k] = sum(vals) if len(vals) > 1 else first
        else:
            out[k] = vals[-1]
    return out


@dataclass
class CycleSpec:
    """Closed-loop execution recipe for one collapsed cycle node.

    The schedule's Leaf records WHERE the cycle runs (realization +
    device split); the CycleSpec says HOW one loop step flows through
    the members:

      * ``order`` — member invocation order within one step (e.g. the
        policy acts on the current obs, then the simulator steps);
      * ``steps`` — loop iterations (the rollout horizon T);
      * ``prime`` — optional member task run once before the loop to
        seed the carry (e.g. the simulator's initial observation);
      * ``chunks`` — env-axis split for the hybrid realization's
        fine-grained pipeline (2 = double-buffered obs/action queues:
        the simulator steps chunk i while generation acts on chunk i+1);
      * ``collect`` — per-step outputs -> trajectory dict
        (default :func:`stack_cycle_steps`).

    The executor injects ``cycle_step`` (the loop index) and, in hybrid
    mode, per-chunk ``env_ids`` into the carry; member tasks that need
    determinism across realizations must key their randomness on them.
    """
    order: Tuple[str, ...]
    steps: int
    prime: Optional[str] = None
    chunks: int = 2
    collect: Optional[Callable[[Sequence[Dict]], Dict]] = None


class ExecutionFlowManager:
    """Runs a Schedule tree over real workers.

    workers: name -> object exposing the task fn(chunk)->chunk interface
             plus onload/offload (repro.core.worker.Worker API).
    """

    def __init__(self, workers: Dict[str, Any],
                 task_fns: Dict[str, Callable[[Any, Dict], Dict]],
                 switcher: Optional[Any] = None,
                 members: Optional[Dict[str, Tuple[str, ...]]] = None,
                 cycle_specs: Optional[Dict[str, CycleSpec]] = None,
                 heartbeat: Optional[Any] = None,
                 on_failure: Optional[Callable[[WorkerFailure],
                                               None]] = None):
        self.workers = workers
        self.task_fns = task_fns
        # failure surfacing (paper §4): every task death becomes a typed
        # WorkerFailure reported to `on_failure` (the controller) before
        # it propagates; `heartbeat` (core.faults.HeartbeatMonitor) gets
        # a beat around every task call so silence is detectable
        self.heartbeat = heartbeat
        self.on_failure = on_failure
        # managed Temporal transitions (core.switching.ContextSwitcher):
        # per-key offload, prefetch-onload overlap, measured cost feedback
        self.switcher = switcher
        # collapsed-cycle support: node name -> member workers (from the
        # plan) and node name -> CycleSpec (from the workflow runner)
        self.members = members or {}
        self.cycle_specs = cycle_specs or {}
        # what each executed cycle leaf ACTUALLY ran: (node, mode,
        # member_devices, chunks) — plan-honoring tests read this
        self.cycle_log: List[Tuple[str, str, Optional[Tuple[int, ...]],
                                   int]] = []
        self.timeline: List[Tuple[str, float, float, int]] = []
        self._tl_lock = threading.Lock()

    def _record(self, worker: str, t0: float, t1: float, chunk: int) -> None:
        with self._tl_lock:
            self.timeline.append((worker, t0, t1, chunk))

    # ------------------------------------------------------------------
    def run(self, sched, batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        t0 = time.perf_counter()
        out = self._run(sched, batch)
        self.total_time = time.perf_counter() - t0
        return out

    def _apply(self, worker_name: str, chunk: Dict, idx: int) -> Dict:
        w = self.workers[worker_name]
        fn = self.task_fns[worker_name]
        try:
            if getattr(w, "offloaded", False):
                w.onload()
            if self.heartbeat is not None:
                self.heartbeat.beat(worker_name)
            t0 = time.perf_counter()
            out = fn(w, chunk)
            if self.heartbeat is not None:
                self.heartbeat.beat(worker_name)
        except WorkerFailure as f:
            if f.step is None and idx >= 0:
                f.step = idx
            if self.on_failure is not None:
                self.on_failure(f)
            raise
        except BaseException as e:  # noqa: BLE001
            f = WorkerFailure(worker_name, e, traceback.format_exc(),
                              step=idx if idx >= 0 else None)
            if self.on_failure is not None:
                self.on_failure(f)
            raise f from e
        t1 = time.perf_counter()
        self._record(worker_name, t0, t1, idx)
        tr = _trace.active()
        if tr is not None:
            # the executor's task choke point: every worker invocation in
            # every realization passes through here, so this one span is
            # the whole busy timeline (timestamps reused from _record)
            tr.add(worker_name, "task", t0, t1, worker=worker_name,
                   chunk=idx, devices=list(getattr(w, "devices", ())))
        return out

    def _run(self, sched, batch: Dict) -> Dict:
        if isinstance(sched, Leaf):
            if len(self.members.get(sched.worker, ())) > 1:
                return self._run_cycle(sched, batch)
            return self._apply(sched.worker, batch, -1)

        if isinstance(sched, Temporal):
            # prefetch-onload incoming workers whose placement does NOT
            # conflict with the running stage — overlapped with the
            # current stage's tail (nested trees can have disjoint sides)
            pre = None
            incoming = self._expand_cycle_members(
                lf.worker for lf in leading_leaves(sched.t))
            if self.switcher is not None:
                s_devs = self._devices_of(sched.s)
                safe = []
                for name in incoming:
                    w = self.workers.get(name)
                    if (w is not None and getattr(w, "offloaded", False)
                            and set(getattr(w, "devices", ())
                                    ).isdisjoint(s_devs)):
                        safe.append(name)
                if safe:
                    pre = self.switcher.prefetch(safe)
            mid = self._run(sched.s, batch)
            # context switch at the cut: s's device-sharing workers
            # offload first (freeing the shared devices), then t's
            # LEADING stage onloads (deeper stages switch at their own
            # cuts)
            t_devs = self._devices_of(sched.t)
            outgoing = [
                name for name in self._expand_cycle_members(
                    lf.worker for lf in leaves(sched.s))
                if (w := self.workers.get(name)) is not None
                and not set(getattr(w, "devices", ())).isdisjoint(t_devs)]
            if self.switcher is not None:
                if pre is not None:
                    pre.join(timeout=JOIN_TIMEOUT)
                    if pre.is_alive():
                        raise ThreadLeakError(
                            [pre.name], "context-prefetch wedged")
                self.switcher.switch(outgoing, incoming)
            else:
                for name in outgoing:
                    self.workers[name].offload()
            return self._run(sched.t, mid)

        if isinstance(sched, Pipelined):
            m = sched.granularity
            arrs = [v for v in batch.values()
                    if isinstance(v, np.ndarray) and v.ndim >= 1]
            B = arrs[0].shape[0] if arrs else m
            if batch.get("_cycle_traj") or B <= m:
                # single-chunk pipeline — or a cycle trajectory, whose
                # leading axis is TIME, not batch items, so the env-axis
                # chunk contract does not apply: the two sides simply run
                # back-to-back on their disjoint devices
                return self._run(sched.t, self._run(sched.s, batch))
            chunks = split_batch(batch, m)
            # anonymous per-run channel: construct directly — create()
            # would pin it in the global registry forever
            ch = Channel(f"pipe-{id(sched)}-{time.time_ns()}")
            results: List[Optional[Dict]] = [None] * len(chunks)
            err: List[BaseException] = []

            def producer():
                i = -1
                tr = _trace.active()
                try:
                    for i, c in enumerate(chunks):
                        if tr is not None:
                            with tr.span("produce", "pipe", chunk=i):
                                out = self._run(sched.s, c)
                        else:
                            out = self._run(sched.s, c)
                        ch.put((i, out))
                except BaseException as e:  # noqa: BLE001
                    # surface producer-side failures: a silently dead
                    # producer yields an empty coalesce downstream, which
                    # shows up as a confusing KeyError far from the cause
                    if isinstance(e, WorkerFailure) and e.step is None:
                        e.step = i  # the chunk the side died on
                    err.append(e)
                finally:
                    ch.close()

            def consumer():
                i = -1
                tr = _trace.active()
                try:
                    while True:
                        try:
                            i, c = ch.get()
                        except ChannelClosed:
                            break
                        if tr is not None:
                            with tr.span("consume", "pipe", chunk=i):
                                results[i] = self._run(sched.t, c)
                        else:
                            results[i] = self._run(sched.t, c)
                except BaseException as e:  # noqa: BLE001
                    if isinstance(e, WorkerFailure) and e.step is None:
                        e.step = i
                    err.append(e)

            tp = threading.Thread(target=producer, daemon=True,
                                  name=f"pipe-prod-{id(sched)}")
            tc = threading.Thread(target=consumer, daemon=True,
                                  name=f"pipe-cons-{id(sched)}")
            tp.start(); tc.start()
            leaked = _join_all([tp, tc])
            if leaked:
                # wake whichever side is parked on the channel, then give
                # it a moment to unwind before declaring the leak
                ch.close()
                leaked = _join_all(leaked, timeout=5.0)
            if err:
                raise err[0]
            if leaked:
                raise ThreadLeakError([th.name for th in leaked],
                                      "Pipelined stage wedged")
            done = [r for r in results if r is not None]
            return coalesce(done) if done else {}

        if isinstance(sched, Async):
            # A single `run(batch)` call covers ONE iteration of an async
            # plan: producer side then consumer side on their own device
            # shares.  The cross-iteration overlap (producer racing ahead
            # under stale weights) is driven by AsyncPipelineDriver, which
            # owns the iteration loop and the weight-version bookkeeping.
            mid = self._run(sched.s, batch)
            return self._run(sched.t, mid)

        raise TypeError(type(sched))

    # ------------------------------------------------------------------
    # collapsed-cycle leaves: closed-loop execution of the members
    # ------------------------------------------------------------------
    def _run_cycle(self, leaf: Leaf, batch: Dict) -> Dict:
        ms = self.members[leaf.worker]
        spec = self.cycle_specs.get(leaf.worker)
        if spec is None:
            raise KeyError(
                f"no CycleSpec registered for collapsed cycle node "
                f"{leaf.worker!r} (members {ms}); the workflow runner "
                f"must pass cycle_specs to Controller.execute")
        # HONOR the realization the scheduler recorded on the Leaf —
        # the executor must not re-derive (and possibly contradict) it
        mode = leaf.cycle_mode or "collocated"
        chunks = 1
        if mode == "hybrid":
            B = self._cycle_batch_size(batch)
            # the chunk count is part of the recorded realization (the
            # scheduler priced it); spec.chunks is the fallback for
            # hand-built plans
            chunks = max(leaf.cycle_chunks or spec.chunks, 1)
            while chunks > 1 and B % chunks:
                chunks -= 1
            if chunks == 1:
                # no divisible chunking exists: the pipeline degenerates
                # to full-batch alternation — log what actually runs
                mode = "collocated"
        self.cycle_log.append(
            (leaf.worker, mode, leaf.member_devices, chunks))
        out = (self._run_cycle_hybrid(spec, batch, chunks)
               if mode == "hybrid"
               else self._run_cycle_collocated(spec, batch))
        # trajectories are step-major (T, N, ...): mark them so a
        # downstream Pipelined stage never mistakes the time axis for
        # the env-chunk axis
        out["_cycle_traj"] = True
        return out

    @staticmethod
    def _cycle_batch_size(batch: Dict) -> int:
        for v in batch.values():
            if isinstance(v, np.ndarray) and v.ndim >= 1:
                return v.shape[0]
        raise ValueError("cycle batch has no array to infer env count from")

    def _run_cycle_collocated(self, spec: CycleSpec, batch: Dict) -> Dict:
        """Members alternate on the shared devices, one full-batch loop
        step at a time."""
        carry = dict(batch)
        if spec.prime is not None:
            carry = self._apply(spec.prime, carry, -1)
        step_outs: List[Dict] = []
        for t in range(spec.steps):
            carry["cycle_step"] = t
            for m in spec.order:
                carry = self._apply(m, carry, t)
            step_outs.append(dict(carry))
        return (spec.collect or stack_cycle_steps)(step_outs)

    def _run_cycle_hybrid(self, spec: CycleSpec, batch: Dict,
                          chunks: int) -> Dict:
        """Members on disjoint device shares, fine-grained-pipelined over
        env chunks: while the last member (the simulator) steps chunk i,
        the first member (generation) acts on chunk i+1.  Ring of
        channels, one thread per member; at most ``chunks`` carries are
        ever in flight (the double-buffering bound), and each thread
        consumes (step, chunk) pairs in a fixed order, so trajectories
        are bit-identical to the collocated realization when member
        tasks key their randomness on (cycle_step, env_ids)."""
        B = self._cycle_batch_size(batch)
        base_ids = np.asarray(batch.get("env_ids", np.arange(B)))
        subs: List[Dict] = []
        for c in range(chunks):
            lo, hi = c * B // chunks, (c + 1) * B // chunks
            sub = {k: (v[lo:hi] if isinstance(v, np.ndarray)
                       and v.ndim >= 1 else v)
                   for k, v in batch.items()}
            sub["env_ids"] = base_ids[lo:hi]
            subs.append(sub)

        k = len(spec.order)
        # direct construction (not Channel.create): these per-iteration
        # rings are anonymous; registering them would leak an entry in
        # the global Channel registry every training iteration
        rings = [Channel(f"cycle-{i}-{time.time_ns()}")
                 for i in range(k)]
        outs: List[List[Optional[Dict]]] = [
            [None] * spec.steps for _ in range(chunks)]
        err: List[BaseException] = []

        def close_all():
            for ch in rings:
                ch.close()

        def member_loop(idx: int):
            name = spec.order[idx]
            inq, outq = rings[idx], rings[(idx + 1) % k]
            last = idx == k - 1
            try:
                for t in range(spec.steps):
                    for c in range(chunks):
                        carry = inq.get()
                        carry["cycle_step"] = t
                        carry = self._apply(name, carry, t * chunks + c)
                        if last:
                            outs[c][t] = dict(carry)
                            if t < spec.steps - 1:
                                outq.put(carry)
                        else:
                            outq.put(carry)
            except ChannelClosed:
                pass
            except BaseException as e:  # noqa: BLE001
                err.append(e)
                close_all()

        # seed the ring: prime each chunk (initial observation), then
        # feed the first member
        try:
            for c, sub in enumerate(subs):
                carry = (self._apply(spec.prime, sub, -1 - c)
                         if spec.prime is not None else dict(sub))
                rings[0].put(carry)
        except BaseException:
            close_all()
            raise
        threads = [threading.Thread(target=member_loop, args=(i,),
                                    daemon=True,
                                    name=f"cycle-member-{spec.order[i]}")
                   for i in range(k)]
        for th in threads:
            th.start()
        leaked = _join_all(threads)
        close_all()
        if leaked:
            # closing the ring wakes members parked on a get; a member
            # still alive after that is genuinely wedged
            leaked = _join_all(leaked, timeout=5.0)
        if err:
            raise err[0]
        if leaked:
            raise ThreadLeakError([th.name for th in leaked],
                                  "hybrid cycle ring wedged")
        chunk_results = [(spec.collect or stack_cycle_steps)(o)
                         for o in outs]
        return merge_cycle_chunks(chunk_results)

    def _expand_cycle_members(self, names) -> List[str]:
        """Schedule leaves name collapsed cycles by their synthetic node
        name; the REAL workers at a Temporal cut are the members — the
        switcher must see them or cycle members would silently escape
        offload/onload discipline."""
        out: List[str] = []
        for n in names:
            out.extend(self.members.get(n, (n,)))
        return out

    def _devices_of(self, sched) -> set:
        out = set()
        for name in self._expand_cycle_members(
                lf.worker for lf in leaves(sched)):
            w = self.workers.get(name)
            if w is not None:
                out |= set(getattr(w, "devices", ()))
        return out


class AsyncPipelineDriver:
    """Cross-iteration executor for bounded-staleness off-policy training.

    Generation keeps producing rollouts under parameter version ``v`` while
    the trainer advances to ``v+1, v+2, …`` — the producer is gated so that
    no sample is ever consumed more than ``staleness_bound`` (K) versions
    stale:

      * before generating item ``i`` the producer blocks until the
        consumer has published version ``i - K`` (K = 0 → fully sync);
      * ``sync_fn(version)`` then pulls the freshest weights into the
        generation-side workers and the payload is version-tagged on the
        bounded :class:`AsyncQueue` (capacity = K).  If ``sync_fn``
        returns an int, that becomes the tag — letting the caller stamp
        the version of the weights it ACTUALLY pulled (the trainer may
        have advanced between the gate and the sync, and tags must match
        the weights the rollout was generated with);
      * the consumer validates the bound on every ``get`` (strict policy),
        trains, publishes ``version + 1``, and the cycle continues.

    ``produce_fn(i, version) -> payload`` runs the generation-side stages;
    ``consume_fn(item: VersionedItem) -> result`` runs the training-side
    stages (including any staleness importance correction).
    """

    def __init__(self, *, produce_fn: Callable[[int, int], Any],
                 consume_fn: Callable[[Any], Any],
                 sync_fn: Optional[Callable[[int], None]] = None,
                 staleness_bound: int = 1,
                 name: str = "async-pipe"):
        self.produce_fn = produce_fn
        self.consume_fn = consume_fn
        self.sync_fn = sync_fn
        self.staleness_bound = staleness_bound
        self.queue = AsyncQueue(name, staleness_bound=staleness_bound,
                                stale_policy="strict")
        self.results: List[Any] = []
        self._producer_err: List[BaseException] = []

    @property
    def version(self) -> int:
        return self.queue.consumer_version

    def run(self, iterations: int) -> List[Any]:
        """Run the full horizon; returns per-iteration consumer results."""
        K = self.staleness_bound

        def producer():
            try:
                for i in range(iterations):
                    # staleness gate: weights for item i are at least v i-K
                    if not self.queue.wait_for_version(i - K):
                        # queue closed (consumer died): don't waste a full
                        # generation pass on a payload whose put can only
                        # raise ChannelClosed
                        break
                    v = self.queue.consumer_version
                    if self.sync_fn is not None:
                        synced = self.sync_fn(v)
                        if isinstance(synced, int):
                            v = max(v, synced)
                    payload = self.produce_fn(i, v)
                    self.queue.put(payload, version=v)
            except BaseException as e:  # noqa: BLE001
                self._producer_err.append(e)
            finally:
                self.queue.close()

        th = threading.Thread(target=producer, daemon=True,
                              name=f"async-producer-{id(self)}")
        th.start()
        try:
            for _ in range(iterations):
                try:
                    item = self.queue.get()
                except ChannelClosed:
                    break
                self.results.append(self.consume_fn(item))
                self.queue.advance_consumer(self.queue.consumer_version + 1)
        finally:
            self.queue.close()
            th.join(timeout=JOIN_TIMEOUT)
        # surface the root cause first: a producer that died explains a
        # wedged queue far better than the leak it caused
        if self._producer_err:
            raise self._producer_err[0]
        if th.is_alive():
            raise ThreadLeakError([th.name], "async producer wedged")
        return self.results
