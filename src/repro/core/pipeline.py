"""Execution Flow Manager: M2Flow transformation of a logical task stream.

Given the schedule chosen by the scheduler, this module re-chunks worker
tasks to the scheduled data granularity (elastic pipelining, §3.3) and
drives the real workers through channels:

  * ``split``  — a task over batch B becomes B/m sub-tasks of size m,
    letting downstream workers start earlier;
  * ``coalesce`` — sub-results are re-assembled when a consumer needs a
    coarser granularity (e.g. the trainer's global batch for the update);
  * temporal stages run under the channel's device lock so context
    switching is automatic and deadlock-free.

This is the *real* executor (threads + JAX on this host); the discrete-
event Simulator mirrors its behaviour at production scale.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.channel import Channel, ChannelClosed
from repro.core.scheduler import Leaf, Pipelined, Temporal, leaves


def split_batch(batch: Dict[str, np.ndarray], m: int) -> List[Dict[str, np.ndarray]]:
    """Split a dict-of-arrays batch into chunks of size m along dim 0."""
    B = next(iter(batch.values())).shape[0]
    assert B % m == 0, (B, m)
    out = []
    for i in range(0, B, m):
        out.append({k: v[i:i + m] for k, v in batch.items()})
    return out


def coalesce(chunks: Sequence[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    """Re-assemble chunk results; non-batch values (metrics dicts, scalars)
    keep the last chunk's value."""
    out: Dict[str, Any] = {}
    for k in chunks[0].keys():
        vals = [c[k] for c in chunks]
        first = vals[0]
        if isinstance(first, np.ndarray) and first.ndim >= 1:
            out[k] = np.concatenate(vals, axis=0)
        else:
            out[k] = vals[-1]
    return out


@dataclass
class StagePlan:
    """One executable stage: a worker task at a data granularity."""
    worker: str
    fn: str
    granularity: int
    devices: int
    shares_devices_with_next: bool = False


class ExecutionFlowManager:
    """Runs a Schedule tree over real workers.

    workers: name -> object exposing the task fn(chunk)->chunk interface
             plus onload/offload (repro.core.worker.Worker API).
    """

    def __init__(self, workers: Dict[str, Any],
                 task_fns: Dict[str, Callable[[Any, Dict], Dict]]):
        self.workers = workers
        self.task_fns = task_fns
        self.timeline: List[Tuple[str, float, float, int]] = []
        self._tl_lock = threading.Lock()

    def _record(self, worker: str, t0: float, t1: float, chunk: int) -> None:
        with self._tl_lock:
            self.timeline.append((worker, t0, t1, chunk))

    # ------------------------------------------------------------------
    def run(self, sched, batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        t0 = time.perf_counter()
        out = self._run(sched, batch)
        self.total_time = time.perf_counter() - t0
        return out

    def _apply(self, worker_name: str, chunk: Dict, idx: int) -> Dict:
        w = self.workers[worker_name]
        fn = self.task_fns[worker_name]
        if getattr(w, "offloaded", False):
            w.onload()
        t0 = time.perf_counter()
        out = fn(w, chunk)
        self._record(worker_name, t0, time.perf_counter(), idx)
        return out

    def _run(self, sched, batch: Dict) -> Dict:
        if isinstance(sched, Leaf):
            return self._apply(sched.worker, batch, -1)

        if isinstance(sched, Temporal):
            mid = self._run(sched.s, batch)
            # context switch: offload all of s's workers, onload t's lazily
            for lf in leaves(sched.s):
                w = self.workers.get(lf.worker)
                if w is not None and not set(
                        getattr(w, "devices", ())).isdisjoint(
                        self._devices_of(sched.t)):
                    w.offload()
            return self._run(sched.t, mid)

        if isinstance(sched, Pipelined):
            m = sched.granularity
            chunks = split_batch(batch, m)
            ch = Channel.create(f"pipe-{id(sched)}-{time.time_ns()}")
            results: List[Optional[Dict]] = [None] * len(chunks)
            err: List[BaseException] = []

            def producer():
                try:
                    for i, c in enumerate(chunks):
                        out = self._run(sched.s, c)
                        ch.put((i, out))
                finally:
                    ch.close()

            def consumer():
                try:
                    while True:
                        try:
                            i, c = ch.get()
                        except ChannelClosed:
                            break
                        results[i] = self._run(sched.t, c)
                except BaseException as e:  # noqa: BLE001
                    err.append(e)

            tp = threading.Thread(target=producer, daemon=True)
            tc = threading.Thread(target=consumer, daemon=True)
            tp.start(); tc.start()
            tp.join(); tc.join()
            if err:
                raise err[0]
            done = [r for r in results if r is not None]
            return coalesce(done) if done else {}

        raise TypeError(type(sched))

    def _devices_of(self, sched) -> set:
        out = set()
        for lf in leaves(sched):
            w = self.workers.get(lf.worker)
            if w is not None:
                out |= set(getattr(w, "devices", ()))
        return out
