"""Fused sampling Pallas TPU kernel: temperature + top-k + top-p +
Gumbel-max categorical in one pass over the logits row.

The unfused serving path (``serve.sampling.sample_token``) materializes
up to four (B, V) intermediates per decode step — tempered logits, a
``lax.top_k`` result, a full descending sort with softmax/cumsum for the
nucleus cutoff, and the categorical's own Gumbel draw — each a separate
HBM round-trip at vocab widths of 100k+.  This kernel streams the row
once in VMEM and fuses everything:

* **temperature** — static scalar multiply.
* **top-k** — the exact k-th largest value via ``k`` iterations of
  find-max + mask-first-occurrence (k is a small static serving
  parameter; k passes over a VMEM-resident row beat a full HBM sort).
* **top-p** — the nucleus cutoff via binary search on the *order-
  preserving unsigned-int bitcast* of the float row: ~32 fixed
  iterations, each a masked sum, no sort.  The kept set {x : mass
  strictly above x < p} matches the oracle's "smallest sorted prefix
  reaching p, cutoff token always kept" semantics including duplicate
  handling.
* **categorical** — Gumbel-max: ``argmax(filtered + gumbel)`` with the
  Gumbel noise passed IN (generated from the caller's per-request keys,
  so fused and unfused paths draw bit-identical samples).
* **behaviour logprob** — the token's logprob under the *unfiltered*
  temperature-1 policy (what the RL importance ratio references),
  computed from the same resident row.

Grid: (B,) — one program per batch row, rows fully parallel.

Layouts:
  logits (B, V)  block (1, V)
  gumbel (B, V)  block (1, V)
  token  (B, 1)  block (1, 1) int32
  lp     (B, 1)  block (1, 1) float32
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _sort_keys(x: jax.Array) -> jax.Array:
    """Order-preserving map float32 -> uint32: a < b  <=>  key(a) < key(b).

    IEEE-754 trick: non-negative floats order like their bit patterns
    (set the sign bit to lift them above the negatives); negative floats
    order in reverse of their bit patterns (flip all bits).
    """
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    neg = (bits >> 31) == 1
    return jnp.where(neg, ~bits, bits | jnp.uint32(0x80000000))


def _first_argmax(x: jax.Array, idx: jax.Array) -> jax.Array:
    """Index of the first occurrence of the row maximum (matches
    jnp.argmax tie-breaking)."""
    m = jnp.max(x)
    big = jnp.int32(x.shape[-1] * x.shape[-2])
    return jnp.min(jnp.where(x >= m, idx, big))


def _sampling_kernel(logits_ref, gumbel_ref, tok_ref, lp_ref, *,
                     temperature: float, top_k: int, top_p: float,
                     vocab_size: int):
    row = logits_ref[...].astype(jnp.float32)  # (1, V)
    V = row.shape[-1]
    idx = jax.lax.broadcasted_iota(jnp.int32, row.shape, 1)
    if 0 < vocab_size < V:
        row = jnp.where(idx < vocab_size, row, NEG_INF)

    # behaviour logprob normalizer on the UNFILTERED temp-1 row
    m0 = jnp.max(row)
    lse = m0 + jnp.log(jnp.sum(jnp.exp(row - m0)))

    if temperature <= 0.0:
        tok = _first_argmax(row, idx)  # greedy
    else:
        x = row / temperature
        if 0 < top_k < V:
            # exact k-th largest: peel the max k times (duplicates count
            # once per occurrence, exactly like lax.top_k)
            def peel(_, carry):
                work, _ = carry
                m = jnp.max(work)
                first = _first_argmax(work, idx)
                return jnp.where(idx == first, NEG_INF, work), m

            _, cutoff = jax.lax.fori_loop(
                0, top_k, peel, (x, jnp.float32(0.0)))
            x = jnp.where(x < cutoff, NEG_INF, x)
        if top_p < 1.0:
            # nucleus cutoff: binary-search the sort-key space for the
            # smallest value whose strictly-greater mass is < p
            mx = jnp.max(x)
            ex = jnp.exp(x - mx)  # masked entries underflow to 0
            z = jnp.sum(ex)
            keys = _sort_keys(x)
            lo = jnp.min(keys) - jnp.uint32(1)  # H(lo) = 1 >= p
            hi = jnp.max(keys)                  # H(hi) = 0 <  p

            def bisect(_, carry):
                lo, hi = carry
                mid = lo + (hi - lo) // jnp.uint32(2)
                above = jnp.sum(jnp.where(keys > mid, ex, 0.0)) / z
                keep = above >= top_p
                return jnp.where(keep, mid, lo), jnp.where(keep, hi, mid)

            lo, hi = jax.lax.fori_loop(0, 33, bisect, (lo, hi))
            x = jnp.where(keys < hi, NEG_INF, x)
        tok = _first_argmax(x + gumbel_ref[...].astype(jnp.float32), idx)

    tok_lp = jnp.sum(jnp.where(idx == tok, row, 0.0))
    tok_ref[0, 0] = tok.astype(jnp.int32)
    lp_ref[0, 0] = (tok_lp - lse).astype(jnp.float32)


def fused_sample_bv(
    logits: jax.Array,  # (B, V)
    gumbel: jax.Array,  # (B, V) Gumbel(0,1) noise (ignored at temp<=0)
    *,
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 1.0,
    vocab_size: int = 0,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (token (B,) int32, behaviour logprob (B,) float32)."""
    B, V = logits.shape
    assert gumbel.shape == (B, V), (gumbel.shape, logits.shape)
    kernel = functools.partial(
        _sampling_kernel, temperature=float(temperature), top_k=int(top_k),
        top_p=float(top_p), vocab_size=int(vocab_size))
    tok, lp = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, V), lambda b: (b, 0)),
            pl.BlockSpec((1, V), lambda b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(logits.astype(jnp.float32), gumbel.astype(jnp.float32))
    return tok[:, 0], lp[:, 0]
