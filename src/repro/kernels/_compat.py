"""Pallas-TPU API compatibility shared by the kernel modules."""
from jax.experimental.pallas import tpu as pltpu


def _resolve_compiler_params():
    # jax renamed TPUCompilerParams -> CompilerParams; support both pins
    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; unsupported jax version")


CompilerParams = _resolve_compiler_params()
