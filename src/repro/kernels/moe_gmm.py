"""Grouped (per-expert) matmul Pallas TPU kernel for MoE FFN.

Computes out[e] = buf[e] @ w[e] for every expert e over the capacity-
dispatched token buffer — the compute hot-spot of the MoE block after
dispatch.  Grid: (E, C/bc, F/bf, D/bd) with the contraction dimension
sequential and a VMEM f32 accumulator.

Layouts:
  buf: (E, C, D)   block (1, bc, bd)
  w:   (E, D, F)   block (1, bd, bf)
  out: (E, C, F)   block (1, bc, bf)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _gmm_kernel(buf_ref, w_ref, o_ref, acc_scr, *, num_d_blocks: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    b = buf_ref[0].astype(jnp.float32)  # (bc, bd)
    w = w_ref[0].astype(jnp.float32)  # (bd, bf)
    acc_scr[...] += jnp.dot(b, w, preferred_element_type=jnp.float32)

    @pl.when(di == num_d_blocks - 1)
    def _finalize():
        o_ref[0, :, :] = acc_scr[...].astype(o_ref.dtype)


def grouped_matmul(
    buf: jax.Array,  # (E, C, D)
    w: jax.Array,  # (E, D, F)
    *,
    block_c: int = 128,
    block_d: int = 512,
    block_f: int = 128,
    interpret: bool = True,
) -> jax.Array:
    E, C, D = buf.shape
    F = w.shape[-1]
    block_c = min(block_c, C)
    block_d = min(block_d, D)
    block_f = min(block_f, F)
    assert C % block_c == 0 and D % block_d == 0 and F % block_f == 0, (
        (C, D, F), (block_c, block_d, block_f))
    nc, nd, nf = C // block_c, D // block_d, F // block_f
    kernel = functools.partial(_gmm_kernel, num_d_blocks=nd)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), buf.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(buf, w)
