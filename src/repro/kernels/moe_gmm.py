"""Grouped (per-expert) matmul Pallas TPU kernel for MoE FFN.

Computes out[e] = buf[e] @ w[e] for every expert e over the capacity-
dispatched token buffer — the compute hot-spot of the MoE block after
dispatch.  Grid: (E, C/bc, F/bf, D/bd) with the contraction dimension
sequential and a VMEM f32 accumulator.

Layouts:
  buf: (E, C, D)   block (1, bc, bd)
  w:   (E, D, F)   block (1, bd, bf)
  out: (E, C, F)   block (1, bc, bf)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _gmm_kernel(buf_ref, w_ref, o_ref, acc_scr, *, num_d_blocks: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    b = buf_ref[0].astype(jnp.float32)  # (bc, bd)
    w = w_ref[0].astype(jnp.float32)  # (bd, bf)
    acc_scr[...] += jnp.dot(b, w, preferred_element_type=jnp.float32)

    @pl.when(di == num_d_blocks - 1)
    def _finalize():
        o_ref[0, :, :] = acc_scr[...].astype(o_ref.dtype)


def decode_capacity(num_tokens: int) -> int:
    """Drop-free per-expert buffer size for ``moe_decode_gmm``: top-k
    expert indices are distinct per token, so one expert receives at most
    ``num_tokens`` assignments; round up to the MXU tile above 128."""
    if num_tokens <= 128:
        return max(num_tokens, 1)
    return ((num_tokens + 127) // 128) * 128


def moe_decode_gmm(
    x: jax.Array,  # (T, d) tokens at the decode frontier
    expert_idx: jax.Array,  # (T, k) int32 top-k expert ids
    gate_vals: jax.Array,  # (T, k) f32 normalized gate weights
    gate_w: jax.Array,  # (E, d, f)
    up_w: jax.Array,  # (E, d, f)
    down_w: jax.Array,  # (E, f, d)
    *,
    interpret: bool = True,
) -> jax.Array:
    """Expert-parallel decode FFN: token→expert gather into a drop-free
    per-expert buffer, three grouped GEMMs, weighted scatter-add back.

    Unlike the training path's capacity dispatch, nothing is ever
    dropped (capacity = T covers the worst case of every token routing
    to one expert), so the result equals the exact top-k combine — the
    invariant the serve tier's batch-invariance contract needs.
    Returns (T, d).
    """
    T, d = x.shape
    E = gate_w.shape[0]
    k = expert_idx.shape[1]
    C = decode_capacity(T)
    flat_e = expert_idx.reshape(T * k)
    # position of each assignment within its expert's buffer (stable,
    # token-major — the same slot math as the capacity dispatch, minus
    # the overflow bucket)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (Tk, E)
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot
    my_pos = jnp.sum(pos_in_expert * onehot, axis=-1)  # (Tk,)
    slot = flat_e * C + my_pos
    token_ids = jnp.repeat(jnp.arange(T), k)  # (Tk,)
    buf = jnp.zeros((E * C, d), x.dtype).at[slot].set(x[token_ids])
    buf = buf.reshape(E, C, d)
    h = jax.nn.silu(
        grouped_matmul(buf, gate_w, interpret=interpret)
    ) * grouped_matmul(buf, up_w, interpret=interpret)
    out = grouped_matmul(h.astype(x.dtype), down_w, interpret=interpret)
    gathered = out.reshape(E * C, d)[slot]  # (Tk, d)
    weighted = gathered * gate_vals.reshape(T * k, 1).astype(x.dtype)
    return jnp.zeros((T, d), x.dtype).at[token_ids].add(weighted)


def grouped_matmul(
    buf: jax.Array,  # (E, C, D)
    w: jax.Array,  # (E, D, F)
    *,
    block_c: int = 128,
    block_d: int = 512,
    block_f: int = 128,
    interpret: bool = True,
) -> jax.Array:
    E, C, D = buf.shape
    F = w.shape[-1]
    block_c = min(block_c, C)
    block_d = min(block_d, D)
    block_f = min(block_f, F)
    assert C % block_c == 0 and D % block_d == 0 and F % block_f == 0, (
        (C, D, F), (block_c, block_d, block_f))
    nc, nd, nf = C // block_c, D // block_d, F // block_f
    kernel = functools.partial(_gmm_kernel, num_d_blocks=nd)
    return pl.pallas_call(
        kernel,
        grid=(E, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), buf.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(buf, w)
