"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q (B, H, S, D); k/v (B, KV, S, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    KV = k.shape[1]
    G = H // KV
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf)
    s = s / jnp.sqrt(jnp.float32(D))
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm, D):
    """Oracle matching ssd_scan_bhcsp layouts.

    x (B, H, nc, s, P); dt (B, H, nc, s); A/D (B, H); Bm/Cm (B, nc, s, N).
    Sequential state recurrence — obviously correct, O(L) steps.
    """
    B, H, nc, s, P = x.shape
    N = Bm.shape[-1]
    L = nc * s
    xf = x.astype(jnp.float32).transpose(0, 2, 3, 1, 4).reshape(B, L, H, P)
    dtf = dt.astype(jnp.float32).transpose(0, 2, 3, 1).reshape(B, L, H)
    Bf = Bm.astype(jnp.float32).reshape(B, L, N)
    Cf = Cm.astype(jnp.float32).reshape(B, L, N)

    def step(state, inp):
        xi, dti, Bi, Ci = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dti * A)  # (B,H)
        upd = dti[..., None, None] * xi[..., None] * Bi[:, None, None, :]
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, Ci)
        return state, y

    init = jnp.zeros((B, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, init,
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)  # (B, L, H, P)
    y = y + xf * D[:, None, :, None]
    y = y.reshape(B, nc, s, H, P).transpose(0, 3, 1, 2, 4)
    return y.astype(x.dtype)


def grouped_matmul_ref(buf, w):
    return jnp.einsum(
        "ecd,edf->ecf", buf.astype(jnp.float32), w.astype(jnp.float32)
    ).astype(buf.dtype)


def moe_decode_ref(x, expert_idx, gate_vals, gate_w, up_w, down_w):
    """Oracle for the grouped MoE decode GEMM: dense all-experts compute
    plus the exact top-k combine matrix (no capacity, no drops).

    x (T, d); expert_idx/gate_vals (T, k); gate_w/up_w (E, d, f);
    down_w (E, f, d) -> (T, d)
    """
    T = x.shape[0]
    E = gate_w.shape[0]
    xf = x.astype(jnp.float32)
    h = jax.nn.silu(
        jnp.einsum("td,edf->tef", xf, gate_w.astype(jnp.float32))
    ) * jnp.einsum("td,edf->tef", xf, up_w.astype(jnp.float32))
    all_out = jnp.einsum("tef,efd->ted", h, down_w.astype(jnp.float32))
    combine = jnp.zeros((T, E), jnp.float32)
    combine = jax.vmap(lambda c, idx, g: c.at[idx].set(g))(
        combine, expert_idx, gate_vals.astype(jnp.float32))
    return jnp.einsum("te,ted->td", combine, all_out).astype(x.dtype)


def ssm_state_update_ref(state, x, dt, A, Bm, Cm, D):
    """Oracle for the single-token SSD state update (ops layout:
    per-head A/D vectors broadcast over batch inside the wrapper).

    state (B, H, P, N); x (B, H, P); dt (B, H); A/D (H,); Bm/Cm (B, N)
    -> (y (B, H, P) f32, new_state (B, H, P, N) f32)
    """
    state = state.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A[None, :])  # (B, H)
    upd = (dtf[:, :, None, None] * xf[:, :, :, None]) * Bm.astype(
        jnp.float32)[:, None, None, :]
    new_state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(jnp.float32))
    y = y + xf * D[None, :, None]
    return y, new_state


def fused_sample_ref(logits, gumbel, *, temperature=1.0, top_k=0,
                     top_p=1.0, vocab_size=0):
    """Oracle for the fused sampling kernel: the unfused serving path
    (temperature -> top-k -> top-p -> Gumbel-max categorical) with the
    Gumbel noise passed in, plus the behaviour logprob under the
    unfiltered temperature-1 policy.

    logits/gumbel (B, V) -> (token (B,) int32, logprob (B,) float32)
    """
    logits = logits.astype(jnp.float32)
    V = logits.shape[-1]
    if 0 < vocab_size < V:
        logits = jnp.where(jnp.arange(V) < vocab_size, logits, NEG_INF)
    if temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1)
    else:
        x = logits / temperature
        if 0 < top_k < V:
            vals, _ = jax.lax.top_k(x, top_k)
            x = jnp.where(x < vals[..., -1:], NEG_INF, x)
        if top_p < 1.0:
            srt = jnp.sort(x, axis=-1)[..., ::-1]
            cum = jnp.cumsum(jax.nn.softmax(srt, axis=-1), axis=-1)
            cut = jnp.take_along_axis(
                srt, jnp.sum(cum < top_p, axis=-1, keepdims=True), axis=-1)
            x = jnp.where(x < cut, NEG_INF, x)
        tok = jnp.argmax(x + gumbel.astype(jnp.float32), axis=-1)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lp = jnp.take_along_axis(logits, tok[..., None], axis=-1)[..., 0] - lse
    return tok.astype(jnp.int32), lp.astype(jnp.float32)


def paged_attention_ref(q, k_pages, v_pages, block_tables, context_lens):
    """Single-token decode attention over a paged KV cache.

    q            (B, H, D)       one query token per sequence
    k_pages      (P, page, KV, D) page pool (page 0 = trash page)
    v_pages      (P, page, KV, D)
    block_tables (B, nb) int32   per-request page ids (trash-padded)
    context_lens (B,)    int32   valid tokens per request
    -> (B, H, D)
    """
    B, H, D = q.shape
    P, page, KV, _ = k_pages.shape
    nb = block_tables.shape[1]
    G = H // KV
    # gather the logical (B, nb*page, KV, D) K/V views through the tables
    k = k_pages[block_tables].reshape(B, nb * page, KV, D)
    v = v_pages[block_tables].reshape(B, nb * page, KV, D)
    kf = jnp.repeat(k.astype(jnp.float32), G, axis=2)  # (B, S, H, D)
    vf = jnp.repeat(v.astype(jnp.float32), G, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32), kf)
    s = s / jnp.sqrt(jnp.float32(D))
    pos = jnp.arange(nb * page)[None, :]  # logical position per slot
    ok = pos < context_lens[:, None]
    s = jnp.where(ok[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # empty context (context_len == 0): zeros, not a softmax over the mask
    p = jnp.where((context_lens > 0)[:, None, None], p, 0.0)
    return jnp.einsum("bhs,bshd->bhd", p, vf).astype(q.dtype)
