"""Paged-attention decode Pallas TPU kernel (vLLM-style, block tables).

One query token per sequence attends over a KV cache scattered across
fixed-size pages.  The per-request page list (*block table*) is a
scalar-prefetch operand — ``PrefetchScalarGridSpec`` makes it available
to the BlockSpec index maps, so each grid step DMAs exactly the one page
it needs from the pool; the kernel never materializes a request's
logically-contiguous KV view in HBM.

Grid: (batch, kv_heads, num_blocks) — the page dimension is sequential
("arbitrary") so the online-softmax accumulators for the GQA query group
persist in VMEM scratch across pages.

Layouts (last two dims are the tiled ones):
  q        (B, KV, G, D)     block (1, 1, G, D)   G = query group size
  k_pages  (P, page, KV, D)  block (1, page, 1, D)  page picked via table
  v_pages  (P, page, KV, D)  block (1, page, 1, D)
  o        (B, KV, G, D)     block (1, 1, G, D)
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _paged_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *,
                  page_size: int, num_blocks: int, scale: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)      # (G, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (page, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, page)

    # logical positions covered by this page; everything at or past the
    # context length (trash-padded table entries included) is masked out
    pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < lens_ref[b], s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # a fully-masked row keeps m_new == NEG_INF; exp(s - m_new) would be
    # exp(0) = 1 there, silently averaging trash pages — force p = 0 so l
    # stays 0 and _finalize emits zeros for empty contexts
    p = jnp.where(m_new <= NEG_INF * 0.5, 0.0, jnp.exp(s - m_new))
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == num_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_attention_bhd(
    q: jax.Array,             # (B, H, D)
    k_pages: jax.Array,       # (P, page, KV, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, nb) int32
    context_lens: jax.Array,  # (B,) int32
    *,
    interpret: bool = True,
) -> jax.Array:
    B, H, D = q.shape
    P, page, KV, _ = k_pages.shape
    nb = block_tables.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, KV, G, D)
    kernel = functools.partial(
        _paged_kernel, page_size=page, num_blocks=nb, scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # (block_tables, context_lens)
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, kv, j, tables, lens: (b, kv, 0, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, kv, j, tables, lens:
                         (tables[b, j], 0, kv, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, kv, j, tables, lens:
                         (tables[b, j], 0, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, kv, j, tables, lens: (b, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), context_lens.astype(jnp.int32), qg,
      k_pages, v_pages)
    return out.reshape(B, H, D)
