"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Grid: (batch, heads, num_chunks) with the chunk dimension sequential
("arbitrary") — the running inter-chunk state (P, N) lives in VMEM scratch
and is carried across chunk iterations, exactly the recurrence of
models/ssm.ssd_chunked but fused per (batch, head) tile:

  y[c] = (L ⊙ C Bᵀ) diag(dt) x  +  (exp(a_cum) C) · state
  state = exp(a_sum) · state + Σ_s exp(a_sum - a_cum_s) dt_s B_s ⊗ x_s

Layouts:
  x:  (B, H, nc, s, P)   block (1, 1, 1, s, P)
  dt: (B, H, nc, s)      block (1, 1, 1, s)    (post-softplus)
  A:  (B, H)             block (1, 1)          (negative decay rate)
  Bm: (B, nc, s, N)      block (1, 1, s, N)    (shared across heads)
  Cm: (B, nc, s, N)      block (1, 1, s, N)
  D:  (B, H)             block (1, 1)
  y:  (B, H, nc, s, P)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, state_scr,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # (s, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (s,)
    A = a_ref[0, 0].astype(jnp.float32)  # scalar
    Bm = b_ref[0, 0].astype(jnp.float32)  # (s, N)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (s, N)
    Dh = d_ref[0, 0].astype(jnp.float32)

    a = dt * A  # (s,) log-decay
    a_cum = jnp.cumsum(a)  # (s,)

    # intra-chunk quadratic term
    diff = a_cum[:, None] - a_cum[None, :]  # (s, s) i-j
    ii = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 1)
    # mask before exp: avoids overflow fwd and NaN cotangents bwd
    L = jnp.exp(jnp.where(ii >= jj, diff, -1e30))
    CB = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # (s, s)
    W = CB * L * dt[None, :]  # (s, s) weight on x_j
    y = jnp.dot(W, x, preferred_element_type=jnp.float32)  # (s, P)

    # contribution of the carried state
    state = state_scr[...]  # (P, N)
    Cdec = Cm * jnp.exp(a_cum)[:, None]  # (s, N)
    y += jnp.dot(Cdec, state.T, preferred_element_type=jnp.float32)

    # state update
    decay_to_end = jnp.exp(a_cum[-1] - a_cum)  # (s,)
    xb = x * (decay_to_end * dt)[:, None]  # (s, P)
    new_contrib = jnp.dot(xb.T, Bm, preferred_element_type=jnp.float32)
    state_scr[...] = state * jnp.exp(a_cum[-1]) + new_contrib

    y_ref[0, 0, 0, :, :] = (y + Dh * x).astype(y_ref.dtype)


def ssd_scan_bhcsp(
    x: jax.Array,  # (B, H, nc, s, P)
    dt: jax.Array,  # (B, H, nc, s)
    A: jax.Array,  # (B, H)
    Bm: jax.Array,  # (B, nc, s, N)
    Cm: jax.Array,  # (B, nc, s, N)
    D: jax.Array,  # (B, H)
    *,
    interpret: bool = True,
) -> jax.Array:
    B, H, nc, s, P = x.shape
    N = Bm.shape[-1]
    kernel = functools.partial(_ssd_kernel, chunk=s)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, s, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, s), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
            pl.BlockSpec((1, 1, s, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, s, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, s, P),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nc, s, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A, Bm, Cm, D)
