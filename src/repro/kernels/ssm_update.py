"""Mamba2 single-token SSD state-update Pallas TPU kernel (decode).

The decode-time recurrence of ``models/ssm.mamba2_decode`` for ONE token
per sequence, fused per (batch, head) tile:

  state' = exp(dt * A) * state + (dt * x) ⊗ B
  y      = state' · C + D * x

This is the serve tier's per-step hot op for SSM/hybrid cache layouts —
the state-cache analogue of paged attention: constant-size work per
request per token, no sequence dimension.

Layouts:
  state: (B, H, P, N)  block (1, 1, P, N)   f32 running SSD state
  x:     (B, H, P)     block (1, 1, P)      post-conv head inputs
  dt:    (B, H)        block (1, 1)         post-softplus step size
  A:     (B, H)        block (1, 1)         negative decay rate
  Bm:    (B, N)        block (1, N)         input projection (per batch)
  Cm:    (B, N)        block (1, N)         readout projection
  D:     (B, H)        block (1, 1)         skip gain
  y:     (B, H, P)     block (1, 1, P)
  state':(B, H, P, N)  block (1, 1, P, N)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams as _CompilerParams


def _ssm_update_kernel(state_ref, x_ref, dt_ref, a_ref, b_ref, c_ref,
                       d_ref, y_ref, new_state_ref):
    state = state_ref[0, 0].astype(jnp.float32)  # (P, N)
    x = x_ref[0, 0].astype(jnp.float32)  # (P,)
    dt = dt_ref[0, 0].astype(jnp.float32)  # scalar
    A = a_ref[0, 0].astype(jnp.float32)  # scalar
    Bm = b_ref[0].astype(jnp.float32)  # (N,)
    Cm = c_ref[0].astype(jnp.float32)  # (N,)
    Dh = d_ref[0, 0].astype(jnp.float32)  # scalar

    decay = jnp.exp(dt * A)
    new_state = state * decay + (dt * x)[:, None] * Bm[None, :]  # (P, N)
    y = jnp.dot(new_state, Cm, preferred_element_type=jnp.float32)  # (P,)
    y_ref[0, 0, :] = (y + Dh * x).astype(y_ref.dtype)
    new_state_ref[0, 0, :, :] = new_state.astype(new_state_ref.dtype)


def ssm_state_update_bh(
    state: jax.Array,  # (B, H, P, N) f32
    x: jax.Array,  # (B, H, P)
    dt: jax.Array,  # (B, H)
    A: jax.Array,  # (B, H)
    Bm: jax.Array,  # (B, N)
    Cm: jax.Array,  # (B, N)
    D: jax.Array,  # (B, H)
    *,
    interpret: bool = True,
):
    """Returns (y (B, H, P) f32, new_state (B, H, P, N) f32)."""
    B, H, P, N = state.shape
    return pl.pallas_call(
        _ssm_update_kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, P, N), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, P), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, h)),
            pl.BlockSpec((1, 1), lambda b, h: (b, h)),
            pl.BlockSpec((1, N), lambda b, h: (b, 0)),
            pl.BlockSpec((1, N), lambda b, h: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, h: (b, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, P), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(state, x, dt, A, Bm, Cm, D)
