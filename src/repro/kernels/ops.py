"""Jit-friendly wrappers dispatching model layouts onto the Pallas kernels.

On this CPU container kernels always run with ``interpret=True`` (the
Pallas interpreter executes the kernel body on CPU for correctness); on a
real TPU backend set ``repro.kernels.ops.INTERPRET = False`` (or rely on
the automatic backend check) to compile them with Mosaic.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import moe_gmm as _gmm
from repro.kernels import paged_attention as _pa
from repro.kernels import sampling as _samp
from repro.kernels import ssd_scan as _ssd
from repro.kernels import ssm_update as _ssu

# interpret=True whenever we're not actually on TPU
INTERPRET: Optional[bool] = None


def _interpret() -> bool:
    if INTERPRET is not None:
        return INTERPRET
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """Model layout (B, S, H, D) / (B, S, KV, D) -> (B, S, H, D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _fa.flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def paged_attention(q, k_pages, v_pages, block_tables, context_lens):
    """Decode-time paged attention: q (B, H, D) over a (P, page, KV, D)
    page pool addressed through per-request block tables."""
    return _pa.paged_attention_bhd(
        q, k_pages, v_pages, block_tables, context_lens,
        interpret=_interpret())


def ssd_scan(x, dt, A, Bm, Cm, D, chunk: int):
    """Model layout (see models.ssm.mamba2_block):
    x (B, L, H, P), dt (B, L, H), A (H,), Bm/Cm (B, L, N), D (H,)."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    assert L % chunk == 0
    nc = L // chunk
    xk = x.reshape(B, nc, chunk, H, P).transpose(0, 3, 1, 2, 4)
    dtk = dt.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)
    Bk = Bm.reshape(B, nc, chunk, N)
    Ck = Cm.reshape(B, nc, chunk, N)
    Ab = jnp.broadcast_to(A[None, :], (B, H))
    Db = jnp.broadcast_to(D[None, :], (B, H))
    y = _ssd.ssd_scan_bhcsp(xk, dtk, Ab, Bk, Ck, Db,
                            interpret=_interpret())
    # back to (B, L, H, P)
    return y.transpose(0, 2, 3, 1, 4).reshape(B, L, H, P)


def grouped_matmul(buf, w, **kw):
    return _gmm.grouped_matmul(buf, w, interpret=_interpret(), **kw)


def moe_decode(x, expert_idx, gate_vals, gate_w, up_w, down_w):
    """Expert-parallel exact top-k decode FFN (token→expert gather +
    grouped per-expert GEMMs): x (T, d), expert_idx/gate_vals (T, k),
    gate_w/up_w (E, d, f), down_w (E, f, d) -> (T, d)."""
    return _gmm.moe_decode_gmm(x, expert_idx, gate_vals, gate_w, up_w,
                               down_w, interpret=_interpret())


def ssm_state_update(state, x, dt, A, Bm, Cm, D):
    """Single-token SSD state update (models.ssm.mamba2_decode layout):
    state (B, H, P, N) f32, x (B, H, P), dt (B, H), A (H,), Bm/Cm (B, N),
    D (H,) -> (y (B, H, P) f32, new_state (B, H, P, N) f32)."""
    B, H = dt.shape
    Ab = jnp.broadcast_to(A[None, :], (B, H))
    Db = jnp.broadcast_to(D[None, :], (B, H))
    return _ssu.ssm_state_update_bh(state, x, dt, Ab, Bm, Cm, Db,
                                    interpret=_interpret())


def fused_sample(logits, gumbel, *, temperature: float = 1.0,
                 top_k: int = 0, top_p: float = 1.0, vocab_size: int = 0):
    """Fused temperature+top-k+top-p+Gumbel-max sampling over (B, V)
    logits; gumbel is the caller's per-row Gumbel(0,1) noise.  Returns
    (token (B,) int32, behaviour logprob (B,) float32)."""
    return _samp.fused_sample_bv(
        logits, gumbel, temperature=temperature, top_k=top_k, top_p=top_p,
        vocab_size=vocab_size, interpret=_interpret())
