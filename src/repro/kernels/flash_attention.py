"""Flash attention Pallas TPU kernel (GQA + causal + sliding window).

TPU-native adaptation of the flash-attention idea: blocked online-softmax
with the running (m, l, acc) state held in VMEM scratch across the
innermost (sequential) grid dimension, MXU-aligned block shapes, and GQA
expressed through the K/V BlockSpec index map (no K/V replication in HBM).

Grid: (batch, q_heads, num_q_blocks, num_k_blocks) — the k dimension is
"arbitrary" (sequential) so scratch accumulators persist across it.

Layouts (chosen so the last two dims are the MXU-tiled ones):
  q:  (B, H,  S, D)   block (1, 1, block_q, D)
  k:  (B, KV, S, D)   block (1, 1, block_k, D)   index: kv = h // group
  v:  (B, KV, S, D)   block (1, 1, block_k, D)
  o:  (B, H,  S, D)   block (1, 1, block_q, D)
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, causal: bool, window: int,
                  num_k_blocks: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = jnp.ones(s.shape, jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]  # (bq, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, 0, :, :] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (B, H, S, D)
    k: jax.Array,  # (B, KV, S, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, H, S, D = q.shape
    KV = k.shape[1]
    group = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, num_k_blocks=nk, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
