"""Plan-vs-actual analysis: overlay the simulator's predicted schedule
on a traced timeline.

Three outputs, all derived from one :class:`FlowReport`:

  * **device utilization** — per-device busy/bubble fractions computed
    from the executor's task spans and the plan's placement;
  * **gap attribution** — every bubble is charged to the most specific
    cause whose span overlaps it, in priority order
    ``switch > sync > channel-wait > preemption > straggler > idle``
    (a straggler bubble = this device idle while another plan device is
    still busy on the same iteration; what is left is true idle);
  * **drift table** — per-node predicted-vs-measured seconds, the ratio
    the CostModels are off by.  :func:`apply_drift` blends these ratios
    back into the profiles — the same feedback the ROADMAP's online
    re-planner will consume.

This module sits ABOVE core (it imports the Simulator and CostModels);
``obs/__init__`` therefore exposes it lazily so ``core.channel`` can
import ``obs.trace`` without a cycle.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.profiler import CostModel
from repro.core.simulator import SimResult, Simulator
from repro.obs.trace import Tracer

Interval = Tuple[float, float]

# bubble causes in attribution priority order (most specific first)
GAP_CAUSES = ("switch", "sync", "channel-wait", "preemption", "straggler",
              "idle")


# ---------------------------------------------------------------------------
# interval algebra
# ---------------------------------------------------------------------------
def merge_intervals(ivs: Sequence[Interval]) -> List[Interval]:
    out: List[Interval] = []
    for lo, hi in sorted((lo, hi) for lo, hi in ivs if hi > lo):
        if out and lo <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], hi))
        else:
            out.append((lo, hi))
    return out


def total(ivs: Sequence[Interval]) -> float:
    return sum(hi - lo for lo, hi in ivs)


def intersect(a: Sequence[Interval], b: Sequence[Interval]) -> List[Interval]:
    """Intersection of two MERGED (sorted, disjoint) interval lists."""
    out: List[Interval] = []
    i = j = 0
    while i < len(a) and j < len(b):
        lo = max(a[i][0], b[j][0])
        hi = min(a[i][1], b[j][1])
        if hi > lo:
            out.append((lo, hi))
        if a[i][1] < b[j][1]:
            i += 1
        else:
            j += 1
    return out


def subtract(a: Sequence[Interval], b: Sequence[Interval]) -> List[Interval]:
    """a minus b, both merged; returns merged remainder."""
    out: List[Interval] = []
    b = list(b)
    for lo, hi in a:
        cur = lo
        for blo, bhi in b:
            if bhi <= cur or blo >= hi:
                continue
            if blo > cur:
                out.append((cur, blo))
            cur = max(cur, bhi)
            if cur >= hi:
                break
        if cur < hi:
            out.append((cur, hi))
    return out


def complement(ivs: Sequence[Interval], lo: float, hi: float) -> List[Interval]:
    return subtract([(lo, hi)], merge_intervals(ivs))


# ---------------------------------------------------------------------------
# report datatypes
# ---------------------------------------------------------------------------
@dataclass
class DeviceUtil:
    device: int
    busy_s: float
    wall_s: float
    gaps: Dict[str, float] = field(default_factory=dict)

    @property
    def busy_frac(self) -> float:
        return self.busy_s / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def bubble_frac(self) -> float:
        return 1.0 - self.busy_frac


@dataclass
class DriftRow:
    worker: str
    predicted_s: float
    measured_s: float
    calls: int

    @property
    def ratio(self) -> float:
        """measured / predicted — the factor the CostModel is off by
        (1.0 = perfect prediction; 0 predicted with nonzero measured
        reads as inf drift)."""
        if self.predicted_s > 0:
            return self.measured_s / self.predicted_s
        return float("inf") if self.measured_s > 0 else 1.0


@dataclass
class FlowReport:
    predicted_wall: float
    measured_wall: float
    devices: List[DeviceUtil] = field(default_factory=list)
    drift: List[DriftRow] = field(default_factory=list)
    iterations: int = 1

    @property
    def wall_ratio(self) -> float:
        """measured / predicted wall — the headline drift number."""
        if self.predicted_wall > 0:
            return self.measured_wall / self.predicted_wall
        return float("inf") if self.measured_wall > 0 else 1.0

    def bubble_fraction(self) -> float:
        """Device-second-weighted bubble fraction across the plan."""
        wall = sum(d.wall_s for d in self.devices)
        if wall <= 0:
            return 0.0
        return sum(d.wall_s - d.busy_s for d in self.devices) / wall

    def gap_totals(self) -> Dict[str, float]:
        out = {c: 0.0 for c in GAP_CAUSES}
        for d in self.devices:
            for c, s in d.gaps.items():
                out[c] = out.get(c, 0.0) + s
        return out

    def to_json(self) -> Dict[str, Any]:
        return {
            "predicted_wall_s": self.predicted_wall,
            "measured_wall_s": self.measured_wall,
            "wall_ratio": self.wall_ratio,
            "iterations": self.iterations,
            "bubble_fraction": self.bubble_fraction(),
            "gap_totals_s": self.gap_totals(),
            "devices": [
                {"device": d.device, "busy_s": d.busy_s, "wall_s": d.wall_s,
                 "busy_frac": d.busy_frac, "gaps_s": d.gaps}
                for d in self.devices],
            "drift": [
                {"worker": r.worker, "predicted_s": r.predicted_s,
                 "measured_s": r.measured_s, "calls": r.calls,
                 "ratio": r.ratio}
                for r in self.drift],
        }

    def format(self) -> str:
        lines = [
            "== plan vs actual ==",
            f"predicted wall {self.predicted_wall:9.4f}s   "
            f"measured wall {self.measured_wall:9.4f}s   "
            f"ratio {self.wall_ratio:6.3f}   "
            f"({self.iterations} iteration(s))",
            "",
            "-- device utilization --",
            f"{'dev':>4s} {'busy%':>7s} {'busy_s':>9s} "
            + " ".join(f"{c:>12s}" for c in GAP_CAUSES),
        ]
        for d in sorted(self.devices, key=lambda x: x.device):
            lines.append(
                f"{d.device:4d} {100 * d.busy_frac:6.1f}% {d.busy_s:9.4f} "
                + " ".join(f"{d.gaps.get(c, 0.0):12.4f}" for c in GAP_CAUSES))
        lines += [
            f"bubble fraction (device-weighted): "
            f"{100 * self.bubble_fraction():.1f}%",
            "",
            "-- drift table (measured / predicted per node) --",
            f"{'node':24s} {'pred_s':>10s} {'meas_s':>10s} "
            f"{'calls':>6s} {'ratio':>7s}",
        ]
        for r in sorted(self.drift, key=lambda x: x.worker):
            ratio = f"{r.ratio:7.3f}" if r.ratio != float("inf") else "    inf"
            lines.append(f"{r.worker:24s} {r.predicted_s:10.4f} "
                         f"{r.measured_s:10.4f} {r.calls:6d} {ratio}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the analysis
# ---------------------------------------------------------------------------
def _cause_intervals(tracer: Tracer, window: Interval
                     ) -> Dict[str, List[Interval]]:
    """Merged interval lists per attributable cause, clipped later by
    intersection with each gap."""
    out: Dict[str, List[Interval]] = {}
    out["switch"] = merge_intervals(
        [(s.t0, s.t1) for s in tracer.spans("switch")])
    out["sync"] = merge_intervals(
        [(s.t0, s.t1) for s in tracer.spans("sync")])
    out["channel-wait"] = merge_intervals(
        [(s.t0, s.t1) for s in tracer.spans("channel-wait")])
    # preemption is an instant — charge a small neighbourhood around each
    # event so it can claim overlap (the re-prefill cost it stands for
    # has no span of its own)
    eps = max((window[1] - window[0]) * 1e-3, 1e-9)
    out["preemption"] = merge_intervals(
        [(i.t - eps, i.t + eps) for i in tracer.instants()
         if i.name == "preempt"])
    return out


def _worker_of(span) -> Optional[str]:
    return span.args.get("worker")


def plan_vs_actual(plan: Any, profiles: Dict[str, CostModel],
                   tracer: Tracer, total_batch: int,
                   iterations: int = 1,
                   sim: Optional[SimResult] = None) -> FlowReport:
    """Overlay prediction on measurement.

    ``plan`` is a ``core.controller.ExecutionPlan`` (schedule + placement
    + cycle members); ``tracer`` holds the executed run's spans.  The
    prediction is re-simulated here (or passed in via ``sim``) so the
    report never depends on what the planner happened to cache.
    """
    if sim is None:
        simulator = Simulator(profiles, members=getattr(plan, "members", {}))
        sim = simulator.run_iterations(plan.schedule, total_batch, iterations)

    tasks = tracer.spans("task")
    iters = tracer.spans("iteration")
    anchor = iters if iters else tasks
    if anchor:
        window = (min(s.t0 for s in anchor), max(s.t1 for s in anchor))
    else:
        window = (0.0, 0.0)
    wall = window[1] - window[0]

    placement: Dict[str, List[int]] = dict(getattr(plan, "placement", {}))

    # per-device busy intervals from task spans (device ids recorded on
    # the span win; the plan's placement is the fallback)
    busy: Dict[int, List[Interval]] = {}
    for s in tasks:
        w = _worker_of(s)
        devs = s.args.get("devices") or placement.get(w, [])
        for d in devs:
            busy.setdefault(int(d), []).append((s.t0, s.t1))
    for d in {d for devs in placement.values() for d in devs}:
        busy.setdefault(int(d), [])
    busy = {d: merge_intervals(ivs) for d, ivs in busy.items()}

    causes = _cause_intervals(tracer, window)
    devices: List[DeviceUtil] = []
    for d in sorted(busy):
        b = intersect(busy[d], [window])
        gaps = complement(b, *window)
        charged: Dict[str, float] = {c: 0.0 for c in GAP_CAUSES}
        remaining = gaps
        for cause in ("switch", "sync", "channel-wait", "preemption"):
            hit = intersect(remaining, causes[cause])
            charged[cause] = total(hit)
            remaining = subtract(remaining, hit)
        # straggler: this device idle while some OTHER device is busy
        others = merge_intervals(
            [iv for od, ivs in busy.items() if od != d for iv in ivs])
        hit = intersect(remaining, others)
        charged["straggler"] = total(hit)
        remaining = subtract(remaining, hit)
        charged["idle"] = total(remaining)
        devices.append(DeviceUtil(device=d, busy_s=total(b), wall_s=wall,
                                  gaps=charged))

    # drift table: predicted busy seconds per sim worker vs measured task
    # seconds per worker (cycle members fold into their collapsed node,
    # which is the name the simulator prices)
    predicted: Dict[str, float] = {}
    for s in sim.spans:
        if s.kind == "compute":
            predicted[s.worker] = predicted.get(s.worker, 0.0) \
                + (s.end - s.start)
    measured: Dict[str, float] = {}
    calls: Dict[str, int] = {}
    for s in tasks:
        w = _worker_of(s)
        if w is None:
            continue
        measured[w] = measured.get(w, 0.0) + s.dur
        calls[w] = calls.get(w, 0) + 1
    for node, ms in getattr(plan, "members", {}).items():
        if node in predicted and node not in measured:
            measured[node] = sum(measured.pop(m, 0.0) for m in ms)
            calls[node] = sum(calls.pop(m, 0) for m in ms)
    drift = [DriftRow(worker=w, predicted_s=p,
                      measured_s=measured.get(w, 0.0),
                      calls=calls.get(w, 0))
             for w, p in sorted(predicted.items())]

    return FlowReport(predicted_wall=sim.makespan, measured_wall=wall,
                      devices=devices, drift=drift, iterations=iterations)


def apply_drift(profiles: Dict[str, CostModel], report: FlowReport,
                blend: float = 0.5) -> Dict[str, float]:
    """Feed measured drift back into the CostModels.

    Each node's base/slope scale by ``1 - blend + blend * ratio`` —
    blend=0 keeps the profile, blend=1 trusts the measurement outright.
    Nodes with no measured calls (or unbounded ratio) are left alone.
    Returns {worker: applied factor} for logging; this is the hook the
    ROADMAP's online re-planner builds on.
    """
    applied: Dict[str, float] = {}
    for row in report.drift:
        cm = profiles.get(row.worker)
        if cm is None or row.calls == 0 or row.ratio == float("inf"):
            continue
        factor = 1.0 - blend + blend * row.ratio
        if factor <= 0:
            continue
        cm.base_time *= factor
        cm.slope_time *= factor
        applied[row.worker] = factor
    return applied


def replay_sim(sim: SimResult, tracer: Optional[Tracer] = None,
               placement: Optional[Dict[str, List[int]]] = None,
               epoch: float = 0.0) -> Tracer:
    """Convert a Simulator timeline into Tracer spans (one lane per
    worker) so benchmarks and simulated tests share the same report and
    export code as the real runtime.  Compute spans become cat="task"
    (with the placement's device ids when given); switch spans become
    cat="switch"; one cat="iteration" span covers the makespan."""
    if tracer is None:
        tracer = Tracer(clock=lambda: 0.0)
        tracer.epoch = epoch
    for s in sim.spans:
        if s.kind == "switch":
            tracer.add(s.worker, "switch", epoch + s.start, epoch + s.end,
                       lane=s.worker)
        else:
            devs = (placement or {}).get(s.worker, [])
            tracer.add(s.worker, "task", epoch + s.start, epoch + s.end,
                       lane=s.worker, worker=s.worker, chunk=s.chunk,
                       devices=list(devs))
    t0 = min((s.start for s in sim.spans), default=0.0)
    tracer.add("iteration", "iteration", epoch + t0,
               epoch + t0 + sim.makespan, lane="run")
    return tracer


def report_to_json_file(report: FlowReport, path: str) -> None:
    with open(path, "w") as f:
        json.dump(report.to_json(), f, indent=2, sort_keys=True)
        f.write("\n")
