"""Thread-safe span recorder with Chrome-trace/Perfetto JSON export.

The runtime's instrumentation sites (executor task choke point, Pipelined
producer/consumer chunks, ContextSwitcher offload/onload, weight sync,
Channel block time, PagedEngine step loop) all funnel through one global
:class:`Tracer`.  Tracing is **default-off**: the global tracer is
``None`` until :func:`install` (or the :func:`tracing` context manager)
arms it, and every instrumentation site's fast path is a single global
read — the measured overhead bound (executor wall with tracing enabled
within 5% of disabled, enforced in tests) depends on keeping it that way.

Design constraints:

  * **zero dependencies** — stdlib only, importable from every layer
    (``core.channel`` and ``comm.resharding`` both instrument; obs must
    never import back into them);
  * **monotonic clocks** — spans carry absolute ``time.perf_counter``
    stamps; export normalizes to the tracer's epoch.  The clock is
    injectable so tests replay fixed timelines and assert deterministic
    export byte-for-byte;
  * **thread attribution** — each span records the recording thread
    (stable small ids in first-appearance order + thread-name metadata),
    so Perfetto lanes mirror the executor's pipe-prod/pipe-cons/
    cycle-member/ctx-prefetch threads.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple


@dataclass
class Span:
    """One timed interval.  ``t0``/``t1`` are absolute clock readings
    (the tracer's ``clock``); ``tid`` is the tracer-local thread id the
    span was recorded from (or assigned explicitly, e.g. one lane per
    worker when replaying a simulated timeline)."""
    name: str
    cat: str
    t0: float
    t1: float
    tid: int = 0
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


@dataclass
class Instant:
    """A zero-duration event (preemption, weight swap, log line)."""
    name: str
    cat: str
    t: float
    tid: int = 0
    args: Dict[str, Any] = field(default_factory=dict)


@dataclass
class CounterSample:
    """A (name, t, value) timeline sample — exported as a Chrome 'C'
    event so Perfetto renders e.g. channel queue depth over time."""
    name: str
    t: float
    value: float


class Tracer:
    """Span/instant/counter recorder.  All record paths are lock-guarded
    and cheap (append to a list); analysis happens at export time."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self.epoch = clock()
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._instants: List[Instant] = []
        self._counters: List[CounterSample] = []
        # thread ident -> (stable small id, thread name)
        self._tids: Dict[int, Tuple[int, str]] = {}
        # named lanes claimed via explicit tid= (sim replay: one per worker)
        self._lanes: Dict[str, int] = {}
        # context merged into every span/instant's args (e.g. iteration)
        self._ctx: Dict[str, Any] = {}

    # ------------------------------------------------------------------
    # context
    # ------------------------------------------------------------------
    def set_context(self, **kv: Any) -> None:
        """Merge ``kv`` into every subsequently recorded event's args
        (``None`` removes a key).  Used for run-wide attributes the
        recording site cannot know — the training iteration, above all."""
        with self._lock:
            for k, v in kv.items():
                if v is None:
                    self._ctx.pop(k, None)
                else:
                    self._ctx[k] = v

    def _merged(self, args: Dict[str, Any]) -> Dict[str, Any]:
        if not self._ctx:
            return args
        out = dict(self._ctx)
        out.update(args)
        return out

    def _tid(self, lane: Optional[str]) -> int:
        # caller holds self._lock
        if lane is not None:
            if lane not in self._lanes:
                # lanes live above thread ids so they never collide
                self._lanes[lane] = 1000 + len(self._lanes)
            return self._lanes[lane]
        ident = threading.get_ident()
        ent = self._tids.get(ident)
        if ent is None:
            ent = (len(self._tids), threading.current_thread().name)
            self._tids[ident] = ent
        return ent[0]

    # ------------------------------------------------------------------
    # recording
    # ------------------------------------------------------------------
    def add(self, name: str, cat: str, t0: float, t1: float, *,
            lane: Optional[str] = None, **args: Any) -> None:
        """Record a completed interval from timestamps the caller already
        took — the executor's hot path uses this (no context-manager
        overhead around the task call)."""
        with self._lock:
            self._spans.append(Span(name, cat, t0, t1, self._tid(lane),
                                    self._merged(args)))

    def instant(self, name: str, cat: str = "event", t: Optional[float] = None,
                *, lane: Optional[str] = None, **args: Any) -> None:
        with self._lock:
            self._instants.append(
                Instant(name, cat, self.clock() if t is None else t,
                        self._tid(lane), self._merged(args)))

    def counter(self, name: str, value: float,
                t: Optional[float] = None) -> None:
        with self._lock:
            self._counters.append(CounterSample(
                name, self.clock() if t is None else t, float(value)))

    @contextmanager
    def span(self, name: str, cat: str = "span",
             lane: Optional[str] = None, **args: Any) -> Iterator[None]:
        t0 = self.clock()
        try:
            yield
        finally:
            self.add(name, cat, t0, self.clock(), lane=lane, **args)

    def trace(self, name: Optional[str] = None, cat: str = "task",
              **args: Any) -> Callable:
        """Decorator form of :meth:`span`."""
        def deco(fn: Callable) -> Callable:
            label = name or getattr(fn, "__name__", "fn")

            def wrapped(*a: Any, **kw: Any) -> Any:
                with self.span(label, cat, **args):
                    return fn(*a, **kw)

            wrapped.__name__ = getattr(fn, "__name__", label)
            wrapped.__doc__ = fn.__doc__
            return wrapped
        return deco

    # ------------------------------------------------------------------
    # access
    # ------------------------------------------------------------------
    def spans(self, cat: Optional[str] = None) -> List[Span]:
        with self._lock:
            out = list(self._spans)
        if cat is not None:
            out = [s for s in out if s.cat == cat]
        return out

    def instants(self, cat: Optional[str] = None) -> List[Instant]:
        with self._lock:
            out = list(self._instants)
        if cat is not None:
            out = [i for i in out if i.cat == cat]
        return out

    def counters(self) -> List[CounterSample]:
        with self._lock:
            return list(self._counters)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._instants.clear()
            self._counters.clear()

    # ------------------------------------------------------------------
    # Chrome-trace export (open in Perfetto / chrome://tracing)
    # ------------------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """Chrome Trace Event Format dict.  Timestamps are microseconds
        relative to the tracer's epoch; events are sorted on a total
        order (ts, -dur, name, tid) and args keys are emitted sorted, so
        the export is a pure function of the recorded events — identical
        inputs (fixed injected clock) give byte-identical JSON."""
        with self._lock:
            spans = list(self._spans)
            instants = list(self._instants)
            counters = list(self._counters)
            tids = dict(self._tids)
            lanes = dict(self._lanes)

        def us(t: float) -> float:
            return round((t - self.epoch) * 1e6, 3)

        events: List[Dict[str, Any]] = []
        for s in spans:
            events.append({"name": s.name, "cat": s.cat, "ph": "X",
                           "ts": us(s.t0), "dur": round(s.dur * 1e6, 3),
                           "pid": 0, "tid": s.tid,
                           "args": dict(sorted(s.args.items()))})
        for i in instants:
            events.append({"name": i.name, "cat": i.cat, "ph": "i",
                           "ts": us(i.t), "s": "g", "pid": 0, "tid": i.tid,
                           "args": dict(sorted(i.args.items()))})
        for c in counters:
            events.append({"name": c.name, "cat": "counter", "ph": "C",
                           "ts": us(c.t), "pid": 0, "tid": 0,
                           "args": {"value": c.value}})
        events.sort(key=lambda e: (e["ts"], -e.get("dur", 0.0),
                                   e["name"], e["tid"]))
        meta: List[Dict[str, Any]] = [
            {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": "repro"}}]
        for _, (tid, tname) in sorted(tids.items(), key=lambda kv: kv[1][0]):
            meta.append({"name": "thread_name", "ph": "M", "pid": 0,
                         "tid": tid, "args": {"name": tname}})
        for lname, tid in sorted(lanes.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M", "pid": 0,
                         "tid": tid, "args": {"name": lname}})
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f, sort_keys=True,
                      separators=(",", ":"))
            f.write("\n")


# ---------------------------------------------------------------------------
# The global tracer: default-off.  Instrumentation sites call active();
# a None return means "record nothing" and costs one global read.
# ---------------------------------------------------------------------------
_tracer: Optional[Tracer] = None


def active() -> Optional[Tracer]:
    return _tracer


def install(tracer: Optional[Tracer] = None) -> Tracer:
    """Arm tracing globally; returns the installed tracer."""
    global _tracer
    _tracer = tracer if tracer is not None else Tracer()
    return _tracer


def uninstall() -> Optional[Tracer]:
    """Disarm tracing; returns the tracer that was active (its recorded
    events stay readable/exportable)."""
    global _tracer
    prev, _tracer = _tracer, None
    return prev


@contextmanager
def tracing(tracer: Optional[Tracer] = None) -> Iterator[Tracer]:
    """Scoped tracing: installs on entry, restores the previous global
    (usually None) on exit."""
    global _tracer
    prev = _tracer
    tr = install(tracer)
    try:
        yield tr
    finally:
        _tracer = prev
