"""Counter/gauge/histogram registry for runtime metrics.

The runtime's instrumentation sites record channel queue depth, block
time, page-pool utilization, tokens/s, recoveries and straggler beat
intervals here; ``WorkflowRunner.run_loop`` snapshots the registry once
per iteration and merges the lines into its verbose output, and
``tools/flowtrace.py`` prints the final snapshot next to the
plan-vs-actual report.

Like :mod:`repro.obs.trace`, this module is stdlib-only and importable
from every layer.  Hot paths gate on :func:`active` (non-None only while
a tracer is installed), so a run without tracing pays one global read
per site.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Deque, Dict, List, Optional

from repro.obs import trace as _trace


class Counter:
    """Monotonically increasing count (recoveries, preemptions, chunks)."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def snapshot(self) -> Dict[str, float]:
        return {"value": self.value}


class Gauge:
    """Last-set value plus the high-water mark (queue depth, page-pool
    utilization, tokens/s)."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self.max = float("-inf")
        self._set = False
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)
            self._set = True
            if value > self.max:
                self.max = float(value)

    def snapshot(self) -> Dict[str, float]:
        if not self._set:
            return {"value": 0.0, "max": 0.0}
        return {"value": self.value, "max": self.max}


class Histogram:
    """Bounded sample reservoir with percentile snapshots (block times,
    beat intervals).  Keeps the most recent ``window`` observations —
    enough for per-iteration p50/p95 without unbounded growth."""

    WINDOW = 1024

    def __init__(self, name: str, window: int = WINDOW):
        self.name = name
        self.count = 0
        self.total = 0.0
        self._samples: Deque[float] = deque(maxlen=window)
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        with self._lock:
            self.count += 1
            self.total += value
            self._samples.append(float(value))

    @staticmethod
    def _percentile(xs: List[float], pct: float) -> float:
        if not xs:
            return 0.0
        k = max(0, min(len(xs) - 1, int(round(pct / 100.0 * (len(xs) - 1)))))
        return xs[k]

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            xs = sorted(self._samples)
            count, total = self.count, self.total
        return {
            "count": float(count),
            "mean": (total / count) if count else 0.0,
            "p50": self._percentile(xs, 50.0),
            "p95": self._percentile(xs, 95.0),
            "max": xs[-1] if xs else 0.0,
        }


class MetricsRegistry:
    """Thread-safe get-or-create registry of named metrics."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, name: str, cls) -> Any:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """Point-in-time view of every metric, keyed by name.  Counters
        and gauges keep accumulating afterwards — the snapshot is a
        read, not a reset."""
        with self._lock:
            items = list(self._metrics.items())
        return {name: m.snapshot() for name, m in sorted(items)}

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


def format_snapshot(snap: Dict[str, Dict[str, float]],
                    prefix: Optional[str] = None) -> List[str]:
    """Render a snapshot as aligned ``name  k=v ...`` lines (optionally
    filtered to names under ``prefix``)."""
    lines = []
    for name, fields in snap.items():
        if prefix is not None and not name.startswith(prefix):
            continue
        body = "  ".join(f"{k}={v:.6g}" for k, v in fields.items())
        lines.append(f"{name:40s} {body}")
    return lines


# ---------------------------------------------------------------------------
# Global registry.  Always present (so snapshots never need None checks),
# but hot-path sites use active(), which hands it out only while tracing
# is armed — metrics and tracing switch on together.
# ---------------------------------------------------------------------------
_registry = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _registry


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the global registry (tests install a fresh one); returns the
    previous registry."""
    global _registry
    prev, _registry = _registry, reg
    return prev


def active() -> Optional[MetricsRegistry]:
    """The registry, but only while a tracer is installed — hot paths
    gate their metric updates on this so a production run without
    flowtrace records nothing."""
    return _registry if _trace.active() is not None else None
