"""Observability: flow tracing, metrics, and plan-vs-actual reporting.

Layering: ``obs.trace`` and ``obs.metrics`` are stdlib-only so every
runtime module (core.channel, core.pipeline, comm.resharding, serve)
can import them; ``obs.report`` imports core (Simulator, CostModel) and
is therefore exposed LAZILY here — importing ``repro.obs`` from inside
core must never pull core back in.
"""
from repro.obs.metrics import (
    MetricsRegistry,
    default_registry,
    format_snapshot,
    set_registry,
)
from repro.obs.trace import Tracer, active, install, tracing, uninstall

__all__ = [
    "Tracer", "active", "install", "uninstall", "tracing",
    "MetricsRegistry", "default_registry", "set_registry",
    "format_snapshot",
    # lazy (see __getattr__): plan_vs_actual, apply_drift, replay_sim,
    # FlowReport, DeviceUtil, DriftRow
]

_REPORT_NAMES = ("plan_vs_actual", "apply_drift", "replay_sim",
                 "FlowReport", "DeviceUtil", "DriftRow",
                 "report_to_json_file")


def __getattr__(name):
    if name in _REPORT_NAMES:
        from repro.obs import report
        return getattr(report, name)
    raise AttributeError(name)
