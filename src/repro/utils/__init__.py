from repro.utils.hardware import TPU_V5E, DEFAULT_CHIP, ChipSpec  # noqa: F401
