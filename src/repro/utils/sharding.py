"""Sharding helpers: divisibility-aware axis assignment + hint utility.

The production meshes are (data=16, model=16) and (pod=2, data=16,
model=16).  Many assigned architectures have dims that do not divide the
16-way model axis (24 heads, 20 heads, 40 experts ...), so every sharding
rule goes through :func:`maybe_axis` which falls back to replication when
the dim is not divisible — lowering must *never* fail on divisibility.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...], None]

# Canonical axis names
POD = "pod"
DATA = "data"
MODEL = "model"


def axis_size(mesh: Mesh, axis: AxisName) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.shape else 1
    n = 1
    for a in axis:
        n *= mesh.shape[a] if a in mesh.shape else 1
    return n


def batch_axes(mesh: Mesh) -> AxisName:
    """Batch shards over ("pod","data") when the pod axis exists."""
    names = mesh.axis_names
    if POD in names and DATA in names:
        return (POD, DATA)
    if DATA in names:
        return DATA
    return None


def maybe_axis(mesh: Mesh, dim: int, axis: AxisName) -> AxisName:
    """Return ``axis`` if ``dim`` divides its total size, else None.

    For tuple axes, tries progressively shorter prefixes, e.g. a batch of 8
    on (pod=2, data=16) keeps only what divides.
    """
    if axis is None:
        return None
    if isinstance(axis, tuple):
        for k in range(len(axis), 0, -1):
            cand = axis[:k]
            if dim % axis_size(mesh, cand) == 0:
                return cand if len(cand) > 1 else cand[0]
        return None
    return axis if dim % axis_size(mesh, axis) == 0 else None


def spec_for(mesh: Mesh, shape: Sequence[int], axes: Sequence[AxisName]) -> P:
    """Build a PartitionSpec, dropping any axis that does not divide."""
    assert len(shape) == len(axes), (shape, axes)
    resolved = [maybe_axis(mesh, d, a) for d, a in zip(shape, axes)]
    return P(*resolved)


def named(mesh: Mesh, shape: Sequence[int], axes: Sequence[AxisName]) -> NamedSharding:
    return NamedSharding(mesh, spec_for(mesh, shape, axes))


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully-replicated sharding on ``mesh``."""
    return NamedSharding(mesh, P())


def tree_replicated(tree, mesh: Mesh):
    """A pytree of replicated NamedShardings matching ``tree`` — the dst
    side of a weight sync onto a worker's mesh (comm.resharding)."""
    s = replicated(mesh)
    return jax.tree_util.tree_map(lambda _: s, tree)


_ACTIVE_MESH: list = [None]


def set_active_mesh(mesh: Optional[Mesh]) -> None:
    """Register the mesh used for lowering so in-model sharding hints can
    adapt their specs (axis availability + divisibility).  The launchers
    set this; CPU unit tests leave it unset and hints become no-ops."""
    _ACTIVE_MESH[0] = mesh


def get_active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH[0]


def _sanitize_spec(mesh: Mesh, shape, spec: P) -> P:
    """Drop axes the mesh lacks and axes that do not divide the dim."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in mesh.shape)
        entry = axes if len(axes) > 1 else (axes[0] if axes else None)
        if entry is not None and i < len(shape):
            entry = maybe_axis(mesh, shape[i], entry)
        out.append(entry)
    return P(*out)


def shard_hint(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that adapts to the active mesh and is a
    no-op outside any mesh context."""
    mesh = get_active_mesh()
    if mesh is not None:
        spec = _sanitize_spec(mesh, x.shape, spec)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def bytes_of(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for l in leaves:
        if hasattr(l, "shape") and hasattr(l, "dtype"):
            total += int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
    return total
