"""Minimal structured logger (stdout, no deps)."""
from __future__ import annotations

import sys
import time
from typing import Any

_T0 = time.time()
VERBOSE = True


def log(tag: str, msg: str, **kv: Any) -> None:
    if not VERBOSE:
        return
    extra = " ".join(f"{k}={v}" for k, v in kv.items())
    sys.stdout.write(f"[{time.time() - _T0:8.2f}s] {tag:12s} {msg} {extra}\n")
    sys.stdout.flush()
