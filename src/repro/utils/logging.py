"""Structured logger with levels, routed through the obs layer.

Levels follow the usual ladder (``debug < info < warn < error``); the
threshold comes from the ``REPRO_LOG_LEVEL`` environment variable
(default ``info``) or :func:`set_level`.  Each emitted line is formatted
OUTSIDE the lock and written with a single ``write`` call under it, so
lines from the executor's named threads (pipe-prod/pipe-cons/
cycle-member-*) never interleave mid-line; the thread name is part of
the line for exactly that audience.

When tracing is armed (:mod:`repro.obs.trace`), every emitted line also
lands in the trace as an instant event (visible on the Perfetto
timeline next to the spans it explains) and bumps a per-level counter in
the metrics registry — verbose output and metrics share one sink.
"""
from __future__ import annotations

import os
import sys
import threading
import time
from typing import Any

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

LEVELS = {"debug": 10, "info": 20, "warn": 30, "error": 40}

_T0 = time.time()
_lock = threading.Lock()
# back-compat: VERBOSE=False mutes everything below error (the old
# binary switch launch scripts toggle)
VERBOSE = True


def _env_level() -> int:
    name = os.environ.get("REPRO_LOG_LEVEL", "info").strip().lower()
    return LEVELS.get(name, LEVELS["info"])


_level = _env_level()


def set_level(name: str) -> int:
    """Set the threshold programmatically; returns the previous value.
    ``REPRO_LOG_LEVEL`` only sets the import-time default."""
    global _level
    prev = _level
    _level = LEVELS.get(name.strip().lower(), _level)
    return prev


def get_level() -> str:
    for name, v in LEVELS.items():
        if v == _level:
            return name
    return str(_level)


def log(tag: str, msg: str, *, level: str = "info", **kv: Any) -> None:
    lv = LEVELS.get(level, LEVELS["info"])
    tr = _trace.active()
    if tr is not None:
        # the trace keeps every line regardless of the stdout threshold —
        # a debug line invisible on the console still lands on the
        # timeline where it can explain a span
        tr.instant(f"log:{tag}", "log", level=level, msg=msg, **kv)
        reg = _metrics.active()
        if reg is not None:
            reg.counter(f"log/{level}").inc()
    if lv < _level or (not VERBOSE and lv < LEVELS["error"]):
        return
    extra = " ".join(f"{k}={v}" for k, v in kv.items())
    tname = threading.current_thread().name
    line = (f"[{time.time() - _T0:8.2f}s] {level:5s} {tag:12s} "
            f"({tname}) {msg} {extra}".rstrip() + "\n")
    with _lock:
        sys.stdout.write(line)
        sys.stdout.flush()


def debug(tag: str, msg: str, **kv: Any) -> None:
    log(tag, msg, level="debug", **kv)


def info(tag: str, msg: str, **kv: Any) -> None:
    log(tag, msg, level="info", **kv)


def warn(tag: str, msg: str, **kv: Any) -> None:
    log(tag, msg, level="warn", **kv)


def error(tag: str, msg: str, **kv: Any) -> None:
    log(tag, msg, level="error", **kv)
