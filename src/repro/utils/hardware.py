"""Target-hardware constants (TPU v5e) used by the roofline analysis."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops_bf16: float  # FLOP/s per chip
    hbm_bandwidth: float  # bytes/s per chip
    ici_link_bandwidth: float  # bytes/s per link
    hbm_bytes: float  # capacity per chip
    vmem_bytes: float


# Per the assignment: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

DEFAULT_CHIP = TPU_V5E

# Reference profile constants for the *paper's* cluster (H100), used only
# by the event-simulator profiles that mimic Fig. 2/3 workloads.
H100_PEAK_FLOPS_BF16 = 989e12
H100_HBM_BW = 3.35e12
