"""Roofline-term derivation from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

cost_analysis() reports *per-device* FLOPs/bytes on the forced-host-device
backend (verified empirically), so terms divide by peak per chip only.
collective_bytes is parsed out of the compiled HLO text: the summed result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (weighted by how often it executes, i.e. ops inside
a while-loop body count × trip-count when derivable).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.utils.hardware import ChipSpec, DEFAULT_CHIP

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one shape, e.g. bf16[4,128]{1,0} or f32[] or (bf16[2,2], f32[3])
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# an HLO instruction line:  %name = <shape(s)> opcode(
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)", re.M
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result sizes of collective ops in compiled HLO text.

    Scan-based models put collectives inside while-loop bodies; XLA emits
    each loop body once.  We multiply body ops by the loop trip count when
    a ``trip_count=N`` annotation or constant comparison bound is present;
    otherwise they count once (a lower bound, flagged by the caller).
    """
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    bytes_by_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}

    # map computation name -> estimated trip multiplier
    trip: Dict[str, int] = {}
    # while loops reference body=<comp>; find known_trip_count hints
    for m in re.finditer(
        r"while\([^)]*\).*?body=%?([\w.\-]+).*?$", hlo_text, re.M
    ):
        body = m.group(1)
        trip.setdefault(body, 1)
    for m in re.finditer(
        r"body=%?([\w.\-]+)[^\n]*known_trip_count=\{?n=(\d+)", hlo_text
    ):
        trip[m.group(1)] = int(m.group(2))
    # also the standard trip count attribute form
    for m in re.finditer(
        r"body=%?([\w.\-]+)[^\n]*\btrip_count=(\d+)", hlo_text
    ):
        trip[m.group(1)] = int(m.group(2))

    current_comp = None
    multiplier = 1
    for line in hlo_text.splitlines():
        comp_m = re.match(r"^\s*%?([\w.\-]+)\s*\(.*\)\s*->", line)
        if comp_m and ("{" in line or line.rstrip().endswith("->")):
            current_comp = comp_m.group(1)
            multiplier = trip.get(current_comp, 1)
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        shape_str, opcode = m.groups()
        # opcode may carry -start/-done suffixes (async collectives)
        base = opcode.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES:
            if opcode.endswith("-done"):
                continue  # counted at -start
            counts[base] += multiplier
            bytes_by_kind[base] += _shape_bytes(shape_str) * multiplier
    return CollectiveStats(counts=counts, bytes_by_kind=bytes_by_kind)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # per device
    hlo_bytes: float  # per device
    collective_bytes: float  # per device
    model_flops: float  # global 6ND
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    arg_bytes: int = 0
    temp_bytes: int = 0
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def finalize(self, chip: ChipSpec = DEFAULT_CHIP,
                 links_per_chip: int = 4) -> "RooflineReport":
        self.compute_s = self.hlo_flops / chip.peak_flops_bf16
        self.memory_s = self.hlo_bytes / chip.hbm_bandwidth
        self.collective_s = self.collective_bytes / (
            chip.ici_link_bandwidth * links_per_chip
        )
        return self

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (global HLO FLOPs) — catches remat/redundancy."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    def row(self) -> str:
        return (
            f"{self.arch:>24s} {self.shape:>12s} {self.mesh:>9s} "
            f"C={self.compute_s*1e3:9.3f}ms M={self.memory_s*1e3:9.3f}ms "
            f"X={self.collective_s*1e3:9.3f}ms dom={self.dominant:10s} "
            f"useful={self.useful_flops_ratio:6.3f}"
        )


def model_flops(cfg, shape) -> float:
    """6·N·D with N = active params, D = tokens processed per step."""
    n = cfg.active_param_count()
    d = shape.tokens_per_step
    mult = 3.0 if shape.phase == "train" else 1.0  # fwd+bwd = 3x fwd
    return 2.0 * n * d * mult
