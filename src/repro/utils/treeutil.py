"""Small pytree utilities used across the framework."""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_paths(tree) -> Dict[str, Any]:
    """Flatten a pytree into {'/a/b/c': leaf} using dict keys."""
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(f"{prefix}/{k}", v)
        elif hasattr(node, "_fields"):  # NamedTuple
            for f in node._fields:
                rec(f"{prefix}/{f}", getattr(node, f))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}/{i}", v)
        else:
            flat[prefix] = node

    rec("", tree)
    return flat


def map_with_path(fn: Callable[[str, Any], Any], tree):
    """tree_map that passes the '/a/b' path string to fn (dicts/lists only)."""
    if isinstance(tree, dict):
        return {k: _map_with_path_rec(fn, v, f"/{k}") for k, v in tree.items()}
    return _map_with_path_rec(fn, tree, "")


def _map_with_path_rec(fn, node, prefix):
    if isinstance(node, dict):
        return {k: _map_with_path_rec(fn, v, f"{prefix}/{k}") for k, v in node.items()}
    if hasattr(node, "_fields"):  # NamedTuple — use field names in paths
        vals = {
            f: _map_with_path_rec(fn, getattr(node, f), f"{prefix}/{f}")
            for f in node._fields
        }
        return type(node)(**vals)
    if isinstance(node, (list, tuple)):
        t = type(node)
        return t(_map_with_path_rec(fn, v, f"{prefix}/{i}") for i, v in enumerate(node))
    return fn(prefix, node)
