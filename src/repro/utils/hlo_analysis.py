"""Static analysis of compiled (post-SPMD, per-device) HLO text.

``compiled.cost_analysis()`` on the CPU backend counts each while-loop
body ONCE, which under-counts scan-over-layers models by ~num_layers×.
This module walks the HLO call graph from ENTRY, multiplying while-loop
bodies by their ``known_trip_count``, and derives:

  * flops              — 2·M·N·K for every ``dot`` (incl. dots inside
                         fusions), window-scaled for convolutions
  * bytes              — Σ (result + operand bytes) of top-level
                         instructions (fusion internals excluded — they
                         live in registers/VMEM, exactly the roofline's
                         HBM-traffic view)
  * collective bytes   — Σ result bytes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute
                         (async -start counted once, -done skipped)

All numbers are PER DEVICE (the HLO module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s+\((.*)\)\s*->")
# tuple result shapes may contain "/*index=N*/" comments (which contain
# '='), so the tuple alternative matches anything up to the closing paren
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[^\s]+)\s+([\w\-]+)\("
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_WINDOW_RE = re.compile(r"window=\{[^}]*?size=([\dx]+)")


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]  # param name -> shape string
    instrs: List[Instr]


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        m = _COMP_HEADER_RE.match(line)
        if m and line.rstrip().endswith("{"):
            name, params_str = m.groups()
            params: Dict[str, str] = {}
            for pm in re.finditer(r"([\w.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                  params_str):
                params[pm.group(1)] = pm.group(2).strip()
            cur = Computation(name=name, params=params, instrs=[])
            comps[name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = name
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            if line.strip() == "}":
                cur = None
            continue
        iname, shape, opcode = im.groups()
        rest = line[im.end():]
        # operand segment: up to the matching close paren (operands carry
        # no parens in post-optimization HLO text)
        close = rest.find(")")
        arg_seg = rest[:close] if close >= 0 else rest
        operands = re.findall(r"%([\w.\-]+)", arg_seg)
        attrs = rest[close + 1:] if close >= 0 else ""
        cur.instrs.append(Instr(iname, shape, opcode, operands, attrs))
    return comps, entry


@dataclasses.dataclass
class HLOStats:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})
    collective_bytes_by_kind: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    unknown_trip_loops: int = 0


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
    # control flow: carries alias between iterations; the body's own
    # instructions are charged when the body computation is visited
    "while", "conditional", "call",
}


def analyze(text: str) -> HLOStats:
    comps, entry = parse_hlo(text)
    stats = HLOStats()
    if entry is None:
        return stats

    def shape_of(comp: Computation, name: str) -> Optional[str]:
        if name in comp.params:
            return comp.params[name]
        for ins in comp.instrs:
            if ins.name == name:
                return ins.shape
        return None

    seen_guard: List[Tuple[str, float]] = []

    def visit(comp_name: str, mult: float, count_bytes: bool) -> None:
        comp = comps.get(comp_name)
        if comp is None:
            return
        if len(seen_guard) > 10000:  # recursion safety
            return
        seen_guard.append((comp_name, mult))
        for ins in comp.instrs:
            op = ins.opcode
            # ---- flops ----
            if op == "dot":
                lhs_shape = shape_of(comp, ins.operands[0]) if ins.operands else None
                k = 1
                if lhs_shape:
                    dims = _shape_dims(lhs_shape)
                    if dims:
                        lhs_dims = dims[0][1]
                        cm = _LHS_CONTRACT_RE.search(ins.attrs)
                        if cm and cm.group(1):
                            for ci in cm.group(1).split(","):
                                ci = int(ci)
                                if ci < len(lhs_dims):
                                    k *= lhs_dims[ci]
                res_elems = 0
                for _, dims in _shape_dims(ins.shape):
                    n = 1
                    for d in dims:
                        n *= d
                    res_elems += n
                f = 2.0 * res_elems * k * mult
                stats.flops += f
                stats.dot_flops += f
            elif op == "convolution":
                wm = _WINDOW_RE.search(ins.attrs)
                window = 1
                if wm:
                    for d in wm.group(1).split("x"):
                        window *= int(d)
                res_elems = sum(
                    int(__import__("numpy").prod(dims)) if dims else 1
                    for _, dims in _shape_dims(ins.shape))
                f = 2.0 * res_elems * window * mult
                stats.flops += f
                stats.conv_flops += f

            # ---- collectives ----
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                b = _shape_bytes(ins.shape) * mult
                stats.collective_bytes += b
                stats.collective_bytes_by_kind[base] += int(b)
                stats.collective_counts[base] += int(mult)

            # ---- bytes (top-level only) ----
            # Per-op HBM-traffic model:
            #   dynamic-slice:        read+write only the slice (result×2)
            #   dynamic-update-slice: in-place on TPU — read the update,
            #                         write the region (update×2)
            #   gather/broadcast:     indexed/scalar reads ≈ result-sized
            #   default:              result + operands (a fused kernel
            #                         touches each I/O buffer once)
            if count_bytes and op not in _SKIP_BYTES_OPS:
                if op == "dynamic-slice":
                    b = 2 * _shape_bytes(ins.shape)
                elif op == "dynamic-update-slice":
                    upd = (shape_of(comp, ins.operands[1])
                           if len(ins.operands) > 1 else None)
                    b = 2 * (_shape_bytes(upd) if upd
                             else _shape_bytes(ins.shape))
                elif op in ("gather", "broadcast"):
                    b = 2 * _shape_bytes(ins.shape)
                elif op == "scatter":
                    upd = (shape_of(comp, ins.operands[2])
                           if len(ins.operands) > 2 else None)
                    b = 2 * (_shape_bytes(upd) if upd
                             else _shape_bytes(ins.shape))
                else:
                    b = _shape_bytes(ins.shape)
                    for o in ins.operands:
                        s = shape_of(comp, o)
                        if s:
                            b += _shape_bytes(s)
                stats.bytes += b * mult

            # ---- recursion ----
            if op == "while":
                bm = _BODY_RE.search(ins.attrs)
                tm = _TRIP_RE.search(ins.attrs)
                trip = int(tm.group(1)) if tm else 1
                if tm is None:
                    stats.unknown_trip_loops += 1
                if bm:
                    visit(bm.group(1), mult * trip, count_bytes)
            elif op == "fusion":
                cm = _CALLS_RE.search(ins.attrs)
                if cm:
                    # fusion internals: dots count, bytes do not
                    visit(cm.group(1), mult, False)
            elif op in ("call", "async-start"):
                cm = _TO_APPLY_RE.search(ins.attrs) or _CALLS_RE.search(ins.attrs)
                if cm:
                    visit(cm.group(1), mult, count_bytes)
            elif op == "conditional":
                for cm in re.finditer(r"%([\w.\-]+)", ins.attrs):
                    if cm.group(1) in comps:
                        visit(cm.group(1), mult, count_bytes)

    visit(entry, 1.0, True)
    return stats
