"""Paged KV-cache: fixed-size blocks, per-request block tables, free-list.

The production insight (vLLM's PagedAttention, HybridFlow's rollout tier)
is that a generation engine should never reserve ``max_seq_len`` of
contiguous KV memory per request: response lengths are long-tailed
(paper Fig. 2), so contiguous allocation strands most of the cache behind
the few longest responses.  Instead the cache is a pool of fixed-size
*pages*; each request owns a *block table* (list of page ids) that grows
one page at a time and is returned to the free list the moment the
request finishes — which is what lets a continuous-batching scheduler
backfill new prompts mid-stage.

Two layers live here:

* :class:`PageAllocator` — host-side free-list bookkeeping (pure Python,
  runs in the scheduler loop; never traced).
* :class:`PagedKVCache` — the device-side page pool, one K and one V
  array of shape ``(layers, num_pages, page_size, kv_heads, head_dim)``.
  Page 0 is reserved as a *trash page*: inactive decode slots point their
  block tables at it so the fixed-shape jitted step can scatter
  unconditionally without corrupting live requests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, NamedTuple

import jax
import jax.numpy as jnp

# page id 0 is never handed out: it absorbs writes from inactive slots
TRASH_PAGE = 0


class OutOfPages(Exception):
    """The free list is exhausted — the scheduler must stop admitting."""


@dataclass
class PageAllocator:
    """Free-list allocator over ``num_pages`` fixed-size pages.

    Page ids are ints in ``[1, num_pages)`` (0 is the trash page).  The
    free list is LIFO so recently-freed (cache-warm) pages are reused
    first.
    """

    num_pages: int
    page_size: int
    _free: List[int] = field(default_factory=list)
    _allocated: int = 0

    def __post_init__(self):
        assert self.num_pages >= 2, "need >= 1 usable page + trash page"
        assert self.page_size >= 1
        self._free = list(range(self.num_pages - 1, TRASH_PAGE, -1))

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return self._allocated

    def pages_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.page_size)  # ceil

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def allocate(self, n: int = 1) -> List[int]:
        if n > len(self._free):
            raise OutOfPages(f"want {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        self._allocated += n
        return out

    def free(self, pages: List[int]) -> None:
        for p in pages:
            assert p != TRASH_PAGE and 0 < p < self.num_pages, p
            assert p not in self._free, f"double free of page {p}"
            self._free.append(p)
        self._allocated -= len(pages)
        assert self._allocated >= 0


class PagedKVCache(NamedTuple):
    """Device-side page pool shared by every request on the engine.

    k/v: (num_layers, num_pages, page_size, kv_heads, head_dim)
    """

    k: jax.Array
    v: jax.Array

    @property
    def num_pages(self) -> int:
        return self.k.shape[1]

    @property
    def page_size(self) -> int:
        return self.k.shape[2]


def init_paged_cache(num_layers: int, num_pages: int, page_size: int,
                     kv_heads: int, head_dim: int,
                     dtype=jnp.float32) -> PagedKVCache:
    shape = (num_layers, num_pages, page_size, kv_heads, head_dim)
    return PagedKVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def pad_block_table(pages: List[int], max_blocks: int) -> List[int]:
    """Fixed-width row for the jitted step; padding points at the trash
    page (reads there are masked by the context length)."""
    assert len(pages) <= max_blocks, (len(pages), max_blocks)
    return pages + [TRASH_PAGE] * (max_blocks - len(pages))
