"""Paged KV-cache: fixed-size blocks, per-request block tables, free-list,
ref-counted sharing, and a radix prefix index.

The production insight (vLLM's PagedAttention, HybridFlow's rollout tier)
is that a generation engine should never reserve ``max_seq_len`` of
contiguous KV memory per request: response lengths are long-tailed
(paper Fig. 2), so contiguous allocation strands most of the cache behind
the few longest responses.  Instead the cache is a pool of fixed-size
*pages*; each request owns a *block table* (list of page ids) that grows
one page at a time and is returned to the free list the moment the
request finishes — which is what lets a continuous-batching scheduler
backfill new prompts mid-stage.

On top of the pool this module layers *prefix sharing* (vLLM
automatic-prefix-caching / SGLang RadixAttention idiom): pages are
ref-counted, and a radix trie indexes computed pages by the token ids
they hold.  A new request whose prompt matches a cached chain adopts
those pages (incref) instead of re-prefilling them; a partially-matched
page is adopted copy-on-write; the trie holds one reference per indexed
page so finished requests leave their prefixes warm, and LRU leaf
eviction reclaims cache-only pages when the pool runs dry.

Three layers live here:

* :class:`PageAllocator` — host-side free-list + refcount bookkeeping
  (pure Python, runs in the scheduler loop; never traced).  It also
  tracks a per-page *computed watermark*: how many rows of the page hold
  valid KV, which is what lets a follower request fast-forward past a
  shared prefix another request is still prefilling.
* :class:`PrefixCache` — the radix trie over token-id page blocks.
* :class:`PagedKVCache` — the device-side page pool, one K and one V
  array of shape ``(layers, num_pages, page_size, kv_heads, head_dim)``.
  Page 0 is reserved as a *trash page*: inactive decode slots point their
  block tables at it so the fixed-shape jitted step can scatter
  unconditionally without corrupting live requests.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# page id 0 is never handed out: it absorbs writes from inactive slots
TRASH_PAGE = 0


class OutOfPages(Exception):
    """The free list is exhausted — the scheduler must stop admitting."""


class PageAccountingError(Exception):
    """Page refcount bookkeeping went negative: a double free, or a free
    of a page that was never allocated.  Raised instead of silently
    re-entering the free list (which would hand one page to two
    requests and corrupt both KV streams)."""


@dataclass
class PageAllocator:
    """Free-list allocator over ``num_pages`` fixed-size ref-counted pages.

    Page ids are ints in ``[1, num_pages)`` (0 is the trash page).  The
    free list is LIFO so recently-freed (cache-warm) pages are reused
    first.  :meth:`allocate` hands out pages at refcount 1; sharers
    (prefix-cache hits, the trie's own index reference) call
    :meth:`incref`; :meth:`free` decrements and only returns a page to
    the free list when its count reaches zero.
    """

    num_pages: int
    page_size: int
    _free: List[int] = field(default_factory=list)
    _refs: Dict[int, int] = field(default_factory=dict)
    # rows of each live page holding valid (computed) KV — the watermark
    # a follower request may fast-forward through without recomputing
    _computed: Dict[int, int] = field(default_factory=dict)
    # monotonic: total pages ever handed out by allocate() (NOT incref);
    # the prefix-sharing accounting tests assert on this
    pages_allocated_total: int = 0

    def __post_init__(self):
        assert self.num_pages >= 2, "need >= 1 usable page + trash page"
        assert self.page_size >= 1
        self._free = list(range(self.num_pages - 1, TRASH_PAGE, -1))

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return len(self._refs)

    def pages_needed(self, num_tokens: int) -> int:
        return -(-num_tokens // self.page_size)  # ceil

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def allocate(self, n: int = 1) -> List[int]:
        if n > len(self._free):
            raise OutOfPages(f"want {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._refs[p] = 1
            self._computed[p] = 0  # fresh page: no valid rows yet
        self.pages_allocated_total += n
        return out

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def incref(self, pages: Sequence[int]) -> None:
        """Adopt already-allocated pages (a prefix-cache hit, or the trie
        indexing a page).  Every incref must be balanced by a free()."""
        for p in pages:
            if self._refs.get(p, 0) <= 0:
                raise PageAccountingError(
                    f"incref of unallocated page {p}")
            self._refs[p] += 1

    def free(self, pages: Sequence[int]) -> None:
        """Drop one reference per page; a page whose count reaches zero
        returns to the free list."""
        for p in pages:
            assert p != TRASH_PAGE and 0 < p < self.num_pages, p
            refs = self._refs.get(p, 0)
            if refs <= 0:
                raise PageAccountingError(f"double free of page {p}")
            if refs == 1:
                del self._refs[p]
                self._computed.pop(p, None)
                self._free.append(p)
            else:
                self._refs[p] = refs - 1

    # -- computed-row watermarks -------------------------------------------
    def note_computed(self, page: int, rows: int) -> None:
        """Record that the first ``rows`` rows of ``page`` hold valid KV.
        Monotone per page lifetime (reset when the page is reallocated)."""
        if self._refs.get(page, 0) > 0 and rows > self._computed.get(page, 0):
            self._computed[page] = min(rows, self.page_size)

    def computed_rows(self, page: int) -> int:
        return self._computed.get(page, 0)


# ===========================================================================
# Radix prefix index (vLLM prefix caching / SGLang RadixAttention idiom)
# ===========================================================================
class PrefixNode:
    """One page worth of tokens in the radix trie.

    ``key`` is the tuple of token ids the page's rows hold.  Internal
    nodes always cover a *full* page (``len(key) == page_size``); a node
    with fewer tokens is a partial leaf — matched copy-on-write, never
    descended through.  ``writer`` is the rid of the request currently
    prefilling this page (followers wait on it instead of duplicating
    the compute); it is cleared when the writer finishes or is
    preempted.
    """

    __slots__ = ("key", "page", "parent", "children", "last_used",
                 "writer")

    def __init__(self, key: Tuple[int, ...], page: int,
                 parent: Optional["PrefixNode"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], PrefixNode] = {}
        self.last_used = 0
        self.writer: Optional[int] = None

    @property
    def num_tokens(self) -> int:
        return len(self.key)


class PrefixMatch(NamedTuple):
    """Result of :meth:`PrefixCache.lookup`.

    ``nodes`` are the fully-matched full-page nodes, in chain order
    (their pages can be adopted outright).  ``partial`` is the deepest
    child sharing ``partial_rows`` leading tokens with the remaining
    prompt — a copy-on-write candidate — or None.
    """

    nodes: List[PrefixNode]
    partial: Optional[PrefixNode]
    partial_rows: int

    @property
    def full_tokens(self) -> int:
        return sum(n.num_tokens for n in self.nodes)


class PrefixCache:
    """Radix trie over token-id page blocks.

    Each node indexes exactly one page and holds one allocator reference
    on it, so indexed pages survive their writer finishing — that
    retention is what makes a GRPO group's shared prompt (or a
    deep-research episode's growing history) prefill once.  When the
    pool runs dry, :meth:`evict` walks leaves least-recently-used first
    and drops pages nobody else references.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self.root = PrefixNode((), -1, None)
        self._clock = itertools.count(1)
        self._nodes = 0
        # rid -> nodes that request is responsible for prefilling
        self._writers: Dict[int, List[PrefixNode]] = {}
        # monotonic stats (cheap; surfaced by obs metrics)
        self.hits = 0
        self.evictions = 0

    @property
    def num_pages(self) -> int:
        """Pages currently indexed (== trie nodes == cache-held refs)."""
        return self._nodes

    # -- lookup --------------------------------------------------------------
    def lookup(self, tokens: Sequence[int]) -> PrefixMatch:
        """Longest cached prefix of ``tokens``: full-page chain plus an
        optional partial (copy-on-write) boundary node.  Touches the
        matched chain's LRU stamps."""
        now = next(self._clock)
        node = self.root
        nodes: List[PrefixNode] = []
        i = 0
        psz = self.page_size
        while len(tokens) - i >= 1:
            child = node.children.get(tuple(tokens[i:i + psz]))
            if child is None or child.num_tokens < psz:
                break
            child.last_used = now
            nodes.append(child)
            node = child
            i += psz
        # boundary: the child sharing the most leading tokens with the
        # remaining prompt donates those rows copy-on-write
        best, best_rows = None, 0
        rest = tokens[i:]
        for child in node.children.values():
            rows = 0
            for a, b in zip(child.key, rest):
                if a != b:
                    break
                rows += 1
            if rows > best_rows:
                best, best_rows = child, rows
        if best is not None:
            best.last_used = now
        if nodes or best is not None:
            self.hits += 1
        return PrefixMatch(nodes, best, best_rows)

    # -- insertion -------------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int],
               allocator: PageAllocator, *, start: int = 0,
               writer: Optional[int] = None) -> List[PrefixNode]:
        """Index ``tokens[start:]`` under the chain covering
        ``tokens[:start]`` (``start`` must be page-aligned).  ``pages``
        is the owning request's full block table; each new node increfs
        its page (the cache's own reference).  Returns the nodes created
        (the ones ``writer`` is responsible for computing)."""
        psz = self.page_size
        assert start % psz == 0, start
        now = next(self._clock)
        # re-walk to the start boundary (caller matched these already)
        node = self.root
        for i in range(0, start, psz):
            node = node.children[tuple(tokens[i:i + psz])]
        created: List[PrefixNode] = []
        for i in range(start, len(tokens), psz):
            key = tuple(tokens[i:i + psz])
            existing = node.children.get(key)
            if existing is not None and existing.num_tokens == psz:
                node = existing  # already indexed (idempotent re-insert)
                continue
            if existing is not None:
                # same key already present as a partial leaf of another
                # page — keep the old one, don't shadow it
                break
            page = int(pages[i // psz])
            grown = self._regrow(node, key, page, now)
            if grown is not None:
                # the page was already indexed by a shorter partial leaf
                # (left at admission, before decode filled more rows) —
                # re-keying it in place keeps one node per page, so the
                # cache holds exactly one reference and eviction still
                # sees refcount 1 once every request lets go
                if grown.num_tokens < psz:
                    break
                node = grown
                continue
            child = PrefixNode(key, page, node)
            child.last_used = now
            child.writer = writer
            allocator.incref([child.page])
            node.children[key] = child
            created.append(child)
            self._nodes += 1
            if len(key) < psz:
                break  # partial tail is always a leaf
            node = child
        if writer is not None and created:
            self._writers.setdefault(writer, []).extend(created)
        return created

    def _regrow(self, node: PrefixNode, key: Tuple[int, ...], page: int,
                now: int) -> Optional[PrefixNode]:
        """If ``page`` is already indexed under ``node`` as a partial leaf
        whose key is a prefix of ``key`` (or an extension of it), return
        that node — re-keyed to the longer of the two — instead of letting
        the caller create a second node for the same physical page."""
        for child in node.children.values():
            if child.page != page:
                continue
            short, long_ = sorted((child.key, key), key=len)
            if long_[:len(short)] != short:
                return None  # same page, diverged content: caller creates
            if child.key != long_:
                del node.children[child.key]
                child.key = long_
                node.children[long_] = child
            child.last_used = now
            return child
        return None

    # -- writer lifecycle -----------------------------------------------------
    def release_writer(self, rid: int) -> None:
        """The prefilling request finished or was preempted: followers
        blocked on its nodes fall back to computing the rows themselves
        (or fast-forward, if the watermark already covers them)."""
        for node in self._writers.pop(rid, ()):
            if node.writer == rid:
                node.writer = None

    # -- eviction ---------------------------------------------------------------
    def evict(self, need: int, allocator: PageAllocator) -> int:
        """Free up to ``need`` cache-only pages, least-recently-used
        leaves first.  A page some request still references
        (refcount > 1) or that is still being written is never dropped.
        Returns the number of pages actually freed."""
        freed = 0
        while freed < need:
            victim = None
            for node in self._iter_leaves():
                if allocator.refcount(node.page) > 1:
                    continue  # pinned by a running request
                if node.writer is not None:
                    continue
                if victim is None or node.last_used < victim.last_used:
                    victim = node
            if victim is None:
                break
            self._remove(victim, allocator)
            freed += 1
            self.evictions += 1
        return freed

    def flush(self, allocator: PageAllocator) -> int:
        """Drop the whole index (weight swap: cached KV is stale).  Pages
        running requests still hold survive via their own references."""
        dropped = 0
        # post-order: children before parents
        stack = [(self.root, False)]
        while stack:
            node, seen = stack.pop()
            if not seen:
                stack.append((node, True))
                stack.extend((c, False) for c in node.children.values())
                continue
            if node is self.root:
                continue
            node.writer = None  # nobody waits on a detached node
            allocator.free([node.page])
            dropped += 1
        self.root = PrefixNode((), -1, None)
        self._nodes = 0
        self._writers.clear()
        return dropped

    def _iter_leaves(self):
        stack = [self.root]
        while stack:
            node = stack.pop()
            if node is not self.root and not node.children:
                yield node
            stack.extend(node.children.values())

    def _remove(self, node: PrefixNode, allocator: PageAllocator) -> None:
        assert not node.children, "evict leaves only"
        del node.parent.children[node.key]
        allocator.free([node.page])  # the cache's own reference
        self._nodes -= 1


class PagedKVCache(NamedTuple):
    """Device-side page pool shared by every request on the engine.

    k/v: (num_layers, num_pages, page_size, kv_heads, head_dim)
    """

    k: jax.Array
    v: jax.Array

    @property
    def num_pages(self) -> int:
        return self.k.shape[1]

    @property
    def page_size(self) -> int:
        return self.k.shape[2]


def init_paged_cache(num_layers: int, num_pages: int, page_size: int,
                     kv_heads: int, head_dim: int,
                     dtype=jnp.float32) -> PagedKVCache:
    shape = (num_layers, num_pages, page_size, kv_heads, head_dim)
    return PagedKVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def pad_block_table(pages: List[int], max_blocks: int) -> List[int]:
    """Fixed-width row for the jitted step; padding points at the trash
    page (reads there are masked by the context length)."""
    assert len(pages) <= max_blocks, (len(pages), max_blocks)
    return pages + [TRASH_PAGE] * (max_blocks - len(pages))
