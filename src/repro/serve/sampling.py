"""Sampling utilities shared by the serving engines.

Filters (top-k, nucleus/top-p) reshape only the *sampling* distribution;
the behaviour logprob returned to the RL stack is always evaluated under
the unfiltered temperature-1 policy (the same distribution the
inference worker's prefill recompute scores), so importance ratios stay
well-defined whatever decoding strategy produced the trajectory.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import token_logprobs

NEG_INF = -1e30


def mask_padded_vocab(logits: jax.Array, vocab_size: int) -> jax.Array:
    """Embedding tables are padded for sharding; never sample the pad."""
    if vocab_size <= 0:
        return logits
    V = logits.shape[-1]
    return jnp.where(jnp.arange(V) < vocab_size, logits, NEG_INF)


def top_k_logits(logits: jax.Array, k: int) -> jax.Array:
    """Keep the k highest logits, mask the rest to -inf.  k<=0 disables."""
    if k <= 0 or k >= logits.shape[-1]:
        return logits
    vals, _ = jax.lax.top_k(logits, k)
    cutoff = vals[..., -1:]
    return jnp.where(logits < cutoff, NEG_INF, logits)


def top_p_logits(logits: jax.Array, p: float) -> jax.Array:
    """Nucleus filter: keep the smallest prefix of the sorted distribution
    whose mass reaches p (the cutoff token itself is always kept, so the
    argmax survives even for tiny p)."""
    if p >= 1.0:
        return logits
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cut_idx = jnp.sum(cum < p, axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cut_idx, axis=-1)
    return jnp.where(logits < cutoff, NEG_INF, logits)


def sample_token(
    key: jax.Array,
    logits: jax.Array,  # (..., V)
    *,
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 1.0,
    vocab_size: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Draw a token and return ``(token int32, behaviour logprob f32)``.

    temperature <= 0 is greedy (argmax); otherwise temperature scales the
    logits FIRST and the filters apply to the tempered distribution
    (temperature -> top-k -> top-p, the standard serving order: the
    nucleus is computed on the same distribution that is sampled).
    """
    logits = mask_padded_vocab(logits.astype(jnp.float32), vocab_size)
    if temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1)
    else:
        filtered = top_p_logits(top_k_logits(logits / temperature, top_k),
                                top_p)
        tok = jax.random.categorical(key, filtered, axis=-1)
    # behaviour logprob under the unfiltered temp-1 policy (see module doc)
    lp = token_logprobs(logits, tok)
    return tok.astype(jnp.int32), lp


def sample_tokens_fused(
    keys: jax.Array,    # (B, 2) per-row PRNG keys (same keys sample_token
    logits: jax.Array,  # (B, V)  would receive row-by-row)
    *,
    temperature: float = 1.0,
    top_k: int = 0,
    top_p: float = 1.0,
    vocab_size: int = 0,
) -> Tuple[jax.Array, jax.Array]:
    """Batched :func:`sample_token` through the fused Pallas kernel.

    ``jax.random.categorical`` IS Gumbel-max (``argmax(logits +
    gumbel(key))``), so drawing the Gumbel noise here from the same
    per-row keys and fusing filter+argmax in the kernel reproduces the
    unfused path draw-for-draw; parity sweeps in test_kernels.py hold
    the two together.
    """
    from repro.kernels import ops as kops

    logits = logits.astype(jnp.float32)
    B, V = logits.shape
    if temperature <= 0.0:
        gumbel = jnp.zeros_like(logits)  # greedy: noise unused
    else:
        gumbel = jax.vmap(
            lambda k: jax.random.gumbel(k, (V,), jnp.float32))(keys)
    return kops.fused_sample(
        logits, gumbel, temperature=temperature, top_k=top_k, top_p=top_p,
        vocab_size=vocab_size)
