"""Sampling utilities shared by the serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def top_k_logits(logits: jax.Array, k: int) -> jax.Array:
    if k <= 0:
        return logits
    vals, _ = jax.lax.top_k(logits, k)
    cutoff = vals[..., -1:]
    return jnp.where(logits < cutoff, -1e30, logits)


def top_p_logits(logits: jax.Array, p: float) -> jax.Array:
    if p >= 1.0:
        return logits
    sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    cut_idx = jnp.sum(cum < p, axis=-1, keepdims=True)
    cutoff = jnp.take_along_axis(sorted_logits, cut_idx, axis=-1)
    return jnp.where(logits < cutoff, -1e30, logits)
