"""Rollout/serving engines: static batch (legacy) and paged continuous.

Two engines implement the "rollout worker" compute of the M2Flow runtime
(the paper's SGLang/vLLM role):

* :class:`Engine` — the original fixed-shape engine: one ``lax.scan``
  over ``max_new_tokens`` with a per-sequence `done` mask.  Every request
  is padded to the longest response, so devices idle behind the long
  tail (paper Fig. 2).
* :class:`PagedEngine` — continuous batching over a paged KV cache: the
  decode batch is re-formed every step (finished requests immediately
  free their pages, queued prompts backfill), attention reads the cache
  through per-request block tables (optionally via the Pallas
  paged-attention kernel), and trainer weight updates apply *in flight*
  at step boundaries with per-request version tags preserved for the
  staleness correction.

Both return per-token *behaviour logprobs* so the trainer can form
importance ratios without a separate inference pass when the collocated
mode is chosen (one-forward-pass trick, §5.3).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.attention import NEG_INF
from repro.serve import layouts as layouts_mod
from repro.serve.paging import (
    OutOfPages,
    PageAllocator,
    PrefixCache,
    pad_block_table,
)
from repro.obs import metrics as _metrics
from repro.obs import trace as _trace
from repro.serve.sampling import sample_token
from repro.serve.scheduler import RUNNING, ContinuousScheduler, Request


class GenerationResult(NamedTuple):
    tokens: jax.Array  # (B, S_total) prompt + generated (PAD after EOS)
    logprobs: jax.Array  # (B, S_total) behaviour logprob per token (0 on prompt)
    lengths: jax.Array  # (B,) total valid length
    done: jax.Array  # (B,) bool — hit EOS before max tokens
    # weight version each request was admitted under (all zeros on the
    # legacy engine; the paged engine tags every request so the staleness
    # correction can reference the actual behaviour-policy version)
    weight_versions: Optional[np.ndarray] = None


def _sample(key, logits: jax.Array, temperature: float, vocab_size: int,
            top_k: int = 0, top_p: float = 1.0):
    """Categorical sample with padded-vocab masking; temp<=0 = greedy."""
    return sample_token(key, logits, temperature=temperature, top_k=top_k,
                        top_p=top_p, vocab_size=vocab_size)


class Engine:
    """Owns jitted prefill/decode functions for one model config."""

    def __init__(self, cfg: ModelConfig, *, max_new_tokens: int = 32,
                 temperature: float = 1.0, top_k: int = 0,
                 top_p: float = 1.0, eos_token: int = 2,
                 pad_token: int = 0):
        self.cfg = cfg
        self.max_new_tokens = max_new_tokens
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.eos = eos_token
        self.pad = pad_token
        self._generate = jax.jit(self._generate_impl, static_argnames=("B", "S"))
        self._act = None  # lazily jitted closed-loop action path

    # ------------------------------------------------------------------
    def _generate_impl(self, params, prompt_tokens, prompt_lens, key, *,
                       B: int, S: int):
        cfg = self.cfg
        total = S + self.max_new_tokens
        state = M.init_decode_state(cfg, B, total)

        # ---- prefill the (left-padded) prompt ----
        logits, state = M.prefill(params, cfg, prompt_tokens, state)
        last = logits[:, 0]  # (B, V)

        out_tokens = jnp.concatenate(
            [prompt_tokens,
             jnp.full((B, self.max_new_tokens), self.pad, jnp.int32)], axis=1)
        out_lp = jnp.zeros((B, total), jnp.float32)

        def step(carry, i):
            state, last, toks, lps, done, key = carry
            key, sub = jax.random.split(key)
            tok, lp = _sample(sub, last, self.temperature, cfg.vocab_size,
                              top_k=self.top_k, top_p=self.top_p)
            tok = jnp.where(done, self.pad, tok)
            lp = jnp.where(done, 0.0, lp)
            pos = S + i
            toks = jax.lax.dynamic_update_slice(toks, tok[:, None], (0, pos))
            lps = jax.lax.dynamic_update_slice(lps, lp[:, None], (0, pos))
            newdone = done | (tok == self.eos)
            logits, state = M.decode_step(params, cfg, tok[:, None], state, pos)
            return (state, logits[:, 0], toks, lps, newdone, key), None

        done0 = jnp.zeros((B,), bool)
        (state, last, out_tokens, out_lp, done, _), _ = jax.lax.scan(
            step, (state, last, out_tokens, out_lp, done0, key),
            jnp.arange(self.max_new_tokens))
        lengths = S + jnp.sum(
            (out_tokens[:, S:] != self.pad).astype(jnp.int32), axis=1)
        return GenerationResult(out_tokens, out_lp, lengths, done)

    # ------------------------------------------------------------------
    def generate(self, params, prompt_tokens, prompt_lens=None,
                 key=None) -> GenerationResult:
        """prompt_tokens: (B, S) int32 left-padded prompts."""
        B, S = prompt_tokens.shape
        if key is None:
            key = jax.random.PRNGKey(0)
        if prompt_lens is None:
            prompt_lens = jnp.full((B,), S, jnp.int32)
        return self._generate(params, prompt_tokens, prompt_lens, key, B=B, S=S)

    # ------------------------------------------------------------------
    # per-step constrained action sampling (the embodied cycle's path)
    # ------------------------------------------------------------------
    def _act_impl(self, params, prompt_tokens, env_keys, *, lo: int,
                  hi: int):
        logits, _ = M.forward(params, self.cfg, prompt_tokens)
        last = logits[:, -1].astype(jnp.float32)
        idx = jnp.arange(last.shape[-1])
        last = jnp.where((idx >= lo) & (idx < hi), last, NEG_INF)
        # one key PER ROW: sampling is invariant to how the env batch is
        # chunked (the hybrid cycle realization splits it), so collocated
        # and hybrid execution draw identical actions
        toks = jax.vmap(jax.random.categorical)(env_keys, last)
        lse = jax.nn.logsumexp(last, axis=-1)
        lps = jnp.take_along_axis(last, toks[:, None], axis=-1)[:, 0] - lse
        return toks.astype(jnp.int32), lps.astype(jnp.float32)

    def act(self, params, prompt_tokens, env_keys, *, action_lo: int,
            action_hi: int):
        """One closed-loop policy step: a single prefill forward, logits
        masked to the action-token window ``[action_lo, action_hi)``,
        per-row categorical sampling under explicit per-env keys.
        Returns (action_tokens (B,), behaviour logprobs (B,))."""
        if self._act is None:
            self._act = jax.jit(self._act_impl,
                                static_argnames=("lo", "hi"))
        return self._act(params, jnp.asarray(prompt_tokens), env_keys,
                         lo=action_lo, hi=action_hi)


# ===========================================================================
# Continuous-batching engine over per-architecture cache layouts
# ===========================================================================
class PagedEngine:
    """Continuous-batching rollout engine with a paged KV cache.

    The engine advances *all* active requests by one token per
    :meth:`step` — mixed prefill/decode (Orca-style iteration-level
    scheduling): a request still consuming its prompt is teacher-forced,
    one past it feeds back its sampled token.  The jitted step runs over
    ``max_batch`` fixed slots (inactive slots write to the reserved trash
    page and are ignored on the host), so one compilation serves every
    batch composition the scheduler produces.

    Weight sync: :meth:`update_weights` enqueues a versioned update that
    is applied at the next step boundary *without draining the engine* —
    running requests keep their pages and simply continue under the new
    weights; each request records the version it was admitted under
    (``weight_version``, what the staleness correction references) and
    the newest version that produced any of its tokens
    (``last_weight_version``).

    Sampling is per-request deterministic: token ``i`` of request ``r``
    is drawn from ``fold_in(PRNGKey(r.seed), position)``, so results do
    not depend on how requests were batched together.
    """

    def __init__(self, cfg: ModelConfig, *, max_batch: int = 8,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 max_seq_len: Optional[int] = None,
                 max_new_tokens: int = 32, temperature: float = 1.0,
                 top_k: int = 0, top_p: float = 1.0, eos_token: int = 2,
                 pad_token: int = 0, use_kernel: bool = False,
                 prefix_sharing: bool = True, prefill_chunk: int = 32,
                 use_sampling_kernel: Optional[bool] = None,
                 dtype=jnp.float32):
        layout_cls = layouts_mod.layout_class(cfg)
        if layout_cls is None:
            raise NotImplementedError(
                "PagedEngine does not window the paged cache yet"
                if cfg.sliding_window else
                f"PagedEngine has no cache layout for kind={cfg.kind}")
        self.cfg = cfg
        self.max_batch = max_batch
        self.page_size = page_size
        self.max_seq_len = max_seq_len or cfg.max_seq_len
        self.max_new_tokens = max_new_tokens
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.eos = eos_token
        self.pad = pad_token
        self.use_kernel = use_kernel
        # fused sampling: like use_kernel, the Pallas path only pays off
        # compiled; default ON on TPU, OFF under CPU interpret mode
        if use_sampling_kernel is None:
            use_sampling_kernel = jax.default_backend() == "tpu"
        self.use_sampling_kernel = use_sampling_kernel
        # per-step prompt-token budget for chunked prefill (0 = legacy
        # token-by-token prefill through the decode step)
        self.prefill_chunk = (int(prefill_chunk)
                              if layout_cls.supports_chunked_prefill else 0)
        if layout_cls.uses_pages:
            self.max_blocks = -(-self.max_seq_len // page_size)
            # default pool: every slot holds a full sequence (+ trash page)
            if num_pages is None:
                num_pages = max_batch * self.max_blocks + 1
            # the pool must at least hold ONE full sequence, or the oldest
            # request could never finish even with everyone else preempted
            assert num_pages - 1 >= self.max_blocks, (num_pages,
                                                      self.max_blocks)
        else:
            # constant-size layouts keep the allocator as an inert stub
            # (page_size still parameterizes host bookkeeping); requests
            # cost zero pages, so the pool size is irrelevant
            self.max_blocks = 1
            if num_pages is None:
                num_pages = 2
        self.allocator = PageAllocator(num_pages=num_pages,
                                       page_size=page_size)
        # the radix trie (full-page adoption + partial-page COW) only
        # attaches to layouts that can honour it; state layouts do their
        # own exact-full-prompt snapshot reuse instead
        self.prefix_cache: Optional[PrefixCache] = (
            PrefixCache(page_size)
            if prefix_sharing and layout_cls.supports_partial_cow else None)
        self.layout = layout_cls(
            cfg, max_batch=max_batch, page_size=page_size,
            num_pages=num_pages, max_blocks=self.max_blocks,
            max_seq_len=self.max_seq_len, temperature=temperature,
            top_k=top_k, top_p=top_p, use_kernel=use_kernel,
            use_sampling_kernel=self.use_sampling_kernel, dtype=dtype,
            prefix_cache=self.prefix_cache, prefix_sharing=prefix_sharing)
        self.scheduler = ContinuousScheduler(
            max_batch=max_batch, allocator=self.allocator,
            max_seq_len=self.max_seq_len, prefix_cache=self.prefix_cache,
            cost_model=self.layout.cost_model(),
            preempt_keeps_progress=self.layout.preempt_keeps_progress)
        # -- weights + in-flight sync --------------------------------------
        self.params: Any = None
        self.weight_version: int = 0
        self._pending: deque = deque()  # (version, params), newest wins
        self._sync_lock = threading.Lock()
        self.weight_swaps = 0
        # -- bookkeeping ----------------------------------------------------
        # bounded: records feed the profiler's tail fit; without a
        # consumer the log must not grow for the life of the worker
        self.finished_log: deque = deque(maxlen=4096)
        self.decode_steps = 0

    @property
    def cache(self):
        """The layout's device cache (a :class:`PagedKVCache` for KV
        layouts, a stacked :class:`repro.models.model.DecodeState` for
        state layouts)."""
        return self.layout.cache

    # ------------------------------------------------------------------
    # weights
    # ------------------------------------------------------------------
    def set_params(self, params: Any, version: Optional[int] = None) -> None:
        """Apply immediately (initial load / synchronous callers)."""
        self.params = params
        if version is not None:
            self.weight_version = version

    def update_weights(self, params: Any,
                       version: Optional[int] = None) -> None:
        """Enqueue an in-flight update; applied at the next step boundary.
        Thread-safe — the trainer may call this while the engine loop is
        mid-generation."""
        with self._sync_lock:
            if version is None:
                # auto-version past any still-pending update, or two
                # back-to-back enqueues would share one tag for
                # different parameter sets
                base = self._pending[-1][0] if self._pending \
                    else self.weight_version
                version = base + 1
            self._pending.append((version, params))

    def rebind_devices(self, sharding) -> None:
        """Re-place the engine's device-resident state — page pools,
        applied params, pending updates — onto ``sharding``.  Called when
        the execution plan rebinds the rollout worker's device slice: the
        KV pool must live where the weights live, or the jitted step sees
        inputs committed to incompatible device sets."""
        def put(tree):
            return jax.tree_util.tree_map(
                lambda x: (jax.device_put(x, sharding)
                           if isinstance(x, jax.Array) else x), tree)

        with self._sync_lock:
            self.layout.rebind(sharding)
            if self.params is not None:
                self.params = put(self.params)
            self._pending = deque(
                (v, put(p)) for v, p in self._pending)

    def _apply_pending(self) -> None:
        # params/weight_version are written under the lock: update_weights
        # reads weight_version to auto-assign the next version, so an
        # unlocked write could hand the same tag to two parameter sets
        with self._sync_lock:
            if not self._pending:
                return
            version, params = self._pending[-1]  # newest update wins
            skipped = len(self._pending) - 1
            self._pending.clear()
            self.params = params
            self.weight_version = version
            self.weight_swaps += 1 + skipped
        # cached prefixes were computed under the OLD weights: a request
        # admitted after the swap must not adopt stale KV.  Running
        # requests keep their pages (in-flight sync semantics); only the
        # cache's own references are dropped.
        if self.prefix_cache is not None:
            self.prefix_cache.flush(self.allocator)
        self.layout.on_weight_swap()
        tr = _trace.active()
        if tr is not None:
            tr.instant("weight-swap", "engine", version=version,
                       skipped=skipped)
            reg = _metrics.active()
            if reg is not None:
                reg.counter("engine/weight_swaps").inc(1 + skipped)

    # ------------------------------------------------------------------
    # request lifecycle
    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], *,
               max_new_tokens: Optional[int] = None,
               seed: int = 0) -> Request:
        return self.scheduler.submit(
            list(int(t) for t in prompt),
            max_new_tokens if max_new_tokens is not None
            else self.max_new_tokens,
            seed=seed, weight_version=self.weight_version)

    # ------------------------------------------------------------------
    # host-side engine loop
    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit, advance every active request, join/evict.  Returns the
        number of requests advanced (chunk-prefilled or decoded).

        Per step: pending COW copies run first, then each request (rid
        order) fast-forwards ``num_cached`` through shared pages as far
        as their computed watermarks allow, requests blocked behind an
        in-flight writer of their shared prefix sit the step out, the
        remaining prompt work is chunk-prefilled under the
        ``prefill_chunk`` token budget, and everyone at the sampling
        frontier decodes one token in the fixed-shape batch."""
        tr = _trace.active()
        reg = _metrics.active()
        t_step = time.perf_counter() if tr is not None else 0.0
        self._apply_pending()  # before the check: update_weights() alone
        # is a valid way to deliver the initial weights
        assert self.params is not None, "engine weights not initialized"
        joined = self.scheduler.admit(weight_version=self.weight_version)
        for q in joined:
            # layout-private admission work: slot reset / snapshot
            # restore / exact-prefix-match reuse (state layouts)
            skipped = self.layout.on_admit(q)
            if skipped:
                self.scheduler.stats.prefix_hit_tokens += skipped
                if reg is not None:
                    reg.counter("serve/prefix_hit_tokens").inc(skipped)
        self._perform_cow_copies()
        self._grow_pages_or_preempt()
        reqs = self.scheduler.active_requests()
        if tr is not None:
            util = (self.allocator.num_allocated
                    / max(self.allocator.num_pages, 1))
            tr.counter("engine/page_util", util)
            if reg is not None:
                reg.gauge("engine/page_util").set(util)
                if self.prefix_cache is not None:
                    reg.gauge("serve/radix_pages").set(
                        self.prefix_cache.num_pages)
        if not reqs:
            if tr is not None:
                tr.add("engine-step", "engine", t_step, time.perf_counter(),
                       advanced=0, prefill=0, decode=0, chunked=0)
            return 0
        budget = self.prefill_chunk
        chunked_tokens = 0
        chunk_only = 0  # advanced by chunk but not yet at the frontier
        deferred = 0
        decode_reqs: List[Request] = []
        waiting: List[Request] = []
        for r in sorted(reqs, key=lambda q: q.rid):
            skipped = self._fast_forward(r)
            if skipped and reg is not None:
                reg.counter("serve/prefix_hit_tokens").inc(skipped)
            if self._waiting_on_writer(r):
                # the shared page under our cursor is still being filled
                # by its writer; wait instead of duplicating its prefill
                waiting.append(r)
                continue
            if self.prefill_chunk > 0 and r.num_cached < r.total_len - 1:
                need = r.total_len - 1 - r.num_cached
                grant = min(need, budget)
                if grant > 0:
                    self._prefill_chunk_step(r, grant)
                    budget -= grant
                    chunked_tokens += grant
                    # a chunk may complete up to a watermark another
                    # sharer extended meanwhile
                    self._fast_forward(r)
                if r.num_cached < r.total_len - 1:
                    deferred += r.total_len - 1 - r.num_cached
                    chunk_only += 1 if grant > 0 else 0
                    continue  # still mid-prompt: no frontier this step
            decode_reqs.append(r)
        if not decode_reqs and chunked_tokens == 0 and waiting:
            # safety valve: never let the whole step idle on writers
            # (cannot happen under the acyclic wait order, but a stalled
            # step here would be an infinite loop in run())
            decode_reqs = waiting
        if decode_reqs:
            B = self.max_batch
            tokens = np.zeros((B,), np.int32)
            positions = np.zeros((B,), np.int32)
            tables = np.zeros((B, self.max_blocks), np.int32)  # trash page
            seeds = np.zeros((B,), np.int32)
            active = np.zeros((B,), bool)
            for r in decode_reqs:
                pos = r.num_cached
                if pos < r.prompt_len:
                    tokens[r.slot] = r.prompt[pos]
                else:
                    tokens[r.slot] = r.generated[pos - r.prompt_len]
                positions[r.slot] = pos
                if r.pages:
                    tables[r.slot] = pad_block_table(r.pages,
                                                     self.max_blocks)
                seeds[r.slot] = r.seed
                active[r.slot] = True
            tok, lp = self.layout.step(self.params, tokens, positions,
                                       tables, seeds, active)
            tok_np, lp_np = np.asarray(tok), np.asarray(lp)
            for r in decode_reqs:
                pos = r.num_cached
                r.num_cached += 1
                r.last_weight_version = self.weight_version
                if r.pages:
                    page = self.page_size
                    self.allocator.note_computed(r.pages[pos // page],
                                                 pos % page + 1)
                self.layout.note_progress(r)
                # sample only at the frontier: during prompt prefill AND
                # during post-preemption replay of already-generated
                # tokens the step is teacher-forced and its sampled token
                # is discarded
                if pos == r.total_len - 1 and pos >= r.prompt_len - 1:
                    t = int(tok_np[r.slot])
                    r.generated.append(t)
                    r.logprobs.append(float(lp_np[r.slot]))
                    if t == self.eos or len(r.generated) >= r.max_new_tokens:
                        r.hit_eos = t == self.eos
                        # only index KV produced wholly under the current
                        # weights — spans of a mid-flight swap are stale
                        idx = r.weight_version == self.weight_version
                        self.layout.on_finish(r, index_in_cache=idx)
                        self.scheduler.finish(r, index_in_cache=idx)
        if deferred:
            self.scheduler.stats.chunk_deferred_tokens += deferred
            if reg is not None:
                reg.counter("serve/prefill_chunk_deferred").inc(deferred)
        self.decode_steps += 1
        self.scheduler.stats.steps += 1
        advanced = len(decode_reqs) + chunk_only
        if tr is not None:
            # num_cached already advanced: a slot still inside its prompt
            # was a prefill (teacher-forced) step, the rest decoded
            prefill = sum(1 for r in decode_reqs
                          if r.num_cached < r.prompt_len)
            tr.add("engine-step", "engine", t_step, time.perf_counter(),
                   advanced=advanced, prefill=prefill,
                   decode=len(decode_reqs) - prefill,
                   chunked=chunked_tokens)
        return advanced

    # ------------------------------------------------------------------
    # prefix sharing + chunked prefill plumbing
    # ------------------------------------------------------------------
    def _fast_forward(self, r: Request) -> int:
        """Advance ``num_cached`` through the shared-prefix region as far
        as the adopted pages' computed watermarks allow (never past the
        sampling frontier).  Returns the number of positions skipped —
        prompt tokens this request will never prefill."""
        if r.shared_len <= r.num_cached:
            return 0
        page = self.page_size
        ceiling = min(r.shared_len, r.total_len - 1)
        skipped = 0
        while r.num_cached < ceiling:
            pidx = r.num_cached // page
            avail = pidx * page + self.allocator.computed_rows(
                r.pages[pidx])
            if avail <= r.num_cached:
                break
            new = min(avail, ceiling)
            skipped += new - r.num_cached
            r.num_cached = new
        if skipped:
            self.scheduler.stats.prefix_hit_tokens += skipped
        return skipped

    def _waiting_on_writer(self, r: Request) -> bool:
        """True when the shared page under the request's cursor is still
        being prefilled by another running request (the trie writer):
        the follower waits for watermarks to advance instead of
        recomputing rows the writer will produce anyway."""
        if r.num_cached >= min(r.shared_len, r.total_len - 1):
            return False
        pidx = r.num_cached // self.page_size
        if pidx >= len(r.shared_nodes):
            return False  # COW tail: those rows are ours to compute
        writer = r.shared_nodes[pidx].writer
        return writer is not None and writer != r.rid

    def _perform_cow_copies(self) -> None:
        """Run the device copies the scheduler planned at admission: the
        computed rows of a shared partial page land in the request's
        private page, the watermark follows, and the pinned source is
        released (decref)."""
        for r in self.scheduler.active_requests():
            if r.pending_cow is None:
                continue
            src, dst, rows = r.pending_cow
            self.layout.cow(src, dst)
            self.allocator.note_computed(dst, rows)
            self.allocator.free([src])  # release the admission pin
            r.pending_cow = None
            reg = _metrics.active()
            if reg is not None:
                reg.counter("serve/cow_pages").inc()

    def _prefill_chunk_step(self, r: Request, grant: int) -> None:
        """Cache ``grant`` positions of request ``r`` starting at
        ``num_cached`` in one jitted forward (prompt tokens, or generated
        tokens during post-preemption replay) and advance the watermarks
        so sharers can fast-forward behind us."""
        start = r.num_cached
        end = start + grant
        C = self.prefill_chunk
        toks = np.zeros((C,), np.int32)
        poss = np.zeros((C,), np.int32)
        for i, pos in enumerate(range(start, end)):
            toks[i] = (r.prompt[pos] if pos < r.prompt_len
                       else r.generated[pos - r.prompt_len])
            poss[i] = pos
        self.layout.prefill_chunk_step(self.params, toks, poss, grant, r)
        r.num_cached = end
        r.last_weight_version = self.weight_version
        if r.pages:
            page = self.page_size
            for pidx in range(start // page, (end - 1) // page + 1):
                self.allocator.note_computed(
                    r.pages[pidx], min(end - pidx * page, page))
        self.layout.note_progress(r)

    def release_prefix_cache(self) -> int:
        """Drop every cache-held page reference (tests, memory pressure,
        or an explicit reset between workloads).  Running requests keep
        theirs.  Returns the number of trie nodes dropped."""
        if self.prefix_cache is None:
            return 0
        return self.prefix_cache.flush(self.allocator)

    def _grow_pages_or_preempt(self) -> None:
        """Back every active request's next slot with a page.  When the
        pool runs dry, preempt the YOUNGEST active request (freeing all
        its pages; it re-queues at the head and recomputes on resume) so
        the oldest requests always make progress — admission guarantees
        a lone request fits, so this cannot livelock."""
        for r in sorted(self.scheduler.active_requests(),
                        key=lambda r: r.rid):
            if r.state != RUNNING:  # preempted earlier in this loop
                continue
            while True:
                try:
                    self.scheduler.ensure_page_for(r)
                    break
                except OutOfPages:
                    victims = [v for v in self.scheduler.active_requests()
                               if v.rid > r.rid]
                    victim = max(victims, key=lambda v: v.rid) if victims \
                        else r  # r itself is youngest: it yields
                    self.preempt_request(victim)
                    if victim is r:
                        break

    def preempt_request(self, victim: Request) -> None:
        """Preempt one running request: the layout snapshots or forgets
        its cache state (per its preemption policy), then the scheduler
        requeues it at the head."""
        self.layout.on_preempt(victim)
        self.scheduler.preempt(victim)
        tr = _trace.active()
        if tr is not None:
            tr.instant("preempt", "engine", rid=victim.rid)
            reg = _metrics.active()
            if reg is not None:
                reg.counter("engine/preemptions").inc()

    def run(self) -> List[Request]:
        """Drive until the queue and the running set are both empty."""
        while self.scheduler.has_work:
            self.step()
        done, self.scheduler.finished = self.scheduler.finished, []
        self.finished_log.extend(done)
        return done

    # ------------------------------------------------------------------
    # batch-compatible front end (drop-in for Engine.generate)
    # ------------------------------------------------------------------
    def generate(self, params, prompt_tokens, prompt_lens=None,
                 key=None) -> GenerationResult:
        """prompt_tokens: (B, S) int32; returns the legacy layout padded
        to ``S + max_new_tokens`` so downstream RL code is unchanged."""
        if params is not None:
            self.set_params(params, self.weight_version)
        if key is None:
            key = jax.random.PRNGKey(0)
        base_seed = int(jax.random.randint(key, (), 0, 2**31 - 1))
        prompts = np.asarray(prompt_tokens)
        B, S = prompts.shape
        # mask keeps base_seed + i inside int32 (the jitted step's seeds)
        reqs = [self.submit(prompts[i], seed=(base_seed + i) & 0x7FFFFFFF)
                for i in range(B)]
        self.run()
        return self._collect(reqs, S)

    def _collect(self, reqs: List[Request], S: int) -> GenerationResult:
        B = len(reqs)
        total = S + self.max_new_tokens
        tokens = np.full((B, total), self.pad, np.int32)
        logprobs = np.zeros((B, total), np.float32)
        lengths = np.zeros((B,), np.int32)
        done = np.zeros((B,), bool)
        versions = np.zeros((B,), np.int32)
        for i, r in enumerate(reqs):
            tokens[i, :S] = r.prompt
            n = len(r.generated)
            tokens[i, S:S + n] = r.generated
            logprobs[i, S:S + n] = r.logprobs
            lengths[i] = S + n
            done[i] = r.hit_eos
            versions[i] = r.weight_version
        return GenerationResult(
            tokens=jnp.asarray(tokens), logprobs=jnp.asarray(logprobs),
            lengths=jnp.asarray(lengths), done=jnp.asarray(done),
            weight_versions=versions)

    # ------------------------------------------------------------------
    # measurement (feeds the profiler's fitted tail factor)
    # ------------------------------------------------------------------
    def pop_request_records(self) -> List[Tuple[int, float]]:
        """(generated_tokens, service_seconds) per finished request;
        clears the log."""
        recs = [(len(r.generated), r.service_time())
                for r in self.finished_log]
        self.finished_log.clear()
        return recs
