"""Rollout/serving engine: batched prefill + autoregressive decode.

This is the "rollout worker" compute used by the M2Flow runtime (the
paper's SGLang/vLLM role).  Generation runs under ``lax.scan`` with a
per-sequence `done` mask, and returns per-token *behaviour logprobs* so
the trainer can form importance ratios without a separate inference pass
when the collocated mode is chosen (one-forward-pass trick, §5.3).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import token_logprobs


class GenerationResult(NamedTuple):
    tokens: jax.Array  # (B, S_total) prompt + generated (PAD after EOS)
    logprobs: jax.Array  # (B, S_total) behaviour logprob per token (0 on prompt)
    lengths: jax.Array  # (B,) total valid length
    done: jax.Array  # (B,) bool — hit EOS before max tokens


def _sample(key, logits: jax.Array, temperature: float, vocab_size: int):
    """Categorical sample with padded-vocab masking; temp<=0 = greedy."""
    logits = logits.astype(jnp.float32)
    neg = jnp.full_like(logits, -1e30)
    V = logits.shape[-1]
    mask = jnp.arange(V) < vocab_size
    logits = jnp.where(mask, logits, neg)
    if temperature <= 0.0:
        tok = jnp.argmax(logits, axis=-1)
    else:
        tok = jax.random.categorical(key, logits / temperature, axis=-1)
    lp = token_logprobs(logits, tok)
    return tok.astype(jnp.int32), lp


class Engine:
    """Owns jitted prefill/decode functions for one model config."""

    def __init__(self, cfg: ModelConfig, *, max_new_tokens: int = 32,
                 temperature: float = 1.0, eos_token: int = 2,
                 pad_token: int = 0):
        self.cfg = cfg
        self.max_new_tokens = max_new_tokens
        self.temperature = temperature
        self.eos = eos_token
        self.pad = pad_token
        self._generate = jax.jit(self._generate_impl, static_argnames=("B", "S"))

    # ------------------------------------------------------------------
    def _generate_impl(self, params, prompt_tokens, prompt_lens, key, *,
                       B: int, S: int):
        cfg = self.cfg
        total = S + self.max_new_tokens
        state = M.init_decode_state(cfg, B, total)

        # ---- prefill the (left-padded) prompt ----
        logits, state = M.prefill(params, cfg, prompt_tokens, state)
        last = logits[:, 0]  # (B, V)

        out_tokens = jnp.concatenate(
            [prompt_tokens,
             jnp.full((B, self.max_new_tokens), self.pad, jnp.int32)], axis=1)
        out_lp = jnp.zeros((B, total), jnp.float32)

        def step(carry, i):
            state, last, toks, lps, done, key = carry
            key, sub = jax.random.split(key)
            tok, lp = _sample(sub, last, self.temperature, cfg.vocab_size)
            tok = jnp.where(done, self.pad, tok)
            lp = jnp.where(done, 0.0, lp)
            pos = S + i
            toks = jax.lax.dynamic_update_slice(toks, tok[:, None], (0, pos))
            lps = jax.lax.dynamic_update_slice(lps, lp[:, None], (0, pos))
            newdone = done | (tok == self.eos)
            logits, state = M.decode_step(params, cfg, tok[:, None], state, pos)
            return (state, logits[:, 0], toks, lps, newdone, key), None

        done0 = jnp.zeros((B,), bool)
        (state, last, out_tokens, out_lp, done, _), _ = jax.lax.scan(
            step, (state, last, out_tokens, out_lp, done0, key),
            jnp.arange(self.max_new_tokens))
        lengths = S + jnp.sum(
            (out_tokens[:, S:] != self.pad).astype(jnp.int32), axis=1)
        return GenerationResult(out_tokens, out_lp, lengths, done)

    # ------------------------------------------------------------------
    def generate(self, params, prompt_tokens, prompt_lens=None,
                 key=None) -> GenerationResult:
        """prompt_tokens: (B, S) int32 left-padded prompts."""
        B, S = prompt_tokens.shape
        if key is None:
            key = jax.random.PRNGKey(0)
        if prompt_lens is None:
            prompt_lens = jnp.full((B,), S, jnp.int32)
        return self._generate(params, prompt_tokens, prompt_lens, key, B=B, S=S)
