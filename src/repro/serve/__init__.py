from repro.serve.engine import (  # noqa: F401
    Engine,
    GenerationResult,
    PagedEngine,
)
from repro.serve.layouts import (  # noqa: F401
    CacheLayout,
    LayoutError,
    MoEPagedKVLayout,
    PagedKVLayout,
    StateCacheLayout,
    covers,
    layout_class,
)
from repro.serve.paging import (  # noqa: F401
    OutOfPages,
    PageAccountingError,
    PageAllocator,
    PagedKVCache,
    PrefixCache,
    PrefixMatch,
    init_paged_cache,
)
from repro.serve.sampling import (  # noqa: F401
    sample_token,
    sample_tokens_fused,
    top_k_logits,
    top_p_logits,
)
from repro.serve.scheduler import (  # noqa: F401
    ContinuousScheduler,
    KVPageCost,
    NullPageCost,
    Request,
)
