"""Continuous-batching scheduler: admission queue + per-step join/evict.

Iteration-level scheduling (Orca/vLLM): the decode batch is re-formed at
*every* step.  A finished request frees its pages and its slot
immediately; the head of the admission queue joins as soon as a slot and
enough pages for its prompt (+ one decode page) are available.  This is
the mechanism that removes the long-tail stall of static batching
(paper Fig. 2): devices never idle behind the slowest response as long
as the queue is non-empty.

The scheduler is pure host-side bookkeeping — the engine owns the jitted
compute and asks the scheduler which requests occupy which slots.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

from repro.serve.paging import PageAllocator

QUEUED = "queued"
RUNNING = "running"
FINISHED = "finished"


@dataclass
class Request:
    """One generation request moving through the engine."""

    rid: int
    prompt: List[int]
    max_new_tokens: int
    seed: int = 0
    # -- lifecycle --------------------------------------------------------
    state: str = QUEUED
    slot: int = -1
    pages: List[int] = field(default_factory=list)
    # number of tokens already written into the KV cache (prompt progress
    # during chunk-less prefill, then prompt + generated during decode)
    num_cached: int = 0
    generated: List[int] = field(default_factory=list)
    logprobs: List[float] = field(default_factory=list)
    hit_eos: bool = False
    # weight version the request was admitted under, and the newest
    # version that produced any of its tokens (in-flight sync may advance
    # it; the staleness correction uses the conservative admitted tag)
    weight_version: int = 0
    last_weight_version: int = 0
    # -- timing (feeds the profiler's measured tail_factor) ---------------
    submit_time: float = 0.0
    start_time: float = 0.0
    finish_time: float = 0.0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_len(self) -> int:
        return self.prompt_len + len(self.generated)

    @property
    def in_prefill(self) -> bool:
        return self.num_cached < self.prompt_len

    def service_time(self) -> float:
        return self.finish_time - self.start_time


@dataclass
class SchedulerStats:
    admitted: int = 0
    finished: int = 0
    evicted_pages: int = 0
    peak_active: int = 0
    steps: int = 0
    preempted: int = 0


class ContinuousScheduler:
    """Admission queue + running set over ``max_batch`` decode slots."""

    def __init__(self, *, max_batch: int, allocator: PageAllocator,
                 max_seq_len: int):
        self.max_batch = max_batch
        self.allocator = allocator
        self.max_seq_len = max_seq_len
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}  # slot -> request
        self._free_slots: List[int] = list(range(max_batch - 1, -1, -1))
        self._rid = itertools.count()
        self.stats = SchedulerStats()
        self.finished: List[Request] = []

    # -- submission --------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int,
               *, seed: int = 0, weight_version: int = 0) -> Request:
        assert len(prompt) >= 1, "empty prompt: nothing to condition on"
        assert len(prompt) + max_new_tokens <= self.max_seq_len, (
            len(prompt), max_new_tokens, self.max_seq_len)
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens, seed=seed,
                      weight_version=weight_version,
                      last_weight_version=weight_version,
                      submit_time=time.perf_counter())
        self.waiting.append(req)
        return req

    # -- per-step batch formation -----------------------------------------
    def admit(self, *, weight_version: Optional[int] = None) -> List[Request]:
        """FIFO-backfill free slots while the page budget allows.

        A request is admitted only if pages for its *whole* prompt plus
        one decode page are free — admission never deadlocks mid-prefill.
        Returns the newly-admitted requests (already slotted).
        """
        joined: List[Request] = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            # total_len, not prompt_len: a preempted request re-enters with
            # generated tokens that must be re-cached (recompute on resume)
            need = self.allocator.pages_needed(req.total_len + 1)
            if not self.allocator.can_allocate(need):
                break
            self.waiting.popleft()
            req.pages = self.allocator.allocate(need)
            req.slot = self._free_slots.pop()
            req.state = RUNNING
            if req.start_time == 0.0:  # keep the first admission time
                req.start_time = time.perf_counter()
            # a resumed (preempted) request keeps its original admission
            # tag — its earlier tokens were produced under that version
            if weight_version is not None and not req.generated:
                req.weight_version = weight_version
                req.last_weight_version = weight_version
            self.running[req.slot] = req
            self.stats.admitted += 1
            joined.append(req)
        self.stats.peak_active = max(self.stats.peak_active,
                                     len(self.running))
        return joined

    def ensure_page_for(self, req: Request) -> None:
        """Grow the block table so position ``num_cached`` is backed."""
        if req.num_cached >= len(req.pages) * self.allocator.page_size:
            req.pages.extend(self.allocator.allocate(1))

    def preempt(self, req: Request) -> None:
        """Kick a running request back to the HEAD of the admission queue,
        freeing its slot and all its pages (vLLM-style recompute
        preemption): its generated tokens are kept and its KV cache is
        rebuilt by teacher-forced replay when it is re-admitted."""
        assert req.state == RUNNING, req.state
        self.allocator.free(req.pages)
        self.stats.evicted_pages += len(req.pages)
        req.pages = []
        del self.running[req.slot]
        self._free_slots.append(req.slot)
        req.slot = -1
        req.num_cached = 0
        req.state = QUEUED
        self.waiting.appendleft(req)
        self.stats.preempted += 1

    def finish(self, req: Request) -> None:
        """Evict: free the pages and the slot immediately (the join half
        of join/evict happens on the next :meth:`admit`)."""
        assert req.state == RUNNING, req.state
        req.state = FINISHED
        req.finish_time = time.perf_counter()
        self.allocator.free(req.pages)
        self.stats.evicted_pages += len(req.pages)
        req.pages = []
        del self.running[req.slot]
        self._free_slots.append(req.slot)
        req.slot = -1
        self.stats.finished += 1
        self.finished.append(req)

    # -- views -------------------------------------------------------------
    @property
    def num_active(self) -> int:
        return len(self.running)

    @property
    def has_work(self) -> bool:
        return bool(self.running or self.waiting)

    def active_requests(self) -> List[Request]:
        return [self.running[s] for s in sorted(self.running)]
