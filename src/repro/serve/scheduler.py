"""Continuous-batching scheduler: admission queue + per-step join/evict.

Iteration-level scheduling (Orca/vLLM): the decode batch is re-formed at
*every* step.  A finished request frees its pages and its slot
immediately; the head of the admission queue joins as soon as a slot and
enough pages for its prompt (+ one decode page) are available.  This is
the mechanism that removes the long-tail stall of static batching
(paper Fig. 2): devices never idle behind the slowest response as long
as the queue is non-empty.

With a :class:`~repro.serve.paging.PrefixCache` attached, admission also
resolves prefix sharing (SGLang RadixAttention idiom): the new request
adopts the longest chain of cached full pages (refcount bumped, so a
shared page outlives any single owner), plans a copy-on-write extension
of a cached partial page when profitable, and indexes its own prompt
region so later arrivals — GRPO siblings behind it in the queue, or the
next turn of a multi-turn episode — share *its* prefill.  When the pool
runs dry, admission and page growth evict cold trie leaves (LRU) before
giving up or preempting.

The scheduler is pure host-side bookkeeping — the engine owns the jitted
compute and asks the scheduler which requests occupy which slots.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from repro.serve.paging import PageAllocator, PrefixCache, PrefixNode

QUEUED = "queued"
RUNNING = "running"
FINISHED = "finished"


class KVPageCost:
    """Per-request page cost of the paged-KV layout: every cached token
    occupies one row, so a request holding ``n`` tokens needs
    ``ceil(n / page_size)`` pool pages."""

    def __init__(self, page_size: int):
        self.page_size = page_size

    def request_pages(self, num_tokens: int) -> int:
        return -(-num_tokens // self.page_size)


class NullPageCost:
    """Constant-size cache layouts (recurrent state): a request's cache
    footprint is its slot, not a token-proportional page count — the
    admission budget degenerates to slot availability and decode-time
    page growth never happens."""

    def request_pages(self, num_tokens: int) -> int:
        return 0


@dataclass
class Request:
    """One generation request moving through the engine."""

    rid: int
    prompt: List[int]
    max_new_tokens: int
    seed: int = 0
    # -- lifecycle --------------------------------------------------------
    state: str = QUEUED
    slot: int = -1
    pages: List[int] = field(default_factory=list)
    # number of tokens already written into the KV cache (prompt progress
    # during chunk-less prefill, then prompt + generated during decode)
    num_cached: int = 0
    generated: List[int] = field(default_factory=list)
    logprobs: List[float] = field(default_factory=list)
    hit_eos: bool = False
    # -- prefix sharing ----------------------------------------------------
    # tokens at the front of the prompt whose KV lives in pages adopted
    # from the prefix cache (full pages + COW rows); the engine
    # fast-forwards ``num_cached`` through this region as the shared
    # pages' computed watermarks allow
    shared_len: int = 0
    # trie nodes backing the adopted full pages (parallel to the first
    # len(shared_nodes) entries of ``pages``); used to wait on an active
    # writer instead of recomputing its rows
    shared_nodes: List[PrefixNode] = field(default_factory=list)
    # planned copy-on-write: (src_page, dst_page, rows).  The source page
    # holds an extra pin (refcount) until the engine performs the device
    # copy — or until release, if the request dies first.
    pending_cow: Optional[Tuple[int, int, int]] = None
    # weight version the request was admitted under, and the newest
    # version that produced any of its tokens (in-flight sync may advance
    # it; the staleness correction uses the conservative admitted tag)
    weight_version: int = 0
    last_weight_version: int = 0
    # -- timing (feeds the profiler's measured tail_factor) ---------------
    submit_time: float = 0.0
    start_time: float = 0.0
    finish_time: float = 0.0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_len(self) -> int:
        return self.prompt_len + len(self.generated)

    @property
    def in_prefill(self) -> bool:
        return self.num_cached < self.prompt_len

    def service_time(self) -> float:
        return self.finish_time - self.start_time


@dataclass
class SchedulerStats:
    admitted: int = 0
    finished: int = 0
    evicted_pages: int = 0
    peak_active: int = 0
    steps: int = 0
    preempted: int = 0
    # -- prefix sharing / chunked prefill ----------------------------------
    prefix_hit_tokens: int = 0       # prompt tokens skipped via shared KV
    prefix_shared_pages: int = 0     # full pages adopted at admission
    cow_pages: int = 0               # copy-on-write page extensions
    chunk_deferred_tokens: int = 0   # prefill tokens pushed past a step


class ContinuousScheduler:
    """Admission queue + running set over ``max_batch`` decode slots."""

    def __init__(self, *, max_batch: int, allocator: PageAllocator,
                 max_seq_len: int,
                 prefix_cache: Optional[PrefixCache] = None,
                 cost_model=None, preempt_keeps_progress: bool = False):
        self.max_batch = max_batch
        self.allocator = allocator
        self.max_seq_len = max_seq_len
        self.prefix_cache = prefix_cache
        # the cache layout's per-request cost model: how many pool pages a
        # request holding n tokens needs.  Defaults to the paged-KV model
        # so existing direct constructions keep their semantics.
        self.cost_model = (cost_model if cost_model is not None
                           else KVPageCost(allocator.page_size))
        # state-cache layouts snapshot a preempted request's recurrent
        # state instead of recomputing: its cached progress survives
        self.preempt_keeps_progress = preempt_keeps_progress
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}  # slot -> request
        self._free_slots: List[int] = list(range(max_batch - 1, -1, -1))
        self._rid = itertools.count()
        self.stats = SchedulerStats()
        self.finished: List[Request] = []

    # -- submission --------------------------------------------------------
    def submit(self, prompt: List[int], max_new_tokens: int,
               *, seed: int = 0, weight_version: int = 0) -> Request:
        assert len(prompt) >= 1, "empty prompt: nothing to condition on"
        assert len(prompt) + max_new_tokens <= self.max_seq_len, (
            len(prompt), max_new_tokens, self.max_seq_len)
        req = Request(rid=next(self._rid), prompt=list(prompt),
                      max_new_tokens=max_new_tokens, seed=seed,
                      weight_version=weight_version,
                      last_weight_version=weight_version,
                      submit_time=time.perf_counter())
        self.waiting.append(req)
        return req

    # -- per-step batch formation -----------------------------------------
    def admit(self, *, weight_version: Optional[int] = None) -> List[Request]:
        """FIFO-backfill free slots while the page budget allows.

        A request is admitted only if pages for its *whole* prompt plus
        one decode page are available — admission never deadlocks
        mid-prefill.  Pages covering a cached prefix are adopted (incref)
        rather than allocated; the remainder comes from the free list,
        topped up by LRU trie eviction when the pool runs dry.  Returns
        the newly-admitted requests (already slotted).
        """
        joined: List[Request] = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            shared_nodes: List[PrefixNode] = []
            cow: Optional[Tuple[int, int]] = None  # (src_page, rows)
            if self.prefix_cache is not None:
                match = self.prefix_cache.lookup(req.prompt)
                shared_nodes = match.nodes
                # a partial-page extension is only worth copying when the
                # source rows are actually computed; an in-flight writer's
                # unfilled tail would copy garbage
                if (match.partial is not None and match.partial_rows > 0
                        and self.allocator.computed_rows(match.partial.page)
                        >= match.partial_rows):
                    cow = (match.partial.page, match.partial_rows)
            shared_pages = [n.page for n in shared_nodes]
            # pin the adopted pages (and the COW source) before any
            # eviction below can free them out from under us
            self.allocator.incref(shared_pages)
            if cow is not None:
                self.allocator.incref([cow[0]])
            # total_len, not prompt_len: a preempted request re-enters with
            # generated tokens that must be re-cached (recompute on resume)
            need = self.cost_model.request_pages(req.total_len + 1)
            need_new = need - len(shared_pages)
            if (not self.allocator.can_allocate(need_new)
                    and self.prefix_cache is not None):
                self.prefix_cache.evict(
                    need_new - self.allocator.num_free, self.allocator)
            if not self.allocator.can_allocate(need_new):
                # admission stalls: roll back the pins, FIFO head keeps
                # its turn (free() is a decref — the cache still holds
                # its own reference, so nothing is physically freed)
                self.allocator.free(shared_pages)
                if cow is not None:
                    self.allocator.free([cow[0]])
                break
            self.waiting.popleft()
            req.pages = shared_pages + self.allocator.allocate(need_new)
            req.shared_nodes = shared_nodes
            req.shared_len = len(shared_pages) * self.allocator.page_size
            if cow is not None:
                req.pending_cow = (cow[0], req.pages[len(shared_pages)],
                                   cow[1])
                req.shared_len += cow[1]
                self.stats.cow_pages += 1
            self.stats.prefix_shared_pages += len(shared_pages)
            if self.prefix_cache is not None:
                # index this request's own prompt region (it is the
                # writer) so queued siblings share its prefill
                self.prefix_cache.insert(
                    req.prompt, req.pages, self.allocator,
                    start=len(shared_pages) * self.allocator.page_size,
                    writer=req.rid)
            req.slot = self._free_slots.pop()
            req.state = RUNNING
            if req.start_time == 0.0:  # keep the first admission time
                req.start_time = time.perf_counter()
            # a resumed (preempted) request keeps its original admission
            # tag — its earlier tokens were produced under that version
            if weight_version is not None and not req.generated:
                req.weight_version = weight_version
                req.last_weight_version = weight_version
            self.running[req.slot] = req
            self.stats.admitted += 1
            joined.append(req)
        self.stats.peak_active = max(self.stats.peak_active,
                                     len(self.running))
        return joined

    def ensure_page_for(self, req: Request) -> None:
        """Grow the block table so position ``num_cached`` is backed.
        Under a constant-size (state) cost model this is a no-op: the
        layout never asks for more pages than admission granted."""
        if len(req.pages) >= self.cost_model.request_pages(
                req.num_cached + 1):
            return
        if (not self.allocator.can_allocate(1)
                and self.prefix_cache is not None):
            self.prefix_cache.evict(1, self.allocator)
        req.pages.extend(self.allocator.allocate(1))

    def _release_pages(self, req: Request) -> None:
        """Drop every reference the request holds: its page table, an
        un-performed COW pin, and its writer role in the trie.  free()
        decrefs — pages also referenced by the cache or by sharers
        survive."""
        if self.prefix_cache is not None:
            self.prefix_cache.release_writer(req.rid)
        if req.pending_cow is not None:
            self.allocator.free([req.pending_cow[0]])
            req.pending_cow = None
        self.allocator.free(req.pages)
        self.stats.evicted_pages += len(req.pages)
        req.pages = []
        req.shared_nodes = []
        req.shared_len = 0

    def preempt(self, req: Request) -> None:
        """Kick a running request back to the HEAD of the admission queue,
        freeing its slot and decref'ing all its pages (vLLM-style
        recompute preemption): its generated tokens are kept and its KV
        cache is rebuilt — or re-adopted from the prefix cache — when it
        is re-admitted."""
        assert req.state == RUNNING, req.state
        self._release_pages(req)
        del self.running[req.slot]
        self._free_slots.append(req.slot)
        req.slot = -1
        if not self.preempt_keeps_progress:
            req.num_cached = 0  # recompute on resume (paged-KV layouts)
        req.state = QUEUED
        self.waiting.appendleft(req)
        self.stats.preempted += 1

    def finish(self, req: Request, *, index_in_cache: bool = True) -> None:
        """Evict: decref the pages and free the slot immediately (the
        join half of join/evict happens on the next :meth:`admit`).

        When ``index_in_cache`` is set and a prefix cache is attached,
        the full sequence (prompt + generated) is indexed first, so a
        follow-up turn that re-feeds this conversation re-uses the KV.
        The engine clears the flag when the request's KV spans a weight
        swap — stale rows must not be served to new requests.
        """
        assert req.state == RUNNING, req.state
        req.state = FINISHED
        req.finish_time = time.perf_counter()
        if self.prefix_cache is not None and index_in_cache:
            toks = req.prompt + req.generated
            if req.generated:
                # the final sampled token's KV row is never written (the
                # decode step that would scatter it never runs), so it
                # must not be indexed: a follower adopting it would serve
                # a row of zeros — and it may lie past the block table
                toks = toks[:-1]
            self.prefix_cache.insert(toks, req.pages, self.allocator)
        self._release_pages(req)
        del self.running[req.slot]
        self._free_slots.append(req.slot)
        req.slot = -1
        self.stats.finished += 1
        self.finished.append(req)

    # -- views -------------------------------------------------------------
    @property
    def num_active(self) -> int:
        return len(self.running)

    @property
    def has_work(self) -> bool:
        return bool(self.running or self.waiting)

    def active_requests(self) -> List[Request]:
        return [self.running[s] for s in sorted(self.running)]
