"""Per-architecture cache layouts behind one serve-tier interface.

The continuous-batching engine (:class:`repro.serve.engine.PagedEngine`)
is host-side scheduling — admission, chunk budgets, preemption, weight
sync — over a device cache whose *shape* depends on the architecture:

* :class:`PagedKVLayout` — the classic vLLM layout: a (L, P, page, KV,
  hd) page pool addressed through per-request block tables.  Pages grow
  with every decoded token, preemption recomputes, and the radix prefix
  trie can share full pages and copy-on-write partial ones.
* :class:`MoEPagedKVLayout` — same KV pool; the FFN half of each layer
  routes through the exact top-k expert combine (optionally the grouped
  per-expert decode GEMM kernel, ``kernels.ops.moe_decode``).
* :class:`StateCacheLayout` — SSM/hybrid stacks: one constant-size
  recurrent state (Mamba2 SSD state + conv window, plus the hybrid
  shared-attention KV ring) per slot.  No page growth during decode,
  preemption *snapshots* the state instead of recomputing, and prefix
  reuse happens only on an exact full-prompt match — SSD state is
  position-dependent, so partial-prefix copy-on-write is structurally
  impossible here (constructing this layout with a
  :class:`~repro.serve.paging.PrefixCache` raises :class:`LayoutError`).

The engine asks the layout for its scheduler cost model
(:class:`~repro.serve.scheduler.KVPageCost` vs
:class:`~repro.serve.scheduler.NullPageCost`), so admission/page-budget
math, chunked prefill, and preemption run unchanged across layouts.
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DENSE, HYBRID, MOE, SSM, ModelConfig
from repro.models import model as M
from repro.models import moe as moe_mod
from repro.models.attention import NEG_INF, KVCache, qkv_project, sdpa
from repro.models.layers import apply_rope, embed, mlp, rmsnorm, unembed
from repro.models.ssm import SSMState
from repro.serve.paging import (
    TRASH_PAGE,
    PagedKVCache,
    PrefixCache,
    init_paged_cache,
    pad_block_table,
)
from repro.serve.sampling import sample_token, sample_tokens_fused
from repro.serve.scheduler import KVPageCost, NullPageCost, Request


class LayoutError(TypeError):
    """A cache layout was constructed with machinery it cannot honour
    (e.g. a state-cache layout with a partial-page COW prefix trie)."""


class CacheLayout:
    """Device-cache strategy for one model architecture.

    Subclasses own the jitted step/prefill compute and the cache buffers;
    the engine owns the host loop and calls through this interface.  The
    class attributes are the *policy* the engine and scheduler read:

    - ``uses_pages``: requests consume pool pages (block tables, page
      watermarks, COW) vs a constant-size per-slot cache.
    - ``supports_partial_cow``: a radix :class:`PrefixCache` (full-page
      adoption + partial-page copy-on-write) may be attached.
    - ``preempt_keeps_progress``: preemption snapshots per-request cache
      state, so ``num_cached`` survives requeueing.
    """

    name = "abstract"
    uses_pages = True
    supports_partial_cow = True
    supports_chunked_prefill = True
    preempt_keeps_progress = False

    def __init__(self, cfg: ModelConfig, *, max_batch: int, page_size: int,
                 num_pages: int, max_blocks: int, max_seq_len: int,
                 temperature: float, top_k: int, top_p: float,
                 use_kernel: bool, use_sampling_kernel: bool, dtype,
                 prefix_cache: Optional[PrefixCache] = None,
                 prefix_sharing: bool = True):
        self.cfg = cfg
        self.max_batch = max_batch
        self.page_size = page_size
        self.num_pages = num_pages
        self.max_blocks = max_blocks
        self.max_seq_len = max_seq_len
        self.temperature = temperature
        self.top_k = top_k
        self.top_p = top_p
        self.use_kernel = use_kernel
        self.use_sampling_kernel = use_sampling_kernel
        self.dtype = dtype

    # -- scheduler integration ---------------------------------------------
    def cost_model(self):
        return (KVPageCost(self.page_size) if self.uses_pages
                else NullPageCost())

    # -- jitted compute (implemented by subclasses) ------------------------
    def step(self, params, tokens, positions, tables, seeds, active):
        """Advance every slot one token; returns (tokens, logprobs)."""
        raise NotImplementedError

    def prefill_chunk_step(self, params, tokens, positions, n_valid,
                           req: Request) -> None:
        """Cache ``n_valid`` positions of one request in a single call."""
        raise NotImplementedError

    def cow(self, src: int, dst: int) -> None:
        """Copy-on-write a whole page (paged-KV layouts only)."""
        raise NotImplementedError

    # -- lifecycle hooks (default: no-ops) ---------------------------------
    def on_admit(self, req: Request) -> int:
        """Called for each newly-admitted request; returns the number of
        prompt positions satisfied from a layout-private cache."""
        return 0

    def on_preempt(self, req: Request) -> None:
        """Called just before the scheduler requeues a running request."""

    def on_finish(self, req: Request, *, index_in_cache: bool) -> None:
        """Called just before the scheduler evicts a finished request."""

    def on_weight_swap(self) -> None:
        """Called after an in-flight weight update lands: any
        layout-private cache of old-weight activations must drop."""

    def note_progress(self, req: Request) -> None:
        """Called after ``req.num_cached`` advances (decode or chunk)."""

    def rebind(self, sharding) -> None:
        """Re-place the layout's device buffers onto ``sharding``."""
        raise NotImplementedError

    # -- shared sampling tail ----------------------------------------------
    def _sample_batch(self, logits, seeds, positions):
        """Per-request deterministic sampling: token at ``position`` of a
        request seeded ``seed`` is drawn from fold_in(PRNGKey(seed), pos)
        — invariant to batching, chunking, and preemption."""
        keys = jax.vmap(
            lambda s, p: jax.random.fold_in(jax.random.PRNGKey(s), p)
        )(seeds, positions)
        if self.use_sampling_kernel:
            return sample_tokens_fused(
                keys, logits, temperature=self.temperature,
                top_k=self.top_k, top_p=self.top_p,
                vocab_size=self.cfg.vocab_size)
        return jax.vmap(functools.partial(
            sample_token, temperature=self.temperature, top_k=self.top_k,
            top_p=self.top_p, vocab_size=self.cfg.vocab_size))(keys, logits)


# ===========================================================================
# Paged KV (dense attention stacks) — the original layout, extracted
# ===========================================================================
def _paged_sdpa(q, k_pages, v_pages, block_tables, context_lens):
    """Pure-JAX paged attention (gather through the block table + sdpa);
    the XLA analogue of kernels/paged_attention.py, exact same math."""
    B = q.shape[0]
    _, page, KV, hd = k_pages.shape
    nb = block_tables.shape[1]
    k = k_pages[block_tables].reshape(B, nb * page, KV, hd)
    v = v_pages[block_tables].reshape(B, nb * page, KV, hd)
    pos = jnp.arange(nb * page)
    mask = jnp.where(pos[None, :] < context_lens[:, None], 0.0,
                     NEG_INF)[:, None, None, :]  # (B, 1, 1, S)
    return sdpa(q, k, v, mask)  # (B, 1, H, hd)


class PagedKVLayout(CacheLayout):
    """vLLM-style paged KV pool + block tables; dense attention stacks."""

    name = "paged-kv"
    uses_pages = True
    supports_partial_cow = True
    preempt_keeps_progress = False

    def __init__(self, cfg: ModelConfig, **kw):
        super().__init__(cfg, **kw)
        self.cache: PagedKVCache = init_paged_cache(
            cfg.num_layers, self.num_pages, self.page_size,
            cfg.num_kv_heads, cfg.resolved_head_dim, self.dtype)
        # donate the page pools: XLA aliases input to output so the
        # per-step .at[].set() updates the cache in place instead of
        # copying the whole pool every token
        self._step_fn = jax.jit(self._step_impl, donate_argnums=(1, 2))
        self._prefill_fn = jax.jit(self._prefill_impl, donate_argnums=(1, 2))
        self._cow_fn = jax.jit(self._cow_impl, donate_argnums=(0, 1))
        if kw.get("prefix_cache") is not None:
            # compile the copy-on-write kernel now (trash page onto
            # itself is a semantic no-op) so the first real COW during a
            # measured run doesn't eat a compilation
            self.cache = PagedKVCache(*self._cow_fn(
                self.cache.k, self.cache.v,
                jnp.asarray(TRASH_PAGE, jnp.int32),
                jnp.asarray(TRASH_PAGE, jnp.int32)))

    # -- per-layer FFN hook (MoE subclass overrides) ------------------------
    def _ffn(self, lp, h):
        return mlp(lp["mlp"], h)

    # -- jitted impls -------------------------------------------------------
    def _step_impl(self, params, k_pages, v_pages, tokens, positions,
                   block_tables, seeds):
        """One token for every slot.  All shapes fixed by construction:
        tokens/positions/seeds (max_batch,), block_tables
        (max_batch, max_blocks), cache (L, P, page, KV, hd)."""
        cfg = self.cfg
        x = embed(params["embed"], tokens[:, None])  # (B, 1, d)
        posb = positions[:, None]
        page = self.page_size
        page_idx = jnp.take_along_axis(
            block_tables, (positions // page)[:, None], axis=1)[:, 0]
        offset = positions % page
        ctx = positions + 1  # valid tokens after this step's write

        def layer_body(carry, xs):
            x = carry
            lp, kl, vl = xs  # kl/vl: (P, page, KV, hd)
            h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            q, k, v = qkv_project(lp["attn"], cfg, h)  # (B, 1, H|KV, hd)
            q = apply_rope(q, posb, cfg.rope_theta)
            k = apply_rope(k, posb, cfg.rope_theta)
            # scatter this step's K/V into each request's current page
            # (inactive slots target the trash page)
            kl = kl.at[page_idx, offset].set(k[:, 0].astype(kl.dtype))
            vl = vl.at[page_idx, offset].set(v[:, 0].astype(vl.dtype))
            if self.use_kernel:
                from repro.kernels import ops as kops

                out = kops.paged_attention(
                    q[:, 0], kl, vl, block_tables, ctx)[:, None]
            else:
                out = _paged_sdpa(q, kl, vl, block_tables, ctx)
            x = x + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
            x = x + self._ffn(lp, rmsnorm(lp["ln2"], x, cfg.norm_eps))
            return x, (kl, vl)

        x, (k_pages, v_pages) = jax.lax.scan(
            layer_body, x, (params["layers"], k_pages, v_pages))
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        logits = unembed(params["embed"], x)[:, 0]  # (B, V)
        tok, lp = self._sample_batch(logits, seeds, positions)
        return tok, lp, k_pages, v_pages

    def _prefill_impl(self, params, k_pages, v_pages, tokens, positions,
                      block_table, n_valid):
        """Write KV for up to ``prefill_chunk`` prompt positions of ONE
        request in a single forward.  No logits come back: every chunked
        position is strictly before the sampling frontier, which always
        goes through :meth:`_step_impl`.  Shapes fixed by construction:
        tokens/positions (C,), block_table (max_blocks,), n_valid ()."""
        cfg = self.cfg
        C = tokens.shape[0]
        page = self.page_size
        S = self.max_blocks * page
        valid = jnp.arange(C) < n_valid
        x = embed(params["embed"], tokens[None, :])  # (1, C, d)
        posb = positions[None, :]
        # padded rows scatter into the trash page, like inactive slots
        page_idx = jnp.where(valid, block_table[positions // page],
                             TRASH_PAGE)
        offset = positions % page
        kpos = jnp.arange(S)
        # causal over the request's own logical context: everything at or
        # before a row's position is already cached (earlier steps) or is
        # written by this very chunk's scatter before the gather below
        mask = jnp.where(kpos[None, :] <= positions[:, None], 0.0,
                         NEG_INF)[None, None]  # (1, 1, C, S)

        def layer_body(carry, xs):
            x = carry
            lp, kl, vl = xs  # kl/vl: (P, page, KV, hd)
            h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
            q, k, v = qkv_project(lp["attn"], cfg, h)  # (1, C, H|KV, hd)
            q = apply_rope(q, posb, cfg.rope_theta)
            k = apply_rope(k, posb, cfg.rope_theta)
            kl = kl.at[page_idx, offset].set(k[0].astype(kl.dtype))
            vl = vl.at[page_idx, offset].set(v[0].astype(vl.dtype))
            kc = kl[block_table].reshape(1, S, *kl.shape[2:])
            vc = vl[block_table].reshape(1, S, *vl.shape[2:])
            out = sdpa(q, kc, vc, mask)  # (1, C, H, hd)
            x = x + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
            x = x + self._ffn(lp, rmsnorm(lp["ln2"], x, cfg.norm_eps))
            return x, (kl, vl)

        _, (k_pages, v_pages) = jax.lax.scan(
            layer_body, x, (params["layers"], k_pages, v_pages))
        return k_pages, v_pages

    @staticmethod
    def _cow_impl(k_pages, v_pages, src, dst):
        """Copy page ``src`` into page ``dst`` on every layer — the
        copy-on-write that lets a request extend a shared partial page
        privately.  The whole page is copied (not just the adopted rows):
        rows past the destination's computed watermark are never read
        before the owner overwrites them, and a row count would otherwise
        have to be a static arg that recompiles per distinct value."""
        k_pages = k_pages.at[:, dst].set(k_pages[:, src])
        v_pages = v_pages.at[:, dst].set(v_pages[:, src])
        return k_pages, v_pages

    # -- host-facing API ----------------------------------------------------
    def step(self, params, tokens, positions, tables, seeds, active):
        tok, lp, kc, vc = self._step_fn(
            params, self.cache.k, self.cache.v, jnp.asarray(tokens),
            jnp.asarray(positions), jnp.asarray(tables),
            jnp.asarray(seeds))
        self.cache = PagedKVCache(k=kc, v=vc)
        return tok, lp

    def prefill_chunk_step(self, params, tokens, positions, n_valid,
                           req: Request) -> None:
        table = jnp.asarray(
            pad_block_table(req.pages, self.max_blocks), jnp.int32)
        kc, vc = self._prefill_fn(
            params, self.cache.k, self.cache.v, jnp.asarray(tokens),
            jnp.asarray(positions), table,
            jnp.asarray(n_valid, jnp.int32))
        self.cache = PagedKVCache(k=kc, v=vc)

    def cow(self, src: int, dst: int) -> None:
        kc, vc = self._cow_fn(
            self.cache.k, self.cache.v,
            jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32))
        self.cache = PagedKVCache(k=kc, v=vc)

    def rebind(self, sharding) -> None:
        self.cache = PagedKVCache(
            k=jax.device_put(self.cache.k, sharding),
            v=jax.device_put(self.cache.v, sharding))


class MoEPagedKVLayout(PagedKVLayout):
    """Paged KV pool with the FFN half routed through the exact top-k
    expert combine.  Capacity-based dispatch (the training path) is
    batch-size dependent — a token's drops depend on who else is in the
    decode batch — which would break both temp-0 static parity and the
    scheduling-invariance contract, so serving always uses the exact
    per-token combine; ``use_kernel`` swaps in the grouped per-expert
    decode GEMM (token→expert gather layout, ``kernels.ops.moe_decode``)."""

    name = "paged-kv-moe"

    def _ffn(self, lp, h):
        return moe_mod.moe_decode_exact(lp["moe"], self.cfg, h,
                                        use_kernel=self.use_kernel)


# ===========================================================================
# Constant-size state cache (SSM / hybrid stacks)
# ===========================================================================
def _batch_axes(cfg: ModelConfig) -> M.DecodeState:
    """Pytree (matching DecodeState) of each leaf's slot/batch axis."""
    if cfg.kind == SSM:
        return M.DecodeState(kv=(), ssm=SSMState(ssm=1, conv=1),
                             cross_kv=(), shared_kv=())
    if cfg.kind == HYBRID:
        return M.DecodeState(
            kv=(), ssm=SSMState(ssm=2, conv=2), cross_kv=(),
            shared_kv=KVCache(k=1, v=1, positions=1))
    raise LayoutError(
        f"state cache layout has no slot axes for kind={cfg.kind}")


class StateCacheLayout(CacheLayout):
    """Constant-size recurrent state per request slot (SSM / hybrid).

    The cache is the model's own stacked :class:`DecodeState` over
    ``max_batch`` slots: Mamba2 SSD state + conv window per layer, plus
    the shared-attention KV ring for hybrid stacks.  Decode needs no page
    growth (``NullPageCost``), preemption snapshots the victim's slot
    state (progress survives requeueing), and prefix reuse is an exact
    full-prompt match against an LRU snapshot cache — SSD state at
    position ``i`` depends on every token before it, so adopting part of
    a cached prefix is meaningless.  Partial-page COW is structurally
    impossible: constructing this layout with a radix
    :class:`PrefixCache` raises :class:`LayoutError`.
    """

    name = "state"
    uses_pages = False
    supports_partial_cow = False
    # a recurrent step is sequential whether it happens in a per-request
    # chunk scan or the decode batch — but the decode batch runs every
    # slot's step in ONE vmapped call, so prefilling through it is
    # max_batch-way parallel while a chunk scan is serial per request.
    # Chunked prefill would only slow the state cache down.
    supports_chunked_prefill = False
    preempt_keeps_progress = True

    def __init__(self, cfg: ModelConfig, **kw):
        if kw.get("prefix_cache") is not None:
            raise LayoutError(
                "state cache layouts cannot take a partial-page COW "
                "prefix cache: recurrent state is position-dependent, so "
                "prefix reuse is exact-full-prompt-match only")
        super().__init__(cfg, **kw)
        self._axes = _batch_axes(cfg)
        self.cache: M.DecodeState = M.init_decode_state(
            cfg, self.max_batch, self.max_seq_len, self.dtype)
        # one zeroed slot row, used to reset a slot for a fresh request
        self._zero_row = self._take_slot(self.cache, 0)
        # rid -> slot-state snapshot taken at preemption
        self._suspended: Dict[int, Any] = {}
        # exact-full-prompt snapshot cache: tuple(tokens) -> state that
        # has consumed tokens[:-1]; LRU-bounded, flushed on weight swap
        self.exact_prefix_capacity = (
            32 if kw.get("prefix_sharing", True) else 0)
        self._exact: "OrderedDict[Tuple[int, ...], Any]" = OrderedDict()
        self.exact_prefix_hits = 0
        self._step_fn = jax.jit(self._step_impl, donate_argnums=(1,))

    # -- slot/state pytree plumbing ----------------------------------------
    def _take_slot(self, state, slot):
        return jax.tree_util.tree_map(
            lambda x, a: jax.lax.dynamic_index_in_dim(
                x, slot, axis=a, keepdims=False), state, self._axes)

    def _put_slot(self, state, row, slot):
        return jax.tree_util.tree_map(
            lambda x, r, a: jax.lax.dynamic_update_index_in_dim(
                x, r.astype(x.dtype), slot, axis=a),
            state, row, self._axes)

    def _row_decode(self, params, tok, pos, st_row):
        """One decode step of one slot: expand the slot row back to a
        B=1 state, reuse the model's own (static-engine-identical)
        ``decode_step``, squeeze back to a row."""
        st1 = jax.tree_util.tree_map(
            lambda x, a: jnp.expand_dims(x, a), st_row, self._axes)
        logits, new_st = M.decode_step(
            params, self.cfg, jnp.reshape(tok, (1, 1)), st1, pos,
            use_kernel=self.use_kernel)
        new_row = jax.tree_util.tree_map(
            lambda x, a: jnp.squeeze(x, a), new_st, self._axes)
        return logits[0, 0], new_row

    # -- jitted impls -------------------------------------------------------
    def _step_impl(self, params, state, tokens, positions, seeds, active):
        def row(tok, pos, act, st_row):
            logits, new_row = self._row_decode(params, tok, pos, st_row)
            # inactive slots (no request, or a request sitting the step
            # out) keep their state — the analogue of the trash page
            new_row = jax.tree_util.tree_map(
                lambda n, o: jnp.where(act, n, o.astype(n.dtype)),
                new_row, st_row)
            return logits, new_row

        logits, state = jax.vmap(
            row, in_axes=(0, 0, 0, self._axes),
            out_axes=(0, self._axes))(tokens, positions, active, state)
        tok, lp = self._sample_batch(logits, seeds, positions)
        return tok, lp, state

    # -- host-facing API ----------------------------------------------------
    def step(self, params, tokens, positions, tables, seeds, active):
        tok, lp, self.cache = self._step_fn(
            params, self.cache, jnp.asarray(tokens),
            jnp.asarray(positions), jnp.asarray(seeds),
            jnp.asarray(active))
        return tok, lp

    # -- lifecycle ----------------------------------------------------------
    def _snapshot(self, slot: int):
        return self._take_slot(self.cache, slot)

    def _store_exact(self, key: Tuple[int, ...], slot: int) -> None:
        if not self.exact_prefix_capacity:
            return
        self._exact[key] = self._snapshot(slot)
        self._exact.move_to_end(key)
        while len(self._exact) > self.exact_prefix_capacity:
            self._exact.popitem(last=False)

    def on_admit(self, req: Request) -> int:
        snap = self._suspended.pop(req.rid, None)
        if snap is not None:
            # resumed after preemption: restore the snapshot; num_cached
            # survived requeueing, so decode continues at the frontier
            self.cache = self._put_slot(self.cache, snap, req.slot)
            return 0
        if req.generated or req.num_cached:
            # mid-flight request without a snapshot cannot happen (the
            # scheduler only requeues via preempt); a fresh slot it is
            self.cache = self._put_slot(self.cache, self._zero_row,
                                        req.slot)
            req.num_cached = 0
            return 0
        hit = self._exact.get(tuple(req.prompt))
        if hit is not None:
            self._exact.move_to_end(tuple(req.prompt))
            self.cache = self._put_slot(self.cache, hit, req.slot)
            req.num_cached = req.prompt_len - 1
            self.exact_prefix_hits += 1
            return req.num_cached
        self.cache = self._put_slot(self.cache, self._zero_row, req.slot)
        return 0

    def on_preempt(self, req: Request) -> None:
        self._suspended[req.rid] = self._snapshot(req.slot)

    def on_finish(self, req: Request, *, index_in_cache: bool) -> None:
        self._suspended.pop(req.rid, None)
        if index_in_cache and req.generated:
            # at finish the slot state has consumed prompt+generated[:-1]
            # (the final sampled token is never fed back), exactly the
            # invariant the exact-match cache stores
            self._store_exact(tuple(req.prompt + req.generated), req.slot)

    def on_weight_swap(self) -> None:
        # snapshots of *running* requests survive (in-flight semantics);
        # the exact-prefix cache holds old-weight state for FUTURE
        # requests and must drop, mirroring the radix-trie flush
        self._exact.clear()

    def note_progress(self, req: Request) -> None:
        if (not req.generated and self.exact_prefix_capacity
                and req.num_cached == req.prompt_len - 1):
            key = tuple(req.prompt)
            if key not in self._exact:
                self._store_exact(key, req.slot)

    def rebind(self, sharding) -> None:
        def put(tree):
            return jax.tree_util.tree_map(
                lambda x: (jax.device_put(x, sharding)
                           if isinstance(x, jax.Array) else x), tree)

        self.cache = put(self.cache)
        self._zero_row = put(self._zero_row)
        self._suspended = {k: put(v) for k, v in self._suspended.items()}
        self._exact = OrderedDict(
            (k, put(v)) for k, v in self._exact.items())


# ===========================================================================
# Registry
# ===========================================================================
def layout_class(cfg: ModelConfig):
    """The layout class serving ``cfg``, or None when uncovered (the
    rollout worker then falls back to the static engine)."""
    if cfg.kind == DENSE and not cfg.sliding_window:
        return PagedKVLayout
    if cfg.kind == MOE and not cfg.sliding_window:
        return MoEPagedKVLayout
    if cfg.kind in (SSM, HYBRID):
        return StateCacheLayout
    return None


def covers(cfg: ModelConfig) -> bool:
    """True when the paged engine has a cache layout for ``cfg``."""
    return layout_class(cfg) is not None


def make_layout(cfg: ModelConfig, **kw) -> CacheLayout:
    cls = layout_class(cfg)
    if cls is None:
        if cfg.sliding_window and cfg.kind in (DENSE, MOE):
            raise NotImplementedError(
                "PagedEngine does not window the paged cache yet")
        raise NotImplementedError(
            f"PagedEngine has no cache layout for kind={cfg.kind}")
    return cls(cfg, **kw)
