from repro.comm.primitives import Payload, Router, global_router, reset_router  # noqa: F401
from repro.comm.resharding import (  # noqa: F401
    reshard,
    reshard_params,
    timed_weight_sync,
    transfer_stats,
)
