"""Cross-worker resharding: the production data plane for weight sync.

At production scale the trainer holds params FSDP+TP-sharded on its
sub-mesh while the rollout engine wants them TP-only (or differently
laid out) on ITS sub-mesh.  The paper's weight-update barrier is, in JAX
terms, a pytree `device_put` from one NamedSharding to another — XLA
emits the minimal collective schedule.  This module wraps that, plus the
byte accounting the profiler feeds to the scheduler (weight sync is part
of the context-switch cost).
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace


def reshard(tree: Any, shardings: Any) -> Any:
    """device_put every leaf to its destination sharding (async)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, shardings,
        is_leaf=lambda x: isinstance(x, (NamedSharding,)))


def reshard_params(params: Any, mesh: Mesh, specs: Any) -> Any:
    shardings = jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), specs,
        is_leaf=lambda x: isinstance(x, P))
    return reshard(params, shardings)


def transfer_stats(tree: Any) -> Dict[str, float]:
    """Bytes that a weight-sync of this tree moves (profiler input)."""
    total = 0
    n = 0
    for l in jax.tree_util.tree_leaves(tree):
        if hasattr(l, "nbytes"):
            total += int(l.nbytes)
            n += 1
    return {"bytes": float(total), "arrays": float(n)}


def timed_weight_sync(params: Any, dst_shardings: Any
                      ) -> Tuple[Any, float]:
    """Reshard + block, returning (new_tree, seconds) — the measured
    weight-update-barrier cost the scheduler charges between training and
    generation stages."""
    t0 = time.perf_counter()
    out = reshard(params, dst_shardings)
    jax.block_until_ready(out)
    t1 = time.perf_counter()
    tr = _trace.active()
    if tr is not None:
        stats = transfer_stats(params)
        tr.add("weight-sync", "sync", t0, t1, bytes=stats["bytes"],
               arrays=int(stats["arrays"]))
        reg = _metrics.active()
        if reg is not None:
            reg.counter("sync/count").inc()
            reg.counter("sync/bytes").inc(stats["bytes"])
            reg.histogram("sync/seconds").observe(t1 - t0)
    return out, t1 - t0
