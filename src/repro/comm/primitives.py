"""Adaptive communication layer (paper §3.5), JAX/single-host adaptation.

The paper's workers are Ray processes picking NCCL / cudaIPC / Gloo per
placement.  Here workers are threads of one process driving JAX devices;
the same *protocol* survives:

  * transparent connection lifecycle — a global :class:`Router` registers
    every worker at launch; point-to-point links are created lazily on
    first send and torn down on worker termination;
  * placement-aware backend choice — payload arrays travel as zero-copy
    references when src/dst share a device, via ``jax.device_put`` when
    they live on different devices/shardings, and as host numpy buffers
    for CPU workers;
  * structure-aware payloads — arbitrary pytrees are flattened; array
    leaves are moved buffer-by-buffer with the treedef piggybacked as
    metadata (never pickled).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


@dataclass
class Payload:
    """Structure-aware message: leaves + treedef travel separately."""

    treedef: Any
    leaves: List[Any]
    meta: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def pack(cls, obj: Any, **meta) -> "Payload":
        leaves, treedef = jax.tree_util.tree_flatten(obj)
        return cls(treedef=treedef, leaves=leaves, meta=meta)

    def unpack(self) -> Any:
        return jax.tree_util.tree_unflatten(self.treedef, self.leaves)

    def nbytes(self) -> int:
        total = 0
        for l in self.leaves:
            if hasattr(l, "nbytes"):
                total += int(l.nbytes)
        return total


class Connection:
    """A lazily-created point-to-point link (one queue per direction)."""

    def __init__(self, a: str, b: str):
        self.key = (a, b)
        self.q: "queue.Queue[Payload]" = queue.Queue()
        self.bytes_sent = 0
        self.messages = 0


class Router:
    """Global worker/connection manager (paper: worker manager + connection
    manager).  Thread-safe; one per Controller."""

    def __init__(self):
        self._workers: Dict[str, Dict[str, Any]] = {}
        self._conns: Dict[Tuple[str, str], Connection] = {}
        self._lock = threading.Lock()

    # -- registration (protocol level) ---------------------------------
    def register(self, name: str, *, devices: Optional[List[int]] = None,
                 host: str = "local") -> None:
        with self._lock:
            self._workers[name] = {
                "devices": devices or [], "host": host,
                "registered_at": time.time(),
            }

    def deregister(self, name: str) -> None:
        with self._lock:
            self._workers.pop(name, None)
            for key in [k for k in self._conns if name in k]:
                del self._conns[key]  # notify + teardown

    def placement(self, name: str) -> Optional[Dict[str, Any]]:
        return self._workers.get(name)

    def _conn(self, src: str, dst: str) -> Connection:
        with self._lock:
            key = (src, dst)
            if key not in self._conns:
                self._conns[key] = Connection(src, dst)
            return self._conns[key]

    # -- primitives ------------------------------------------------------
    def _needs_transfer(self, src: str, dst: str) -> bool:
        src_info, dst_info = self.placement(src), self.placement(dst)
        return bool(
            src_info and dst_info
            and src_info["devices"] and dst_info["devices"]
            and src_info["devices"] != dst_info["devices"]
        )

    @staticmethod
    def _host_leaves(leaves: List[Any]) -> List[Any]:
        """Move array leaves to host (the NCCL/cudaIPC analogue)."""
        return [np.asarray(l) if isinstance(l, jax.Array) else l
                for l in leaves]

    def _dispatch(self, src: str, dst: str, payload: Payload) -> None:
        conn = self._conn(src, dst)
        conn.q.put(payload)
        conn.bytes_sent += payload.nbytes()
        conn.messages += 1

    def send(self, src: str, dst: str, obj: Any, *, async_op: bool = True):
        """Backend selection happens here: same-device payloads pass by
        reference; cross-device arrays are resharded with device_put."""
        payload = Payload.pack(obj, src=src, dst=dst)
        if self._needs_transfer(src, dst):
            payload.leaves = self._host_leaves(payload.leaves)
            payload.meta["backend"] = "device_transfer"
        else:
            payload.meta["backend"] = "zero_copy"
        self._dispatch(src, dst, payload)
        return None

    def recv(self, dst: str, src: str, *, timeout: Optional[float] = None) -> Any:
        conn = self._conn(src, dst)
        payload = conn.q.get(timeout=timeout)
        return payload.unpack()

    def broadcast(self, src: str, dsts: List[str], obj: Any) -> None:
        """One-to-many send that flattens the pytree ONCE and shares the
        leaf buffers across destinations (leaves are read-only in transit,
        so structural sharing is safe); the host copy for cross-device
        destinations is also made at most once."""
        packed = Payload.pack(obj, src=src)
        host_leaves: Optional[List[Any]] = None  # lazily built, shared
        for d in dsts:
            if self._needs_transfer(src, d):
                if host_leaves is None:
                    host_leaves = self._host_leaves(packed.leaves)
                leaves, backend = host_leaves, "device_transfer"
            else:
                leaves, backend = packed.leaves, "zero_copy"
            self._dispatch(src, d, Payload(
                treedef=packed.treedef, leaves=leaves,
                meta={"src": src, "dst": d, "backend": backend,
                      "broadcast": True}))

    # -- stats -----------------------------------------------------------
    def stats(self) -> Dict[str, Dict[str, int]]:
        return {
            f"{a}->{b}": {"bytes": c.bytes_sent, "messages": c.messages}
            for (a, b), c in self._conns.items()
        }


_GLOBAL_ROUTER: Optional[Router] = None


def global_router() -> Router:
    global _GLOBAL_ROUTER
    if _GLOBAL_ROUTER is None:
        _GLOBAL_ROUTER = Router()
    return _GLOBAL_ROUTER


def reset_router() -> None:
    global _GLOBAL_ROUTER
    _GLOBAL_ROUTER = None
