"""Optimizer, trainer, checkpoint, data pipeline + property tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import init_model
from repro.train import (
    AdamWConfig,
    TrainHParams,
    adamw_update,
    init_adamw,
    lm_loss,
    make_prefill_step,
    make_train_step,
)
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.data import PromptDataset, decode_digits, encode_digits
from repro.train.optimizer import clip_by_global_norm, schedule_lr


def tiny_cfg():
    return get_config("yi-9b").reduced().replace(
        vocab_size=64, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_matches_manual_reference():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, clip_norm=0.0,
                      weight_decay=0.0)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st_ = init_adamw(p)
    p2, st2, _ = adamw_update(cfg, p, g, st_)
    # manual first step: m=0.1*g/(1-0.9), v=0.01*g^2/(1-0.99) -> delta=g/|g|
    mhat = 0.1 * 0.5 / (1 - 0.9)
    vhat = 0.01 * 0.25 / (1 - 0.99)
    expect = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    assert float(p2["w"][0]) == pytest.approx(expect, rel=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 3.0}  # norm 6
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(6.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    assert float(schedule_lr(cfg, jnp.int32(0))) == pytest.approx(0.1)
    assert float(schedule_lr(cfg, jnp.int32(9))) == pytest.approx(1.0)
    assert float(schedule_lr(cfg, jnp.int32(110))) == pytest.approx(
        0.1, rel=1e-3)


@settings(max_examples=10, deadline=None)
@given(norm=st.floats(0.1, 10.0), scale=st.floats(0.01, 100.0))
def test_clip_norm_property(norm, scale):
    g = {"a": jnp.ones(8) * scale}
    clipped, _ = clip_by_global_norm(g, norm)
    assert float(jnp.linalg.norm(clipped["a"])) <= norm * 1.001


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------
def test_lm_overfit_tiny_batch():
    """Supervised sanity: the stack must be able to drive CE toward 0 on a
    single repeated batch."""
    cfg = tiny_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    hp = TrainHParams(optimizer=AdamWConfig(lr=3e-3, clip_norm=1.0))
    step = jax.jit(make_train_step(cfg, hp, loss_fn=lm_loss))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    losses = []
    for _ in range(60):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.5, (losses[0], losses[-1])


def test_microbatch_accumulation_matches_full_batch():
    """n_microbatches must not change the computed update (up to fp)."""
    cfg = tiny_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    B, S = 8, 16
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                     cfg.vocab_size),
        "old_logprobs": jnp.full((B, S), -2.0),
        "advantages": jax.random.normal(jax.random.PRNGKey(2), (B, S)),
        "loss_mask": jnp.ones((B, S)),
    }
    # NOTE: token-level loss normalizes per microbatch; with uniform masks
    # the mean-of-means equals the global mean, so grads agree.
    hp1 = TrainHParams(n_microbatches=1)
    hp4 = TrainHParams(n_microbatches=4)
    p1, _, m1 = jax.jit(make_train_step(cfg, hp1))(params, opt, batch)
    p4, _, m4 = jax.jit(make_train_step(cfg, hp4))(params, opt, batch)
    l1 = jax.tree_util.tree_leaves(p1)
    l4 = jax.tree_util.tree_leaves(p4)
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=5e-3)


def test_prefill_step_alignment():
    """prefill logprobs entry t must score tokens[t] given the prefix."""
    cfg = tiny_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    pf = jax.jit(make_prefill_step(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                              cfg.vocab_size)
    lp = pf(params, {"tokens": toks})
    assert lp.shape == toks.shape
    assert float(jnp.abs(lp[:, 0]).max()) == 0.0  # entry 0 unused
    assert (lp[:, 1:] <= 0).all()


def test_policy_loss_zero_advantage_gives_zero_grad_signal():
    cfg = tiny_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    B, S = 2, 8
    pf = jax.jit(make_prefill_step(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = {
        "tokens": toks,
        "old_logprobs": pf(params, {"tokens": toks}),
        "advantages": jnp.zeros((B, S)),
        "loss_mask": jnp.ones((B, S)),
    }
    _, _, m = jax.jit(make_train_step(cfg, TrainHParams()))(params, opt,
                                                            batch)
    assert float(m["pg_loss"]) == pytest.approx(0.0, abs=1e-6)
    assert float(m["ratio_mean"]) == pytest.approx(1.0, rel=1e-4)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_adamw(params)
    save_checkpoint(str(tmp_path / "ck"), {"params": params, "opt": opt},
                    step=7, metadata={"arch": cfg.name})
    got, step, meta = load_checkpoint(str(tmp_path / "ck"),
                                      {"params": params, "opt": opt})
    assert step == 7 and meta["arch"] == cfg.name
    a = jax.tree_util.tree_leaves(params)
    b = jax.tree_util.tree_leaves(got["params"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_roundtrip_adversarial_keys(tmp_path):
    """Regression: keys were sanitized with k.replace('/', '|') and
    inverted with the reverse replace on load — any state key containing
    a literal '|' (or the escape char itself) silently corrupted.  The
    JSON-pointer-style escaping must round-trip them all."""
    tree = {
        "plain": np.arange(3, dtype=np.float32),
        "pipe|separated": np.ones(2, np.float32),
        "ti~lde": np.zeros(2, np.float32),
        "tricky~1combo": np.full(2, 7.0, np.float32),
        "even~0|~1worse": np.full(2, -1.0, np.float32),
        "nested": {"a|b": np.arange(4, dtype=np.int32)},
    }
    save_checkpoint(str(tmp_path / "ck"), tree, step=1)
    got, step, _ = load_checkpoint(str(tmp_path / "ck"), tree)
    assert step == 1
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_loads_legacy_pipe_escaped_arrays(tmp_path):
    """Checkpoints written by the old '|' scheme (no '|' in keys) must
    still load through the manifest-driven fallback."""
    import os

    import msgpack

    tree = {"layer": {"w": np.arange(4, dtype=np.float32)}}
    path = str(tmp_path / "legacy")
    os.makedirs(path)
    np.savez(os.path.join(path, "arrays.npz"),
             **{"/layer/w".replace("/", "|"): tree["layer"]["w"]})
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb({"step": 3, "keys": ["/layer/w"],
                               "metadata": {}}))
    got, step, _ = load_checkpoint(path, tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(got["layer"]["w"]),
                                  tree["layer"]["w"])


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_math_task_encode_decode_roundtrip():
    for n in (0, 7, 42, 81):
        assert decode_digits(encode_digits(n)) == n


def test_prompt_dataset_batches():
    ds = PromptDataset(8, prompt_len=8, seed=0)
    b = ds.next_batch()
    assert b["prompt_tokens"].shape == (8, 8)
    assert (b["answers"] >= 0).all()
    # prompts end at the same (right-aligned) position
    assert (b["prompt_tokens"][:, -1] != 0).all()
