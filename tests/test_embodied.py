"""Embodied cycle execution: realization parity, plan honoring, env
terminated/truncated semantics, GAE truncation bootstrap, checkpoint
wiring, and a tiny e2e learning run."""
import numpy as np
import pytest

from repro.core import CycleSpec, ExecutionFlowManager, cycle_node_name
from repro.core.scheduler import Leaf, leaves
from repro.rl import (
    EmbodiedPPOConfig,
    EmbodiedPPORunner,
    EnvConfig,
    VecReachEnv,
    gae_advantages,
)


def tiny_runner(mode: str, **kw) -> EmbodiedPPORunner:
    cfg = dict(num_envs=8, horizon=4, iterations=1, mode=mode, seed=0,
               profile_batches=(4, 8))
    cfg.update(kw)
    return EmbodiedPPORunner(EmbodiedPPOConfig(**cfg))


def run_one(runner: EmbodiedPPORunner):
    runner.profile()
    runner.plan_execution()
    runner._sync_weights()
    return runner.controller.execute(
        runner.plan, runner.workers, runner.task_fns, runner.make_batch(),
        cycle_specs=runner.cycle_specs())


# ---------------------------------------------------------------------------
# env semantics (satellite bugfixes)
# ---------------------------------------------------------------------------
def test_env_step_returns_post_reset_obs_and_terminal_obs():
    """Regression: step used to return the finished episode's terminal
    observation, so the next action (and the GAE bootstrap value) was
    computed from a dead state.  Now the returned obs is post-reset and
    the true final obs rides in info["terminal_obs"]."""
    env = VecReachEnv(EnvConfig(num_envs=4, max_steps=1), seed=0)
    obs, _, done, info = env.step(np.zeros(4, np.int64))
    assert done.all()  # max_steps=1: every episode ends at the first step
    # post-reset: step counters are 0 again, so the step_frac feature
    # (obs[:, 3]) is 0; the terminal obs was taken at steps=1 -> frac 1
    np.testing.assert_allclose(obs[:, 3], 0.0)
    np.testing.assert_allclose(info["terminal_obs"][:, 3], 1.0)
    # and the returned obs matches what the env would observe NOW
    np.testing.assert_array_equal(obs, env.observe())


def test_env_splits_terminated_from_truncated():
    # huge eps: every step reaches the goal -> terminated, not truncated
    env = VecReachEnv(EnvConfig(num_envs=4, max_steps=8, eps=1e9), seed=0)
    _, _, done, info = env.step(np.zeros(4, np.int64))
    assert done.all()
    assert info["terminated"].all() and not info["truncated"].any()
    # tiny arena horizon: timeouts are truncations
    env = VecReachEnv(EnvConfig(num_envs=4, max_steps=1, eps=1e-9), seed=0)
    _, _, done, info = env.step(np.zeros(4, np.int64))
    assert done.all()
    assert info["truncated"].all() and not info["terminated"].any()


def test_env_subset_stepping_matches_full_batch():
    """Per-env RNG: stepping halves separately consumes exactly the same
    random streams as stepping the full batch — the determinism the
    hybrid cycle realization's parity rests on."""
    a = VecReachEnv(EnvConfig(num_envs=8, max_steps=2), seed=3)
    b = VecReachEnv(EnvConfig(num_envs=8, max_steps=2), seed=3)
    rng = np.random.default_rng(0)
    for _ in range(6):  # several steps => several auto-resets
        acts = rng.integers(0, 9, size=8)
        obs_a, rew_a, done_a, _ = a.step(acts)
        o1, r1, d1, _ = b.step(acts[:4], np.arange(4))
        o2, r2, d2, _ = b.step(acts[4:], np.arange(4, 8))
        np.testing.assert_array_equal(obs_a, np.concatenate([o1, o2]))
        np.testing.assert_array_equal(rew_a, np.concatenate([r1, r2]))
        np.testing.assert_array_equal(done_a, np.concatenate([d1, d2]))


# ---------------------------------------------------------------------------
# GAE terminated/truncated split (satellite bugfix)
# ---------------------------------------------------------------------------
def test_gae_truncation_bootstraps_termination_does_not():
    rewards = np.array([[1.0]], np.float32)
    values = np.array([[0.0], [5.0]], np.float32)  # bootstrap value 5
    term = np.array([[1.0]], np.float32)
    trunc = np.array([[1.0]], np.float32)
    zeros = np.zeros_like(term)
    adv_term, _ = gae_advantages(rewards, values, gamma=1.0, lam=1.0,
                                 terminated=term, truncated=zeros)
    adv_trunc, _ = gae_advantages(rewards, values, gamma=1.0, lam=1.0,
                                  terminated=zeros, truncated=trunc)
    assert adv_term[0, 0] == pytest.approx(1.0)  # bootstrap dropped
    assert adv_trunc[0, 0] == pytest.approx(6.0)  # bootstrapped through
    # the truncated step should bootstrap with the TERMINAL obs value,
    # not the post-reset values[t+1]
    adv_tv, _ = gae_advantages(rewards, values, gamma=1.0, lam=1.0,
                               terminated=zeros, truncated=trunc,
                               terminal_values=np.array([[2.0]], np.float32))
    assert adv_tv[0, 0] == pytest.approx(3.0)
    # both kinds of end reset the advantage carry
    r2 = np.array([[1.0], [7.0]], np.float32)
    v2 = np.zeros((3, 1), np.float32)
    adv2, _ = gae_advantages(r2, v2, gamma=1.0, lam=1.0,
                             terminated=np.zeros((2, 1), np.float32),
                             truncated=np.array([[1.0], [0.0]], np.float32))
    assert adv2[0, 0] == pytest.approx(1.0)  # no bleed from t=1
    # legacy positional dones == terminated (old call sites unchanged)
    adv_legacy, _ = gae_advantages(rewards, values, term, gamma=1.0, lam=1.0)
    np.testing.assert_allclose(adv_legacy, adv_term)


# ---------------------------------------------------------------------------
# cycle execution parity + plan honoring (tentpole)
# ---------------------------------------------------------------------------
def test_cycle_realizations_produce_identical_trajectories():
    """Collocated and hybrid realizations of the same seeded workflow
    must emit bit-identical trajectories: actions are sampled with
    per-(step, env) keys and the env's RNG is per-env, so chunked
    pipelined execution draws the same randomness as full-batch
    alternation."""
    out_c = run_one(tiny_runner("collocated"))
    out_h = run_one(tiny_runner("hybrid"))
    for k in ("action_tokens", "rewards", "terminated", "truncated",
              "obs", "terminal_obs", "tokens", "dones"):
        np.testing.assert_array_equal(
            np.asarray(out_c[k]), np.asarray(out_h[k]), err_msg=k)
    np.testing.assert_allclose(out_c["action_logprobs"],
                               out_h["action_logprobs"], atol=1e-5)
    assert out_c["successes"] == out_h["successes"]


def test_forced_modes_recorded_on_leaf_and_honored_by_executor():
    for mode in ("collocated", "hybrid"):
        runner = tiny_runner(mode)
        run_one(runner)
        cyc = [lf for lf in leaves(runner.plan.schedule)
               if lf.worker.startswith("cycle(")]
        assert len(cyc) == 1
        assert cyc[0].cycle_mode == mode
        log = runner.controller.last_cycle_log
        assert len(log) == 1
        node, ran_mode, member_devices, chunks = log[0]
        assert ran_mode == mode  # the executor ran the RECORDED mode
        assert member_devices == cyc[0].member_devices
        if mode == "hybrid":
            assert member_devices is not None
            assert sum(member_devices) <= cyc[0].devices
            assert chunks == cyc[0].cycle_chunks


def test_executor_honors_leaf_not_rederivation():
    """Hand the executor two plans differing ONLY in the Leaf's recorded
    realization; it must run each as recorded — there is no cost-model
    re-derivation in the execution path."""
    runner = tiny_runner("auto")
    runner.profile()
    runner.plan_execution()
    name = cycle_node_name(("policy_gen", "simulator"))
    members = {name: ("policy_gen", "simulator")}
    for leaf, want in (
            (Leaf(name, 4, 8, cycle_mode="collocated"), "collocated"),
            (Leaf(name, 4, 8, cycle_mode="hybrid",
                  member_devices=(2, 2)), "hybrid")):
        mgr = ExecutionFlowManager(runner.workers, runner.task_fns,
                                   members=members,
                                   cycle_specs=runner.cycle_specs())
        out = mgr.run(leaf, runner.make_batch())
        assert mgr.cycle_log[0][1] == want
        assert out["rewards"].shape == (runner.rl.horizon, 8)


def test_cycle_placement_binds_member_workers():
    """The plan's placement column names the MEMBER workers (the real
    ones the PlacementManager can bind), with disjoint shares under the
    hybrid realization and a shared slice under collocation."""
    r_h = tiny_runner("hybrid")
    r_h.profile()
    r_h.plan_execution()
    pl = r_h.plan.placement
    assert "policy_gen" in pl and "simulator" in pl
    assert not set(pl["policy_gen"]) & set(pl["simulator"])  # disjoint
    r_c = tiny_runner("collocated")
    r_c.profile()
    r_c.plan_execution()
    pl = r_c.plan.placement
    assert pl["policy_gen"] == pl["simulator"]  # time-shared slice


def test_simulator_replays_recorded_realization():
    """The event simulator prices a cycle leaf by its RECORDED
    realization, not a re-derived cheaper-of-two."""
    from repro.core import Simulator
    from repro.core.profiler import CostModel

    profiles = {
        "sim": CostModel("sim", base_time=1.0, scalable=False,
                         max_useful_devices=1),
        "gen": CostModel("gen", base_time=0.0, slope_time=0.01),
    }
    members = {"cycle(gen+sim)": ("gen", "sim")}
    sim = Simulator(profiles, members)
    col = Leaf("cycle(gen+sim)", 4, 16, cycle_mode="collocated")
    hyb = Leaf("cycle(gen+sim)", 4, 16, cycle_mode="hybrid",
               member_devices=(3, 1), cycle_chunks=2)
    t_col = sim.run(col, 16).makespan
    t_hyb = sim.run(hyb, 16).makespan
    # flat-cost sim: hybrid pays the chunk count (2 x 1.0s), collocation
    # pays one step (1.0s + gen) — the simulator must NOT silently
    # substitute the cheaper realization
    assert t_hyb > t_col
    assert t_col == pytest.approx(1.0 + 0.01 * 16 / 4)


# ---------------------------------------------------------------------------
# checkpoint wiring (satellite): periodic save + resume through the runner
# ---------------------------------------------------------------------------
def test_runner_checkpoint_save_and_resume(tmp_path):
    import jax

    ck = str(tmp_path / "ck")
    r1 = tiny_runner("collocated", iterations=2, checkpoint_dir=ck,
                     checkpoint_every=1)
    r1.profile()
    r1.plan_execution()
    r1.run_loop(verbose=False)
    assert len(r1.stats) == 2
    r2 = tiny_runner("collocated", iterations=2, checkpoint_dir=ck,
                     checkpoint_every=1)
    r2.profile()
    r2.plan_execution()
    start = r2.resume_trainer_checkpoint()
    assert start == 2  # resumes after the last completed iteration
    p1 = jax.tree_util.tree_leaves(r1.actor.get_state("params"))
    p2 = jax.tree_util.tree_leaves(r2.actor.get_state("params"))
    for a, b in zip(p1, p2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# tiny e2e: the runner-driven loop actually learns
# ---------------------------------------------------------------------------
def test_embodied_runner_learns_above_random():
    """30 iterations through the full runtime must lift the success rate
    well above the random-policy baseline (~0.05 successes/env on this
    horizon)."""
    rl = EmbodiedPPOConfig(num_envs=32, horizon=12, iterations=30,
                           mode="auto", seed=0, profile_batches=(16, 32))
    runner = EmbodiedPPORunner(rl)
    runner.run(verbose=False)
    curve = runner.success_curve()
    first = float(np.mean(curve[:5]))
    last = float(np.mean(curve[-10:]))
    assert last > first + 0.1, (first, last)
    assert last > 0.2, last  # far above the ~0.05 random baseline
