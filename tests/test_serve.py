"""Serve subsystem: paged KV allocator, continuous-batching scheduler,
sampling filters, and PagedEngine parity/lifecycle contracts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.profiler import engine_cost_model, fit_tail_factor
from repro.models import init_model
from repro.models.layers import token_logprobs
from repro.serve import (Engine, OutOfPages, PageAccountingError,
                         PagedEngine, PageAllocator, PrefixCache)
from repro.serve.paging import TRASH_PAGE, pad_block_table
from repro.serve.sampling import sample_token, top_k_logits, top_p_logits
from repro.serve.scheduler import ContinuousScheduler
from repro.train.data import PromptDataset


def dense_cfg():
    return get_config("yi-9b").reduced().replace(
        vocab_size=32, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128)


@pytest.fixture(scope="module")
def cfg():
    return dense_cfg()


@pytest.fixture(scope="module")
def params(cfg):
    return init_model(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------
def test_allocator_never_hands_out_trash_page():
    a = PageAllocator(num_pages=8, page_size=4)
    got = a.allocate(7)
    assert TRASH_PAGE not in got
    assert sorted(got) == list(range(1, 8))


def test_allocator_free_list_reuse_and_exhaustion():
    a = PageAllocator(num_pages=6, page_size=4)
    first = a.allocate(3)
    assert a.num_free == 2
    a.free(first)
    assert a.num_free == 5
    again = a.allocate(5)
    assert set(first) <= set(again)  # freed pages are recycled
    with pytest.raises(OutOfPages):
        a.allocate(1)


def test_allocator_double_free_raises_typed_error():
    a = PageAllocator(num_pages=4, page_size=2)
    pages = a.allocate(1)
    a.free(pages)
    # the page must NOT re-enter the free list twice (two requests would
    # be handed the same page); the typed error makes the bug loud
    with pytest.raises(PageAccountingError):
        a.free(pages)
    assert a.num_free == 3  # freed exactly once


def test_allocator_refcount_sharing_lifecycle():
    a = PageAllocator(num_pages=4, page_size=2)
    (p,) = a.allocate(1)
    a.incref([p])           # a sharer adopts the page
    assert a.refcount(p) == 2
    a.free([p])             # first owner drops out
    assert a.refcount(p) == 1 and a.num_free == 2
    a.free([p])             # last reference: physically freed
    assert a.refcount(p) == 0 and a.num_free == 3
    with pytest.raises(PageAccountingError):
        a.incref([p])       # incref of an unallocated page is a bug


def test_pages_needed_is_ceil_div():
    a = PageAllocator(num_pages=4, page_size=8)
    assert a.pages_needed(1) == 1
    assert a.pages_needed(8) == 1
    assert a.pages_needed(9) == 2


def test_pad_block_table_pads_with_trash():
    assert pad_block_table([3, 5], 4) == [3, 5, TRASH_PAGE, TRASH_PAGE]


# ---------------------------------------------------------------------------
# continuous-batching scheduler
# ---------------------------------------------------------------------------
def _sched(max_batch=2, num_pages=9, page_size=4, max_seq=16):
    alloc = PageAllocator(num_pages=num_pages, page_size=page_size)
    return ContinuousScheduler(max_batch=max_batch, allocator=alloc,
                               max_seq_len=max_seq)


def test_scheduler_admits_fifo_up_to_slots():
    s = _sched(max_batch=2)
    r1 = s.submit([1, 2, 3], 4)
    r2 = s.submit([1, 2], 4)
    r3 = s.submit([9], 4)
    joined = s.admit()
    assert [r.rid for r in joined] == [r1.rid, r2.rid]
    assert r3.state == "queued" and s.num_active == 2


def test_scheduler_backfills_freed_slot_and_pages():
    s = _sched(max_batch=1, num_pages=3, page_size=4)
    r1 = s.submit([1, 2, 3], 2)
    r2 = s.submit([4, 5], 2)
    (a,) = s.admit()
    assert a is r1 and s.allocator.num_free == 1
    assert not s.admit()  # no slot free
    s.finish(r1)  # evict: pages back on the free list immediately
    assert s.allocator.num_free == 2 and r1.pages == []
    (b,) = s.admit()
    assert b is r2 and r2.slot == 0  # freed slot reused


def test_scheduler_blocks_admission_on_page_budget():
    # 2 slots but pages for only one prompt at a time
    s = _sched(max_batch=2, num_pages=3, page_size=2, max_seq=8)
    s.submit([1, 2, 3], 2)  # needs ceil(4/2)=2 pages
    s.submit([1, 2, 3], 2)
    joined = s.admit()
    assert len(joined) == 1  # second must wait for pages, not slots


def test_scheduler_ensure_page_grows_block_table():
    s = _sched(max_batch=1, num_pages=9, page_size=2, max_seq=16)
    r = s.submit([1, 2, 3], 8)
    s.admit()
    npages = len(r.pages)
    r.num_cached = npages * 2  # simulate filling every allocated slot
    s.ensure_page_for(r)
    assert len(r.pages) == npages + 1


# ---------------------------------------------------------------------------
# sampling: top-k / top-p
# ---------------------------------------------------------------------------
def test_top_k_keeps_exactly_k():
    logits = jnp.asarray([0.1, 2.0, -1.0, 3.0, 0.5])
    out = top_k_logits(logits, 2)
    kept = np.asarray(out) > -1e29
    assert kept.tolist() == [False, True, False, True, False]


def test_top_k_disabled_for_nonpositive_or_full_k():
    logits = jnp.asarray([0.1, 2.0, -1.0])
    np.testing.assert_array_equal(np.asarray(top_k_logits(logits, 0)),
                                  np.asarray(logits))
    np.testing.assert_array_equal(np.asarray(top_k_logits(logits, 3)),
                                  np.asarray(logits))


def test_top_p_nucleus_mass_property():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (64,))
    p = 0.7
    out = np.asarray(top_p_logits(logits, p))
    probs = np.asarray(jax.nn.softmax(logits))
    kept = out > -1e29
    # kept set is the smallest prefix of the sorted distribution >= p
    order = np.argsort(-probs)
    cum = np.cumsum(probs[order])
    n_expected = int(np.searchsorted(cum, p)) + 1
    assert kept.sum() == n_expected
    assert probs[kept].sum() >= p - 1e-6


def test_top_p_always_keeps_argmax():
    logits = jnp.asarray([0.0, 5.0, 1.0, -2.0])
    out = np.asarray(top_p_logits(logits, 1e-6))
    assert out[1] > -1e29 and (out[[0, 2, 3]] < -1e29).all()


@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 16), seed=st.integers(0, 50))
def test_sample_token_respects_top_k(k, seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (32,)) * 3
    allowed = set(np.argsort(-np.asarray(logits))[:k].tolist())
    tok, _ = sample_token(jax.random.fold_in(key, 1), logits,
                          temperature=1.0, top_k=k)
    assert int(tok) in allowed


def test_sample_token_greedy_and_behaviour_logprob():
    logits = jnp.asarray([0.0, 4.0, 1.0, 2.0])
    tok, lp = sample_token(jax.random.PRNGKey(0), logits, temperature=0.0)
    assert int(tok) == 1
    # behaviour logprob is under the UNFILTERED temp-1 policy
    want = float(token_logprobs(logits[None], jnp.asarray([1]))[0])
    assert lp == pytest.approx(want, abs=1e-6)


def test_sample_token_masks_padded_vocab():
    logits = jnp.asarray([0.0, 1.0, 50.0, 60.0])  # ids 2,3 are padding
    for s in range(8):
        tok, _ = sample_token(jax.random.PRNGKey(s), logits,
                              temperature=1.0, vocab_size=2)
        assert int(tok) < 2


# ---------------------------------------------------------------------------
# PagedEngine vs legacy Engine
# ---------------------------------------------------------------------------
def test_paged_matches_legacy_token_for_token_at_temp0(cfg, params):
    ds = PromptDataset(6, prompt_len=6, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    legacy = Engine(cfg, max_new_tokens=8, temperature=0.0)
    want = legacy.generate(params, jnp.asarray(prompts),
                           key=jax.random.PRNGKey(1))
    # fewer slots than requests -> exercises queueing + backfill
    paged = PagedEngine(cfg, max_batch=4, page_size=4, max_new_tokens=8,
                        temperature=0.0)
    got = paged.generate(params, prompts, key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(want.tokens),
                                  np.asarray(got.tokens))
    np.testing.assert_array_equal(np.asarray(want.lengths),
                                  np.asarray(got.lengths))
    np.testing.assert_allclose(np.asarray(want.logprobs),
                               np.asarray(got.logprobs), atol=1e-4)
    # after the drain the only live pages are the prefix cache's; a flush
    # returns every page to the free list
    assert paged.allocator.num_allocated == paged.prefix_cache.num_pages
    paged.release_prefix_cache()
    assert paged.allocator.num_allocated == 0


@pytest.mark.parametrize("page_size", [2, 4, 16])
def test_paged_engine_parity_across_page_sizes(cfg, params, page_size):
    ds = PromptDataset(4, prompt_len=5, seed=3)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    legacy = Engine(cfg, max_new_tokens=6, temperature=0.0)
    want = np.asarray(legacy.generate(params, jnp.asarray(prompts)).tokens)
    paged = PagedEngine(cfg, max_batch=2, page_size=page_size,
                        max_new_tokens=6, temperature=0.0)
    got = np.asarray(paged.generate(params, prompts).tokens)
    np.testing.assert_array_equal(want, got)


def test_paged_engine_kernel_backed_parity(cfg, params):
    ds = PromptDataset(3, prompt_len=5, seed=2)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    ref_eng = PagedEngine(cfg, max_batch=3, page_size=4, max_new_tokens=5,
                          temperature=0.0)
    kern_eng = PagedEngine(cfg, max_batch=3, page_size=4, max_new_tokens=5,
                           temperature=0.0, use_kernel=True)
    a = ref_eng.generate(params, prompts)
    b = kern_eng.generate(params, prompts)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    np.testing.assert_allclose(np.asarray(a.logprobs),
                               np.asarray(b.logprobs), atol=1e-4)


def test_paged_engine_scheduling_invariant_sampling(cfg, params):
    """Per-request RNG: results must not depend on slot count/batching."""
    ds = PromptDataset(5, prompt_len=5, seed=1)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    outs = []
    for max_batch in (2, 5):
        eng = PagedEngine(cfg, max_batch=max_batch, page_size=4,
                          max_new_tokens=6, temperature=1.0)
        outs.append(eng.generate(params, prompts,
                                 key=jax.random.PRNGKey(7)))
    np.testing.assert_array_equal(np.asarray(outs[0].tokens),
                                  np.asarray(outs[1].tokens))
    np.testing.assert_allclose(np.asarray(outs[0].logprobs),
                               np.asarray(outs[1].logprobs), atol=1e-5)


def test_paged_engine_logprobs_match_prefill_recompute(cfg, params):
    """Same contract the legacy engine honours: behaviour logprobs from
    generation equal the inference worker's recompute."""
    from repro.train import make_prefill_step

    ds = PromptDataset(4, prompt_len=6, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    eng = PagedEngine(cfg, max_batch=4, page_size=4, max_new_tokens=6,
                      temperature=1.0)
    res = eng.generate(params, prompts, key=jax.random.PRNGKey(5))
    pf = jax.jit(make_prefill_step(cfg))
    recomputed = pf(params, {"tokens": jnp.asarray(res.tokens)})
    S = prompts.shape[1]
    gen_lp = np.asarray(res.logprobs)[:, S:]
    rec_lp = np.asarray(recomputed)[:, S:]
    mask = np.asarray(res.tokens)[:, S:] != 0
    np.testing.assert_allclose(gen_lp[mask], rec_lp[mask], atol=2e-3)


def test_paged_engine_ragged_lengths_and_page_recycling(cfg, params):
    """Skewed per-request budgets: short requests leave early, pages are
    recycled, and the engine takes far fewer slot-steps than static
    padding would."""
    eng = PagedEngine(cfg, max_batch=4, page_size=4, max_new_tokens=32,
                      temperature=0.0, max_seq_len=4 + 32, num_pages=4 * 9 + 1,
                      eos_token=-1)  # never sampled: budget-driven stop
    ds = PromptDataset(8, prompt_len=4, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    budgets = [2, 2, 2, 2, 2, 2, 2, 24]
    reqs = [eng.submit(prompts[i], max_new_tokens=budgets[i], seed=i)
            for i in range(8)]
    eng.set_params(params)
    done = eng.run()
    assert len(done) == 8
    for r, b in zip(reqs, budgets):
        assert len(r.generated) == b
    eng.release_prefix_cache()
    assert eng.allocator.num_allocated == 0
    # static padding would cost 8 requests x (4 + 24) slot-steps in two
    # full batches; continuous batching re-forms the batch every step
    static_steps = 2 * (4 + 24)
    assert eng.decode_steps < static_steps


# ---------------------------------------------------------------------------
# in-flight weight sync
# ---------------------------------------------------------------------------
def test_paged_engine_inflight_weight_update_version_tags(cfg, params):
    params_v1 = jax.tree_util.tree_map(lambda x: x * 1.05, params)
    eng = PagedEngine(cfg, max_batch=2, page_size=4, max_new_tokens=6,
                      temperature=0.0, eos_token=-1)
    ds = PromptDataset(4, prompt_len=5, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    eng.set_params(params, version=0)
    reqs = [eng.submit(prompts[i], seed=i) for i in range(4)]
    # run a few steps under v0, then swap in flight
    for _ in range(3):
        eng.step()
    eng.update_weights(params_v1, version=1)
    eng.run()
    assert eng.weight_version == 1 and eng.weight_swaps == 1
    # requests admitted before the swap keep their admission tag (what
    # the staleness correction references) but record the newer weights
    early = [r for r in reqs if r.weight_version == 0]
    late = [r for r in reqs if r.weight_version == 1]
    assert early and late  # 2 slots x 4 requests straddle the swap
    assert all(r.last_weight_version == 1 for r in late)
    assert all(r.last_weight_version >= r.weight_version for r in reqs)


def test_paged_engine_requests_after_swap_match_new_params(cfg, params):
    """A request admitted after an in-flight swap must generate exactly
    what a fresh engine holding only the new weights generates."""
    params_v1 = jax.tree_util.tree_map(lambda x: x * 1.1, params)
    ds = PromptDataset(2, prompt_len=5, seed=4)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])

    eng = PagedEngine(cfg, max_batch=1, page_size=4, max_new_tokens=5,
                      temperature=0.0)
    eng.set_params(params, version=0)
    first = eng.submit(prompts[0], seed=0)
    eng.update_weights(params_v1, version=1)  # lands before any step
    second = eng.submit(prompts[1], seed=1)
    eng.run()
    assert first.weight_version == 1 and second.weight_version == 1

    fresh = PagedEngine(cfg, max_batch=1, page_size=4, max_new_tokens=5,
                        temperature=0.0)
    fresh.set_params(params_v1, version=1)
    ref2 = fresh.submit(prompts[1], seed=1)
    fresh.run()
    assert second.generated == ref2.generated


def test_rollout_worker_paged_engine_roundtrip(cfg, params):
    from repro.rl.workers import RolloutWorker

    w = RolloutWorker("rollout/t", cfg=cfg, max_new_tokens=4,
                      temperature=1.0, engine="paged", max_batch=4,
                      page_size=4)
    assert isinstance(w.engine, PagedEngine)
    w.update_weights(params, version=3)
    ds = PromptDataset(4, prompt_len=5, seed=0)
    out = w.generate({"prompt_tokens": np.asarray(
        ds.next_batch()["prompt_tokens"])})
    assert out["tokens"].shape[1] == 5 + 4
    assert (out["weight_versions"] == 3).all()
    recs = w.request_records()
    assert len(recs) == 4 and all(t >= 0 for _, t in recs)
    w.shutdown()


# ---------------------------------------------------------------------------
# prefix cache: radix trie over page-aligned token blocks
# ---------------------------------------------------------------------------
def test_prefix_cache_insert_lookup_roundtrip():
    a = PageAllocator(num_pages=16, page_size=4)
    c = PrefixCache(page_size=4)
    toks = list(range(10))  # 2 full pages + a 2-token partial leaf
    pages = a.allocate(3)
    c.insert(toks, pages, a)
    assert c.num_pages == 3
    # the trie holds one reference per indexed page (owner + cache)
    assert all(a.refcount(p) == 2 for p in pages)
    m = c.lookup(toks)
    assert [n.page for n in m.nodes] == pages[:2]
    assert m.partial is not None and m.partial.page == pages[2]
    assert m.partial_rows == 2
    # a prompt diverging after the full pages matches only those
    m2 = c.lookup(toks[:8] + [99, 98])
    assert [n.page for n in m2.nodes] == pages[:2]
    assert m2.partial is None and m2.partial_rows == 0


def test_prefix_cache_cow_candidate_from_full_page_head():
    """A prompt sharing only the leading rows of a cached FULL page gets
    that page as a copy-on-write donor, not as an adopted page."""
    a = PageAllocator(num_pages=8, page_size=4)
    c = PrefixCache(page_size=4)
    pages = a.allocate(1)
    c.insert([0, 1, 2, 3], pages, a)
    m = c.lookup([0, 1, 2, 99, 100])
    assert m.nodes == [] and m.partial is not None
    assert m.partial.page == pages[0] and m.partial_rows == 3


def test_prefix_cache_evicts_lru_leaves_first():
    a = PageAllocator(num_pages=16, page_size=2)
    c = PrefixCache(page_size=2)
    pa = a.allocate(2)
    pb = a.allocate(1)
    c.insert([0, 1, 2, 3], pa, a)
    c.insert([9, 9], pb, a)
    a.free(pa + pb)  # owners finished: only the cache's refs remain
    assert a.num_allocated == 3
    c.lookup([0, 1, 2, 3])  # touch chain A -> chain B becomes LRU
    assert c.evict(1, a) == 1
    assert c.num_pages == 2 and a.refcount(pb[0]) == 0
    # next eviction takes chain A's leaf; the parent is not a leaf yet
    assert c.evict(1, a) == 1
    assert a.refcount(pa[1]) == 0 and a.refcount(pa[0]) == 1
    # the parent became a leaf; asking for more than exists is bounded
    assert c.evict(5, a) == 1
    assert c.num_pages == 0 and a.num_allocated == 0


def test_prefix_cache_eviction_refuses_shared_and_writing_pages():
    a = PageAllocator(num_pages=8, page_size=2)
    c = PrefixCache(page_size=2)
    mine = a.allocate(1)
    c.insert([5, 6], mine, a)  # rc 2: running request + cache
    assert c.evict(1, a) == 0  # pinned by the running request
    theirs = a.allocate(1)
    c.insert([7, 8], theirs, a, writer=42)
    a.free(mine + theirs)  # both owners drop their refs
    # the page still being prefilled (writer attached) is not evictable
    assert c.evict(2, a) == 1
    assert a.refcount(theirs[0]) == 1 and a.refcount(mine[0]) == 0
    c.release_writer(42)
    assert c.evict(2, a) == 1
    assert c.num_pages == 0 and a.num_allocated == 0


def test_prefix_cache_flush_releases_everything():
    a = PageAllocator(num_pages=8, page_size=2)
    c = PrefixCache(page_size=2)
    pgs = a.allocate(3)
    c.insert([0, 1, 2, 3, 4], pgs, a, writer=7)
    a.free(pgs)
    assert a.num_allocated == 3
    assert c.flush(a) == 3
    assert c.num_pages == 0 and a.num_allocated == 0
    m = c.lookup([0, 1, 2, 3])
    assert not m.nodes and m.partial is None


# ---------------------------------------------------------------------------
# prefix sharing through the engine
# ---------------------------------------------------------------------------
def test_grpo_group_allocates_shared_prompt_pages_once(cfg, params):
    """A GRPO group's N identical prompts must prefill the prompt KV
    once: followers adopt the leader's pages through the radix cache.
    Asserted via allocator accounting, not timing."""
    ds = PromptDataset(1, prompt_len=16, seed=0)
    prompt = np.asarray(ds.next_batch()["prompt_tokens"])[0]
    group = np.stack([prompt] * 8)

    def run(sharing):
        eng = PagedEngine(cfg, max_batch=8, page_size=8, max_new_tokens=4,
                          temperature=0.0, prefix_sharing=sharing)
        out = eng.generate(params, group, key=jax.random.PRNGKey(0))
        return eng, np.asarray(out.tokens)

    shared_eng, shared_toks = run(True)
    private_eng, private_toks = run(False)
    np.testing.assert_array_equal(shared_toks, private_toks)
    # shared: 2 prompt pages allocated once + 1 decode page per request;
    # private: 3 pages x 8 requests
    assert shared_eng.allocator.pages_allocated_total == 10
    assert private_eng.allocator.pages_allocated_total == 24
    assert shared_eng.scheduler.stats.prefix_hit_tokens > 0
    assert shared_eng.scheduler.stats.prefix_shared_pages == 14  # 7 x 2


def test_prefix_cache_copy_on_write_divergent_tail(cfg, params):
    """Two prompts sharing a partial page: the second copies the shared
    rows into its own page (never mutating the cached one) and still
    generates exactly what a cold engine does."""
    ds = PromptDataset(1, prompt_len=6, seed=8)
    base = [int(t) for t in np.asarray(ds.next_batch()["prompt_tokens"])[0]]
    p2 = base[:5] + [(base[5] + 1) % 32]  # diverges inside page 2

    eng = PagedEngine(cfg, max_batch=1, page_size=4, max_new_tokens=4,
                      temperature=0.0)
    eng.set_params(params)
    eng.submit(base, seed=0)
    eng.run()
    r2 = eng.submit(p2, seed=1)
    eng.run()
    assert eng.scheduler.stats.cow_pages >= 1

    cold = PagedEngine(cfg, max_batch=1, page_size=4, max_new_tokens=4,
                       temperature=0.0, prefix_sharing=False)
    cold.set_params(params)
    c2 = cold.submit(p2, seed=1)
    cold.run()
    assert r2.generated == c2.generated
    np.testing.assert_allclose(r2.logprobs, c2.logprobs, atol=1e-5)


def test_preempt_resume_with_shared_prefix_pages(cfg, params):
    """Preempting a request that holds shared (ref-counted) pages must
    decref rather than blind-free: the survivors keep decoding from the
    shared prefix, the victim replays deterministically on resume, and
    the pool drains to exactly the cache-held pages."""
    ds = PromptDataset(1, prompt_len=8, seed=9)
    prompt = np.asarray(ds.next_batch()["prompt_tokens"])[0]
    group = np.stack([prompt] * 3)

    def run(num_pages):
        eng = PagedEngine(cfg, max_batch=3, page_size=4, max_seq_len=32,
                          max_new_tokens=20, temperature=1.0,
                          num_pages=num_pages, eos_token=-1)
        out = eng.generate(params, group, key=jax.random.PRNGKey(11))
        eng.release_prefix_cache()
        assert eng.allocator.num_allocated == 0
        return eng, np.asarray(out.tokens)

    tight_eng, tight = run(num_pages=12)   # 11 usable << 20-page peak
    roomy_eng, roomy = run(num_pages=None)
    assert tight_eng.scheduler.stats.preempted > 0
    assert roomy_eng.scheduler.stats.preempted == 0
    np.testing.assert_array_equal(tight, roomy)


def test_chunked_prefill_parity_and_deferral_accounting(cfg, params):
    """A tiny per-step prefill budget must spread prompt ingestion over
    steps — counting the deferred tokens — without changing a single
    sampled token or logprob."""
    ds = PromptDataset(3, prompt_len=24, seed=5)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])

    def run(chunk):
        eng = PagedEngine(cfg, max_batch=3, page_size=4, max_new_tokens=5,
                          temperature=1.0, prefill_chunk=chunk)
        return eng, eng.generate(params, prompts, key=jax.random.PRNGKey(2))

    small_eng, small = run(8)
    big_eng, big = run(256)
    np.testing.assert_array_equal(np.asarray(small.tokens),
                                  np.asarray(big.tokens))
    np.testing.assert_allclose(np.asarray(small.logprobs),
                               np.asarray(big.logprobs), atol=1e-5)
    assert small_eng.scheduler.stats.chunk_deferred_tokens > 0
    assert big_eng.scheduler.stats.chunk_deferred_tokens == 0


def test_serve_metrics_surface_under_tracing(cfg, params):
    """The serve-tier counters/gauges only record while tracing is
    armed, and land in the default registry under serve/ and engine/."""
    from repro.obs import default_registry, tracing

    ds = PromptDataset(1, prompt_len=16, seed=0)
    prompt = np.asarray(ds.next_batch()["prompt_tokens"])[0]
    group = np.stack([prompt] * 4)
    eng = PagedEngine(cfg, max_batch=4, page_size=8, max_new_tokens=3,
                      temperature=0.0)
    default_registry().clear()
    try:
        with tracing():
            eng.generate(params, group, key=jax.random.PRNGKey(0))
        snap = default_registry().snapshot()
        assert snap["serve/prefix_hit_tokens"]["value"] > 0
        assert snap["serve/radix_pages"]["max"] > 0
        assert snap["engine/page_util"]["max"] > 0
    finally:
        default_registry().clear()


# ---------------------------------------------------------------------------
# profiler: measured tail factor
# ---------------------------------------------------------------------------
def test_fit_tail_factor_known_values():
    assert fit_tail_factor([1.0, 1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert fit_tail_factor([1.0, 1.0, 6.0]) == pytest.approx(6.0 / (8 / 3))
    assert fit_tail_factor([]) == 1.0


def test_engine_cost_model_fits_measured_tail(cfg, params):
    eng = PagedEngine(cfg, max_batch=4, page_size=4, max_new_tokens=16,
                      temperature=0.0, eos_token=-1)
    ds = PromptDataset(4, prompt_len=4, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    eng.set_params(params)
    # warm-up request so the jitted step compiles outside the measurement
    eng.submit(prompts[0], max_new_tokens=1, seed=99)
    eng.run()
    eng.pop_request_records()
    for i, budget in enumerate([2, 2, 2, 12]):
        eng.submit(prompts[i], max_new_tokens=budget, seed=i)
    eng.run()
    recs = eng.pop_request_records()
    cm = engine_cost_model("rollout", recs)
    # the skewed budgets must surface as a measured long tail
    assert cm.tail_factor > 1.2
    assert cm.slope_time >= 0.0
    # the log is consumed
    assert eng.pop_request_records() == []


def test_paged_engine_preempts_on_page_exhaustion(cfg, params):
    """A pool too small for the whole batch must trigger recompute
    preemption (youngest request yields), not crash — and the output must
    be identical to an uncontended run (deterministic per-request RNG +
    teacher-forced replay)."""
    ds = PromptDataset(4, prompt_len=6, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])

    def run(num_pages):
        eng = PagedEngine(cfg, max_batch=4, page_size=4, max_seq_len=32,
                          max_new_tokens=24, temperature=1.0,
                          num_pages=num_pages, eos_token=-1)
        eng.set_params(params)
        reqs = [eng.submit(prompts[i], seed=i) for i in range(4)]
        eng.run()
        eng.release_prefix_cache()
        assert eng.allocator.num_allocated == 0
        return eng, [r.generated for r in reqs]

    tight_eng, tight_out = run(num_pages=10)   # 9 usable pages < 4 seqs
    roomy_eng, roomy_out = run(num_pages=None)  # full-occupancy pool
    assert tight_eng.scheduler.stats.preempted > 0
    assert roomy_eng.scheduler.stats.preempted == 0
    assert tight_out == roomy_out
    for out in tight_out:
        assert len(out) == 24
