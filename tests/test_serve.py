"""Serve subsystem: paged KV allocator, continuous-batching scheduler,
sampling filters, and PagedEngine parity/lifecycle contracts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.profiler import engine_cost_model, fit_tail_factor
from repro.models import init_model
from repro.models.layers import token_logprobs
from repro.serve import Engine, OutOfPages, PagedEngine, PageAllocator
from repro.serve.paging import TRASH_PAGE, pad_block_table
from repro.serve.sampling import sample_token, top_k_logits, top_p_logits
from repro.serve.scheduler import ContinuousScheduler
from repro.train.data import PromptDataset


def dense_cfg():
    return get_config("yi-9b").reduced().replace(
        vocab_size=32, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128)


@pytest.fixture(scope="module")
def cfg():
    return dense_cfg()


@pytest.fixture(scope="module")
def params(cfg):
    return init_model(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------
def test_allocator_never_hands_out_trash_page():
    a = PageAllocator(num_pages=8, page_size=4)
    got = a.allocate(7)
    assert TRASH_PAGE not in got
    assert sorted(got) == list(range(1, 8))


def test_allocator_free_list_reuse_and_exhaustion():
    a = PageAllocator(num_pages=6, page_size=4)
    first = a.allocate(3)
    assert a.num_free == 2
    a.free(first)
    assert a.num_free == 5
    again = a.allocate(5)
    assert set(first) <= set(again)  # freed pages are recycled
    with pytest.raises(OutOfPages):
        a.allocate(1)


def test_allocator_double_free_asserts():
    a = PageAllocator(num_pages=4, page_size=2)
    pages = a.allocate(1)
    a.free(pages)
    with pytest.raises(AssertionError):
        a.free(pages)


def test_pages_needed_is_ceil_div():
    a = PageAllocator(num_pages=4, page_size=8)
    assert a.pages_needed(1) == 1
    assert a.pages_needed(8) == 1
    assert a.pages_needed(9) == 2


def test_pad_block_table_pads_with_trash():
    assert pad_block_table([3, 5], 4) == [3, 5, TRASH_PAGE, TRASH_PAGE]


# ---------------------------------------------------------------------------
# continuous-batching scheduler
# ---------------------------------------------------------------------------
def _sched(max_batch=2, num_pages=9, page_size=4, max_seq=16):
    alloc = PageAllocator(num_pages=num_pages, page_size=page_size)
    return ContinuousScheduler(max_batch=max_batch, allocator=alloc,
                               max_seq_len=max_seq)


def test_scheduler_admits_fifo_up_to_slots():
    s = _sched(max_batch=2)
    r1 = s.submit([1, 2, 3], 4)
    r2 = s.submit([1, 2], 4)
    r3 = s.submit([9], 4)
    joined = s.admit()
    assert [r.rid for r in joined] == [r1.rid, r2.rid]
    assert r3.state == "queued" and s.num_active == 2


def test_scheduler_backfills_freed_slot_and_pages():
    s = _sched(max_batch=1, num_pages=3, page_size=4)
    r1 = s.submit([1, 2, 3], 2)
    r2 = s.submit([4, 5], 2)
    (a,) = s.admit()
    assert a is r1 and s.allocator.num_free == 1
    assert not s.admit()  # no slot free
    s.finish(r1)  # evict: pages back on the free list immediately
    assert s.allocator.num_free == 2 and r1.pages == []
    (b,) = s.admit()
    assert b is r2 and r2.slot == 0  # freed slot reused


def test_scheduler_blocks_admission_on_page_budget():
    # 2 slots but pages for only one prompt at a time
    s = _sched(max_batch=2, num_pages=3, page_size=2, max_seq=8)
    s.submit([1, 2, 3], 2)  # needs ceil(4/2)=2 pages
    s.submit([1, 2, 3], 2)
    joined = s.admit()
    assert len(joined) == 1  # second must wait for pages, not slots


def test_scheduler_ensure_page_grows_block_table():
    s = _sched(max_batch=1, num_pages=9, page_size=2, max_seq=16)
    r = s.submit([1, 2, 3], 8)
    s.admit()
    npages = len(r.pages)
    r.num_cached = npages * 2  # simulate filling every allocated slot
    s.ensure_page_for(r)
    assert len(r.pages) == npages + 1


# ---------------------------------------------------------------------------
# sampling: top-k / top-p
# ---------------------------------------------------------------------------
def test_top_k_keeps_exactly_k():
    logits = jnp.asarray([0.1, 2.0, -1.0, 3.0, 0.5])
    out = top_k_logits(logits, 2)
    kept = np.asarray(out) > -1e29
    assert kept.tolist() == [False, True, False, True, False]


def test_top_k_disabled_for_nonpositive_or_full_k():
    logits = jnp.asarray([0.1, 2.0, -1.0])
    np.testing.assert_array_equal(np.asarray(top_k_logits(logits, 0)),
                                  np.asarray(logits))
    np.testing.assert_array_equal(np.asarray(top_k_logits(logits, 3)),
                                  np.asarray(logits))


def test_top_p_nucleus_mass_property():
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (64,))
    p = 0.7
    out = np.asarray(top_p_logits(logits, p))
    probs = np.asarray(jax.nn.softmax(logits))
    kept = out > -1e29
    # kept set is the smallest prefix of the sorted distribution >= p
    order = np.argsort(-probs)
    cum = np.cumsum(probs[order])
    n_expected = int(np.searchsorted(cum, p)) + 1
    assert kept.sum() == n_expected
    assert probs[kept].sum() >= p - 1e-6


def test_top_p_always_keeps_argmax():
    logits = jnp.asarray([0.0, 5.0, 1.0, -2.0])
    out = np.asarray(top_p_logits(logits, 1e-6))
    assert out[1] > -1e29 and (out[[0, 2, 3]] < -1e29).all()


@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 16), seed=st.integers(0, 50))
def test_sample_token_respects_top_k(k, seed):
    key = jax.random.PRNGKey(seed)
    logits = jax.random.normal(key, (32,)) * 3
    allowed = set(np.argsort(-np.asarray(logits))[:k].tolist())
    tok, _ = sample_token(jax.random.fold_in(key, 1), logits,
                          temperature=1.0, top_k=k)
    assert int(tok) in allowed


def test_sample_token_greedy_and_behaviour_logprob():
    logits = jnp.asarray([0.0, 4.0, 1.0, 2.0])
    tok, lp = sample_token(jax.random.PRNGKey(0), logits, temperature=0.0)
    assert int(tok) == 1
    # behaviour logprob is under the UNFILTERED temp-1 policy
    want = float(token_logprobs(logits[None], jnp.asarray([1]))[0])
    assert lp == pytest.approx(want, abs=1e-6)


def test_sample_token_masks_padded_vocab():
    logits = jnp.asarray([0.0, 1.0, 50.0, 60.0])  # ids 2,3 are padding
    for s in range(8):
        tok, _ = sample_token(jax.random.PRNGKey(s), logits,
                              temperature=1.0, vocab_size=2)
        assert int(tok) < 2


# ---------------------------------------------------------------------------
# PagedEngine vs legacy Engine
# ---------------------------------------------------------------------------
def test_paged_matches_legacy_token_for_token_at_temp0(cfg, params):
    ds = PromptDataset(6, prompt_len=6, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    legacy = Engine(cfg, max_new_tokens=8, temperature=0.0)
    want = legacy.generate(params, jnp.asarray(prompts),
                           key=jax.random.PRNGKey(1))
    # fewer slots than requests -> exercises queueing + backfill
    paged = PagedEngine(cfg, max_batch=4, page_size=4, max_new_tokens=8,
                        temperature=0.0)
    got = paged.generate(params, prompts, key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(want.tokens),
                                  np.asarray(got.tokens))
    np.testing.assert_array_equal(np.asarray(want.lengths),
                                  np.asarray(got.lengths))
    np.testing.assert_allclose(np.asarray(want.logprobs),
                               np.asarray(got.logprobs), atol=1e-4)
    # every page returned to the free list once the batch drained
    assert paged.allocator.num_allocated == 0


@pytest.mark.parametrize("page_size", [2, 4, 16])
def test_paged_engine_parity_across_page_sizes(cfg, params, page_size):
    ds = PromptDataset(4, prompt_len=5, seed=3)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    legacy = Engine(cfg, max_new_tokens=6, temperature=0.0)
    want = np.asarray(legacy.generate(params, jnp.asarray(prompts)).tokens)
    paged = PagedEngine(cfg, max_batch=2, page_size=page_size,
                        max_new_tokens=6, temperature=0.0)
    got = np.asarray(paged.generate(params, prompts).tokens)
    np.testing.assert_array_equal(want, got)


def test_paged_engine_kernel_backed_parity(cfg, params):
    ds = PromptDataset(3, prompt_len=5, seed=2)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    ref_eng = PagedEngine(cfg, max_batch=3, page_size=4, max_new_tokens=5,
                          temperature=0.0)
    kern_eng = PagedEngine(cfg, max_batch=3, page_size=4, max_new_tokens=5,
                           temperature=0.0, use_kernel=True)
    a = ref_eng.generate(params, prompts)
    b = kern_eng.generate(params, prompts)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))
    np.testing.assert_allclose(np.asarray(a.logprobs),
                               np.asarray(b.logprobs), atol=1e-4)


def test_paged_engine_scheduling_invariant_sampling(cfg, params):
    """Per-request RNG: results must not depend on slot count/batching."""
    ds = PromptDataset(5, prompt_len=5, seed=1)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    outs = []
    for max_batch in (2, 5):
        eng = PagedEngine(cfg, max_batch=max_batch, page_size=4,
                          max_new_tokens=6, temperature=1.0)
        outs.append(eng.generate(params, prompts,
                                 key=jax.random.PRNGKey(7)))
    np.testing.assert_array_equal(np.asarray(outs[0].tokens),
                                  np.asarray(outs[1].tokens))
    np.testing.assert_allclose(np.asarray(outs[0].logprobs),
                               np.asarray(outs[1].logprobs), atol=1e-5)


def test_paged_engine_logprobs_match_prefill_recompute(cfg, params):
    """Same contract the legacy engine honours: behaviour logprobs from
    generation equal the inference worker's recompute."""
    from repro.train import make_prefill_step

    ds = PromptDataset(4, prompt_len=6, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    eng = PagedEngine(cfg, max_batch=4, page_size=4, max_new_tokens=6,
                      temperature=1.0)
    res = eng.generate(params, prompts, key=jax.random.PRNGKey(5))
    pf = jax.jit(make_prefill_step(cfg))
    recomputed = pf(params, {"tokens": jnp.asarray(res.tokens)})
    S = prompts.shape[1]
    gen_lp = np.asarray(res.logprobs)[:, S:]
    rec_lp = np.asarray(recomputed)[:, S:]
    mask = np.asarray(res.tokens)[:, S:] != 0
    np.testing.assert_allclose(gen_lp[mask], rec_lp[mask], atol=2e-3)


def test_paged_engine_ragged_lengths_and_page_recycling(cfg, params):
    """Skewed per-request budgets: short requests leave early, pages are
    recycled, and the engine takes far fewer slot-steps than static
    padding would."""
    eng = PagedEngine(cfg, max_batch=4, page_size=4, max_new_tokens=32,
                      temperature=0.0, max_seq_len=4 + 32, num_pages=4 * 9 + 1,
                      eos_token=-1)  # never sampled: budget-driven stop
    ds = PromptDataset(8, prompt_len=4, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    budgets = [2, 2, 2, 2, 2, 2, 2, 24]
    reqs = [eng.submit(prompts[i], max_new_tokens=budgets[i], seed=i)
            for i in range(8)]
    eng.set_params(params)
    done = eng.run()
    assert len(done) == 8
    for r, b in zip(reqs, budgets):
        assert len(r.generated) == b
    assert eng.allocator.num_allocated == 0
    # static padding would cost 8 requests x (4 + 24) slot-steps in two
    # full batches; continuous batching re-forms the batch every step
    static_steps = 2 * (4 + 24)
    assert eng.decode_steps < static_steps


# ---------------------------------------------------------------------------
# in-flight weight sync
# ---------------------------------------------------------------------------
def test_paged_engine_inflight_weight_update_version_tags(cfg, params):
    params_v1 = jax.tree_util.tree_map(lambda x: x * 1.05, params)
    eng = PagedEngine(cfg, max_batch=2, page_size=4, max_new_tokens=6,
                      temperature=0.0, eos_token=-1)
    ds = PromptDataset(4, prompt_len=5, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    eng.set_params(params, version=0)
    reqs = [eng.submit(prompts[i], seed=i) for i in range(4)]
    # run a few steps under v0, then swap in flight
    for _ in range(3):
        eng.step()
    eng.update_weights(params_v1, version=1)
    eng.run()
    assert eng.weight_version == 1 and eng.weight_swaps == 1
    # requests admitted before the swap keep their admission tag (what
    # the staleness correction references) but record the newer weights
    early = [r for r in reqs if r.weight_version == 0]
    late = [r for r in reqs if r.weight_version == 1]
    assert early and late  # 2 slots x 4 requests straddle the swap
    assert all(r.last_weight_version == 1 for r in late)
    assert all(r.last_weight_version >= r.weight_version for r in reqs)


def test_paged_engine_requests_after_swap_match_new_params(cfg, params):
    """A request admitted after an in-flight swap must generate exactly
    what a fresh engine holding only the new weights generates."""
    params_v1 = jax.tree_util.tree_map(lambda x: x * 1.1, params)
    ds = PromptDataset(2, prompt_len=5, seed=4)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])

    eng = PagedEngine(cfg, max_batch=1, page_size=4, max_new_tokens=5,
                      temperature=0.0)
    eng.set_params(params, version=0)
    first = eng.submit(prompts[0], seed=0)
    eng.update_weights(params_v1, version=1)  # lands before any step
    second = eng.submit(prompts[1], seed=1)
    eng.run()
    assert first.weight_version == 1 and second.weight_version == 1

    fresh = PagedEngine(cfg, max_batch=1, page_size=4, max_new_tokens=5,
                        temperature=0.0)
    fresh.set_params(params_v1, version=1)
    ref2 = fresh.submit(prompts[1], seed=1)
    fresh.run()
    assert second.generated == ref2.generated


def test_rollout_worker_paged_engine_roundtrip(cfg, params):
    from repro.rl.workers import RolloutWorker

    w = RolloutWorker("rollout/t", cfg=cfg, max_new_tokens=4,
                      temperature=1.0, engine="paged", max_batch=4,
                      page_size=4)
    assert isinstance(w.engine, PagedEngine)
    w.update_weights(params, version=3)
    ds = PromptDataset(4, prompt_len=5, seed=0)
    out = w.generate({"prompt_tokens": np.asarray(
        ds.next_batch()["prompt_tokens"])})
    assert out["tokens"].shape[1] == 5 + 4
    assert (out["weight_versions"] == 3).all()
    recs = w.request_records()
    assert len(recs) == 4 and all(t >= 0 for _, t in recs)
    w.shutdown()


# ---------------------------------------------------------------------------
# profiler: measured tail factor
# ---------------------------------------------------------------------------
def test_fit_tail_factor_known_values():
    assert fit_tail_factor([1.0, 1.0, 1.0, 1.0]) == pytest.approx(1.0)
    assert fit_tail_factor([1.0, 1.0, 6.0]) == pytest.approx(6.0 / (8 / 3))
    assert fit_tail_factor([]) == 1.0


def test_engine_cost_model_fits_measured_tail(cfg, params):
    eng = PagedEngine(cfg, max_batch=4, page_size=4, max_new_tokens=16,
                      temperature=0.0, eos_token=-1)
    ds = PromptDataset(4, prompt_len=4, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])
    eng.set_params(params)
    # warm-up request so the jitted step compiles outside the measurement
    eng.submit(prompts[0], max_new_tokens=1, seed=99)
    eng.run()
    eng.pop_request_records()
    for i, budget in enumerate([2, 2, 2, 12]):
        eng.submit(prompts[i], max_new_tokens=budget, seed=i)
    eng.run()
    recs = eng.pop_request_records()
    cm = engine_cost_model("rollout", recs)
    # the skewed budgets must surface as a measured long tail
    assert cm.tail_factor > 1.2
    assert cm.slope_time >= 0.0
    # the log is consumed
    assert eng.pop_request_records() == []


def test_paged_engine_preempts_on_page_exhaustion(cfg, params):
    """A pool too small for the whole batch must trigger recompute
    preemption (youngest request yields), not crash — and the output must
    be identical to an uncontended run (deterministic per-request RNG +
    teacher-forced replay)."""
    ds = PromptDataset(4, prompt_len=6, seed=0)
    prompts = np.asarray(ds.next_batch()["prompt_tokens"])

    def run(num_pages):
        eng = PagedEngine(cfg, max_batch=4, page_size=4, max_seq_len=32,
                          max_new_tokens=24, temperature=1.0,
                          num_pages=num_pages, eos_token=-1)
        eng.set_params(params)
        reqs = [eng.submit(prompts[i], seed=i) for i in range(4)]
        eng.run()
        assert eng.allocator.num_allocated == 0
        return eng, [r.generated for r in reqs]

    tight_eng, tight_out = run(num_pages=10)   # 9 usable pages < 4 seqs
    roomy_eng, roomy_out = run(num_pages=None)  # full-occupancy pool
    assert tight_eng.scheduler.stats.preempted > 0
    assert roomy_eng.scheduler.stats.preempted == 0
    assert tight_out == roomy_out
    for out in tight_out:
        assert len(out) == 24
