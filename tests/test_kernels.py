"""Per-kernel shape/dtype sweeps asserting allclose against ref.py oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,S,D", [
    (1, 2, 1, 128, 32),
    (2, 4, 2, 256, 64),
    (1, 8, 8, 128, 128),  # MHA
    (2, 6, 2, 384, 64),   # 3-way GQA groups
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 100), (False, 0)])
def test_flash_attention_sweep(B, H, KV, S, D, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=128, block_k=128)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal,
        window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@settings(max_examples=8, deadline=None)
@given(
    bq=st.sampled_from([32, 64, 128]),
    bk=st.sampled_from([32, 64, 128]),
    s_mult=st.integers(2, 4),
)
def test_flash_attention_block_shape_property(bq, bk, s_mult):
    """Output must be independent of the BlockSpec tiling choice."""
    S = 128 * s_mult
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, S, 2, 32))
    k = jax.random.normal(ks[1], (1, S, 2, 32))
    v = jax.random.normal(ks[2], (1, S, 2, 32))
    a = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    b = ops.flash_attention(q, k, v, block_q=128, block_k=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,P,N,L,chunk", [
    (1, 2, 16, 8, 64, 16),
    (2, 4, 32, 16, 128, 32),
    (1, 1, 64, 64, 256, 64),
])
def test_ssd_scan_sweep(B, H, P, N, L, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, L, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, L, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, L, N)) * 0.5).astype(dtype)
    D = jnp.ones((H,), jnp.float32)
    got = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk)
    nc = L // chunk
    want = ref.ssd_scan_ref(
        x.reshape(B, nc, chunk, H, P).transpose(0, 3, 1, 2, 4),
        dt.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2),
        jnp.broadcast_to(A, (B, H)),
        Bm.reshape(B, nc, chunk, N), Cm.reshape(B, nc, chunk, N),
        jnp.broadcast_to(D, (B, H)))
    want = want.transpose(0, 2, 3, 1, 4).reshape(B, L, H, P)
    tol = 2e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# single-token SSD state update (state-cache decode path)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,P,N", [
    (1, 2, 16, 8),
    (3, 4, 32, 16),
    (2, 24, 64, 128),  # mamba2-370m head geometry
])
def test_ssm_state_update_sweep(B, H, P, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 6)
    state = jax.random.normal(ks[0], (B, H, P, N), jnp.float32)
    x = jax.random.normal(ks[1], (B, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[2], (B, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[3], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[4], (B, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[5], (B, N)) * 0.5).astype(dtype)
    D = jnp.ones((H,), jnp.float32)
    got_y, got_s = ops.ssm_state_update(state, x, dt, A, Bm, Cm, D)
    want_y, want_s = ref.ssm_state_update_ref(state, x, dt, A, Bm, Cm, D)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# grouped matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("E,C,D,F,bc,bd,bf", [
    (2, 64, 128, 64, 64, 64, 64),
    (4, 128, 256, 128, 64, 128, 64),
    (8, 256, 128, 512, 128, 128, 128),
])
def test_grouped_matmul_sweep(E, C, D, F, bc, bd, bf, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    buf = jax.random.normal(ks[0], (E, C, D), dtype)
    w = (jax.random.normal(ks[1], (E, D, F)) * 0.05).astype(dtype)
    got = ops.grouped_matmul(buf, w, block_c=bc, block_d=bd, block_f=bf)
    want = ref.grouped_matmul_ref(buf, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype] * 10, rtol=TOL[dtype] * 10)


@settings(max_examples=6, deadline=None)
@given(e=st.integers(1, 6), scale=st.floats(0.01, 2.0))
def test_grouped_matmul_linearity_property(e, scale):
    """gmm(a·buf, w) == a · gmm(buf, w) — catches accumulator bugs."""
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    buf = jax.random.normal(ks[0], (e, 64, 128))
    w = jax.random.normal(ks[1], (e, 128, 64)) * 0.1
    a = ops.grouped_matmul(buf * scale, w)
    b = ops.grouped_matmul(buf, w) * scale
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# expert-parallel exact MoE decode (gather + grouped GEMMs + combine)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,E,k,d,f", [
    (1, 4, 2, 64, 32),
    (7, 8, 2, 128, 64),
    (160, 4, 2, 128, 128),  # T > 128: capacity rounds up to 256
])
def test_moe_decode_sweep(T, E, k, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (T, d), dtype)
    gate_w = (jax.random.normal(ks[1], (E, d, f)) * 0.05).astype(dtype)
    up_w = (jax.random.normal(ks[2], (E, d, f)) * 0.05).astype(dtype)
    down_w = (jax.random.normal(ks[3], (E, f, d)) * 0.05).astype(dtype)
    rng = np.random.default_rng(0)
    idx = jnp.asarray(np.stack([rng.permutation(E)[:k]
                                for _ in range(T)]).astype(np.int32))
    gv = jnp.asarray(rng.dirichlet(np.ones(k), size=T).astype(np.float32))
    got = ops.moe_decode(x, idx, gv, gate_w, up_w, down_w)
    want = ref.moe_decode_ref(x, idx, gv, gate_w, up_w, down_w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype] * 10, rtol=TOL[dtype] * 10)


def test_moe_decode_is_capacity_free():
    """Every token's full top-k contributes even when all tokens pick
    the same expert — the drop regime capacity dispatch cannot serve."""
    T, E, k, d, f = 9, 4, 2, 64, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    x = jax.random.normal(ks[0], (T, d))
    gate_w = jax.random.normal(ks[1], (E, d, f)) * 0.05
    up_w = jax.random.normal(ks[2], (E, d, f)) * 0.05
    down_w = jax.random.normal(ks[3], (E, f, d)) * 0.05
    # adversarial skew: every token routes to experts {0, 1}
    idx = jnp.tile(jnp.asarray([[0, 1]], jnp.int32), (T, 1))
    gv = jnp.tile(jnp.asarray([[0.7, 0.3]], jnp.float32), (T, 1))
    got = ops.moe_decode(x, idx, gv, gate_w, up_w, down_w)
    want = ref.moe_decode_ref(x, idx, gv, gate_w, up_w, down_w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# paged attention (decode over block tables)
# ---------------------------------------------------------------------------
def _paged_inputs(key, B, H, KV, D, P, page, nb, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k_pages = jax.random.normal(ks[1], (P, page, KV, D), dtype)
    v_pages = jax.random.normal(ks[2], (P, page, KV, D), dtype)
    # distinct non-trash pages per request (page 0 is the trash page)
    rng = np.random.default_rng(int(jax.random.randint(ks[0], (), 0, 1 << 30)))
    tables = np.stack([rng.permutation(np.arange(1, P))[:nb]
                       for _ in range(B)]).astype(np.int32)
    return q, k_pages, v_pages, jnp.asarray(tables)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,D,page,nb", [
    (1, 2, 1, 32, 8, 2),
    (3, 4, 2, 16, 8, 4),    # GQA groups of 2
    (2, 8, 8, 64, 16, 3),   # MHA
    (4, 6, 2, 32, 4, 5),    # 3-way GQA groups
])
def test_paged_attention_sweep(B, H, KV, D, page, nb, dtype):
    P = nb * B + 1
    q, kp, vp, tables = _paged_inputs(
        jax.random.PRNGKey(0), B, H, KV, D, P, page, nb, dtype)
    # ragged context lengths incl. partial pages and a single-token ctx
    lens = jnp.asarray(
        [1 + (i * 7) % (nb * page) for i in range(B)], jnp.int32)
    got = ops.paged_attention(q, kp, vp, tables, lens)
    want = ref.paged_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@settings(max_examples=8, deadline=None)
@given(
    page=st.sampled_from([2, 4, 8, 16]),
    ctx=st.integers(1, 31),
    seed=st.integers(0, 100),
)
def test_paged_attention_block_size_property(page, ctx, seed):
    """Output must be independent of the page-size tiling choice."""
    B, H, KV, D = 2, 4, 2, 16
    total = 32
    nb = total // page
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    # one logically-contiguous KV stream laid out under two page sizes
    kflat = jax.random.normal(ks[1], (B, total, KV, D))
    vflat = jax.random.normal(ks[2], (B, total, KV, D))
    lens = jnp.asarray([ctx, total - ctx + 1], jnp.int32)

    def run(page_size):
        nb_ = total // page_size
        P = B * nb_ + 1
        kp = jnp.zeros((P, page_size, KV, D))
        vp = jnp.zeros((P, page_size, KV, D))
        tables = np.zeros((B, nb_), np.int32)
        pid = 1
        for b in range(B):
            for j in range(nb_):
                kp = kp.at[pid].set(
                    kflat[b, j * page_size:(j + 1) * page_size])
                vp = vp.at[pid].set(
                    vflat[b, j * page_size:(j + 1) * page_size])
                tables[b, j] = pid
                pid += 1
        return np.asarray(ops.paged_attention(
            q, kp, vp, jnp.asarray(tables), lens))

    np.testing.assert_allclose(run(page), run(total), atol=1e-5, rtol=1e-5)


def test_paged_attention_ignores_trash_page_contents():
    """Positions past the context length (incl. trash-padded table rows)
    must not influence the output."""
    B, H, KV, D, page, nb = 2, 4, 2, 16, 4, 4
    P = 16
    q, kp, vp, tables = _paged_inputs(
        jax.random.PRNGKey(3), B, H, KV, D, P, page, nb)
    lens = jnp.asarray([3, 9], jnp.int32)
    base = np.asarray(ops.paged_attention(q, kp, vp, tables, lens))
    # poison the trash page and every slot past the context length
    kp2 = kp.at[0].set(1e3)
    vp2 = vp.at[0].set(1e3)
    got = np.asarray(ops.paged_attention(q, kp2, vp2, tables, lens))
    np.testing.assert_allclose(base, got, atol=1e-6)


def test_paged_attention_empty_context_returns_zeros():
    """context_len == 0 (inactive slot) must yield zeros, not a softmax
    over the masked scores (i.e. the mean of the trash pages)."""
    B, H, KV, D, page, nb = 2, 4, 2, 16, 4, 2
    q, kp, vp, tables = _paged_inputs(
        jax.random.PRNGKey(4), B, H, KV, D, 16, page, nb)
    vp = vp.at[:].set(7.0)  # make averaging-garbage obvious
    lens = jnp.asarray([0, 5], jnp.int32)
    got = np.asarray(ops.paged_attention(q, kp, vp, tables, lens))
    want = np.asarray(ref.paged_attention_ref(q, kp, vp, tables, lens))
    np.testing.assert_allclose(got[0], 0.0, atol=1e-6)
    np.testing.assert_allclose(got, want, atol=1e-5)


# ---------------------------------------------------------------------------
# fused sampling (temperature -> top-k -> top-p -> Gumbel-max)
# ---------------------------------------------------------------------------
def _sampling_inputs(seed, B, V):
    kl, kk = jax.random.split(jax.random.PRNGKey(seed))
    logits = 4.0 * jax.random.normal(kl, (B, V), jnp.float32)
    keys = jax.vmap(lambda i: jax.random.fold_in(kk, i))(jnp.arange(B))
    gumbel = jax.vmap(
        lambda k: jax.random.gumbel(k, (V,), jnp.float32))(keys)
    return logits, gumbel, keys


@pytest.mark.parametrize("B,V", [(1, 64), (4, 128), (3, 250)])
@pytest.mark.parametrize("temperature,top_k,top_p,vocab_size", [
    (0.0, 0, 1.0, 0),     # greedy
    (1.0, 0, 1.0, 0),     # plain categorical
    (0.7, 5, 1.0, 0),     # top-k only
    (1.0, 0, 0.9, 0),     # nucleus only
    (0.8, 12, 0.7, 40),   # all filters + padded vocab mask
    (1.3, 0, 0.95, 40),
])
def test_fused_sample_sweep(B, V, temperature, top_k, top_p, vocab_size):
    """Token draws are bit-exact vs the oracle (same Gumbel noise in,
    same filters, same argmax tie-breaking); logprobs allclose."""
    logits, gumbel, _ = _sampling_inputs(B * 7 + V, B, V)
    tok, lp = ops.fused_sample(
        logits, gumbel, temperature=temperature, top_k=top_k,
        top_p=top_p, vocab_size=vocab_size)
    want_tok, want_lp = ref.fused_sample_ref(
        logits, gumbel, temperature=temperature, top_k=top_k,
        top_p=top_p, vocab_size=vocab_size)
    np.testing.assert_array_equal(np.asarray(tok), np.asarray(want_tok))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(want_lp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("temperature,top_k,top_p", [
    (0.0, 0, 1.0),
    (1.0, 0, 1.0),
    (0.7, 8, 1.0),
    (1.0, 0, 0.85),
    (0.9, 6, 0.8),
])
def test_fused_sample_matches_unfused_serving_path(temperature, top_k,
                                                   top_p):
    """Draw-for-draw parity with the engine's unfused sample_token under
    the same per-request PRNG keys (jax.random.categorical IS Gumbel-max,
    so feeding the kernel Gumbel noise from the same keys must reproduce
    every draw)."""
    import functools

    from repro.serve.sampling import sample_token, sample_tokens_fused

    B, V = 5, 96
    logits, _, keys = _sampling_inputs(11, B, V)
    want_tok, want_lp = jax.vmap(functools.partial(
        sample_token, temperature=temperature, top_k=top_k, top_p=top_p,
        vocab_size=77))(keys, logits)
    got_tok, got_lp = sample_tokens_fused(
        keys, logits, temperature=temperature, top_k=top_k, top_p=top_p,
        vocab_size=77)
    np.testing.assert_array_equal(np.asarray(got_tok),
                                  np.asarray(want_tok))
    np.testing.assert_allclose(np.asarray(got_lp), np.asarray(want_lp),
                               atol=2e-5, rtol=2e-5)


def test_fused_sample_greedy_ties_break_like_argmax():
    logits = (jnp.zeros((2, 64), jnp.float32)
              .at[0, 7].set(3.0).at[0, 20].set(3.0)  # tie: first wins
              .at[1, 0].set(1.0))
    gumbel = jnp.zeros_like(logits)
    tok, lp = ops.fused_sample(logits, gumbel, temperature=0.0)
    assert np.asarray(tok).tolist() == [7, 0]
    want = np.asarray(jax.nn.log_softmax(logits)[jnp.arange(2), tok])
    np.testing.assert_allclose(np.asarray(lp), want, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2 ** 16),
    temperature=st.sampled_from([0.0, 0.5, 1.0, 1.7]),
    top_k=st.integers(0, 16),
    top_p=st.sampled_from([0.6, 0.8, 0.95, 1.0]),
)
def test_fused_sample_filter_property(seed, temperature, top_k, top_p):
    """Any filter combination: the fused draw equals the oracle draw."""
    B, V = 2, 80
    logits, gumbel, _ = _sampling_inputs(seed, B, V)
    got = ops.fused_sample(logits, gumbel, temperature=temperature,
                           top_k=top_k, top_p=top_p)
    want = ref.fused_sample_ref(logits, gumbel, temperature=temperature,
                                top_k=top_k, top_p=top_p)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               atol=2e-5, rtol=2e-5)
