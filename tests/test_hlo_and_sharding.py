"""HLO analyzer exactness + sharding rules (multi-device parts run in a
subprocess so the main test process keeps 1 CPU device)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.utils.hlo_analysis import _shape_bytes, analyze, parse_hlo
from repro.utils.treeutil import map_with_path


def test_analyzer_counts_scanned_dot_flops_exactly():
    L, M_, K_, N = 4, 8, 32, 16

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    ws = jnp.ones((L, K_, N), jnp.float32)[:, :K_, :]
    x = jnp.ones((M_, K_), jnp.float32)
    # K must match across scan: use square weights
    ws = jnp.ones((L, K_, K_), jnp.float32)
    compiled = jax.jit(f).lower(ws, x).compile()
    st = analyze(compiled.as_text())
    assert st.flops == pytest.approx(2 * L * M_ * K_ * K_, rel=0.01)
    assert st.unknown_trip_loops == 0


def test_shape_bytes_tuple_with_index_comments():
    s = "(s32[], bf16[16,64]{1,0}, /*index=2*/f32[4,128]{1,0})"
    assert _shape_bytes(s) == 4 + 16 * 64 * 2 + 4 * 128 * 4


def test_map_with_path_namedtuple():
    from repro.models.attention import KVCache
    kv = KVCache(k=jnp.zeros((2, 2)), v=jnp.zeros((2, 2)),
                 positions=jnp.zeros((2,), jnp.int32))
    paths = []
    map_with_path(lambda p, x: paths.append(p), {"kv": kv})
    assert "/kv/k" in paths and "/kv/positions" in paths


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.launch.mesh import make_local_mesh
    from repro.models import model as M
    from repro.train.sharding_rules import (
        param_specs, decode_state_specs, batch_spec)

    mesh = jax.make_mesh((2, 8), ("data", "model"))
    cfg = get_config("yi-9b")
    sds = jax.eval_shape(lambda: M.init_model(
        jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    specs = param_specs(mesh, cfg, sds)
    # wq (L, d, H, hd): d on data, H(32 % 8 == 0) on model
    wq = specs["layers"]["attn"]["wq"]
    assert wq == P(None, "data", "model", None), wq
    # wk kv=4 not divisible by 8 -> head axis dropped
    wk = specs["layers"]["attn"]["wk"]
    assert wk == P(None, "data", None, None), wk
    # embed padded vocab divisible
    assert specs["embed"]["tokens"] == P("model", "data")
    # decode cache: kv heads=4 not divisible -> W seq-sharded on model
    st = jax.eval_shape(lambda: M.init_decode_state(cfg, 16, 4096,
                                                    jnp.bfloat16))
    dspecs = decode_state_specs(mesh, cfg, st)
    assert dspecs.kv.k == P(None, "data", "model", None, None), dspecs.kv.k
    assert dspecs.kv.positions == P(None, "data", "model")
    # batch of 1 -> replicated
    assert batch_spec(mesh, 1) == P(None)
    print("SUBPROC_OK")
""")


def test_sharding_rules_on_16_devices():
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}, cwd="/root/repo")
    assert "SUBPROC_OK" in out.stdout, out.stdout + out.stderr
