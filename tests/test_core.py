"""M2Flow core: channels, device lock, workers, flowgraph, pipeline."""
import threading
import time

import numpy as np
import pytest

from repro.comm.primitives import Router, reset_router
from repro.core import (
    Channel,
    ChannelClosed,
    Cluster,
    DeviceLock,
    FlowGraph,
    GraphTracer,
    Worker,
    WorkerFailure,
    WorkerGroup,
)
from repro.core.pipeline import coalesce, split_batch


@pytest.fixture(autouse=True)
def fresh_state():
    reset_router()
    Channel.reset_all()
    yield
    reset_router()
    Channel.reset_all()


# ---------------------------------------------------------------------------
# Channel
# ---------------------------------------------------------------------------
def test_channel_fifo_and_close():
    ch = Channel.create("c1")
    for i in range(5):
        ch.put(i)
    assert [ch.get() for _ in range(5)] == [0, 1, 2, 3, 4]
    ch.close()
    with pytest.raises(ChannelClosed):
        ch.get()


def test_channel_weighted_load_balancing():
    ch = Channel.create("c2")
    for i, w in enumerate([5.0, 1.0, 1.0, 5.0]):
        ch.put(i, weight=w)
    ch.get(consumer="a")  # weight 5 -> a
    ch.get(consumer="b")  # weight 1 -> b
    assert ch.balanced_consumer() == "b"


def test_channel_custom_policy():
    ch = Channel.create("c3")
    for i in (3, 1, 2):
        ch.put(i)
    # policy: always pick the smallest item
    got = ch.get(policy=lambda items: int(np.argmin(items)))
    assert got == 1


def test_channel_get_batch_coalesces():
    ch = Channel.create("c4")
    for i in range(6):
        ch.put(i)
    assert ch.get_batch(min_items=4) == [0, 1, 2, 3]


def test_channel_producer_consumer_threads():
    ch = Channel.create("c5", capacity=2)
    out = []

    def produce():
        for i in range(20):
            ch.put(i)
        ch.close()

    def consume():
        while True:
            try:
                out.append(ch.get())
            except ChannelClosed:
                return

    tp, tc = threading.Thread(target=produce), threading.Thread(target=consume)
    tp.start(); tc.start(); tp.join(); tc.join()
    assert out == list(range(20))


# ---------------------------------------------------------------------------
# Device lock (context switching)
# ---------------------------------------------------------------------------
def test_device_lock_priority_order():
    """Consumers (higher rank) must not grab the lock while a producer
    (lower rank) is waiting — the dependency-ordered acquisition."""
    lock = DeviceLock("L")
    lock.set_priority("producer", 0, devices=(0, 1))
    lock.set_priority("consumer", 1, devices=(0, 1))
    order = []

    lock.acquire("consumer")  # consumer grabs first (nothing else waiting)
    done = threading.Event()

    def producer():
        lock.acquire("producer")
        order.append("producer")
        lock.release("producer")
        done.set()

    def late_consumer():
        time.sleep(0.05)  # ensure producer is already waiting
        lock.acquire("consumer")
        order.append("consumer2")
        lock.release("consumer")

    t1 = threading.Thread(target=producer)
    t2 = threading.Thread(target=late_consumer)
    t1.start(); t2.start()
    time.sleep(0.05)
    lock.release("consumer")  # now both wait; producer has lower rank
    t1.join(); t2.join()
    assert order == ["producer", "consumer2"]


def test_device_lock_onload_offload_hooks_and_placement_skip():
    lock = DeviceLock("L")
    lock.set_priority("a", 0, devices=(0,))
    lock.set_priority("b", 1, devices=(0,))   # shares device 0 with a
    lock.set_priority("c", 2, devices=(5,))   # disjoint devices
    calls = []
    lock.acquire("a", onload=lambda: calls.append("on-a"))
    lock.release("a", offload=lambda: calls.append("off-a"))
    lock.acquire("b", onload=lambda: calls.append("on-b"))
    lock.release("b", offload=lambda: calls.append("off-b"),
                 next_shares_devices=False)
    # c on different devices: acquiring after b must NOT trigger onload
    lock.acquire("c", onload=lambda: calls.append("on-c"))
    lock.release("c")
    assert "on-b" in calls and "off-a" in calls
    assert "on-c" not in calls  # disjoint placement skips the switch


# ---------------------------------------------------------------------------
# Worker / WorkerGroup
# ---------------------------------------------------------------------------
class EchoWorker(Worker):
    def work(self, x):
        return {"v": x["v"] * 2, "who": self.name}

    def boom(self, x):
        raise ValueError("kaput")


def test_worker_group_dispatch_and_timing():
    cluster = Cluster(num_nodes=1, devices_per_node=4)
    wg = WorkerGroup.launch(EchoWorker, cluster, count=3)
    h = wg.work({"v": np.ones(2)})
    out = h.wait()
    assert len(out) == 3
    assert all((o["v"] == 2).all() for o in out)
    assert h.timing("max") >= 0.0
    wg.shutdown()


def test_worker_failure_handler_fires():
    cluster = Cluster()
    wg = WorkerGroup.launch(EchoWorker, cluster, count=1)
    failures = []
    wg.on_failure(failures.append)
    h = wg.boom({"v": 1})
    with pytest.raises(WorkerFailure):
        h.wait()
    assert failures and failures[0].worker == "EchoWorker/0"
    wg.shutdown()


def test_worker_offload_onload_roundtrip():
    import jax.numpy as jnp
    w = Worker("w/0", devices=(0,))
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(4)}
    w.register_state("params", tree)
    before = w.state_bytes()
    w.offload()
    assert w.offloaded
    w.onload()
    got = w.get_state("params")
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.arange(6.0).reshape(2, 3))
    assert w.state_bytes() == before
    w.shutdown()


def test_router_send_recv_and_stats():
    r = Router()
    r.register("a", devices=[0])
    r.register("b", devices=[1])
    r.send("a", "b", {"x": np.ones(3)})
    got = r.recv("b", "a")
    np.testing.assert_array_equal(got["x"], np.ones(3))
    st = r.stats()
    assert st["a->b"]["messages"] == 1 and st["a->b"]["bytes"] >= 24


# ---------------------------------------------------------------------------
# FlowGraph
# ---------------------------------------------------------------------------
def test_trace_to_graph():
    tr = GraphTracer()
    tr.record("put", "rollout", "ch1", 0.0, nbytes=100)
    tr.record("get", "inference", "ch1", 0.1)
    tr.record("put", "inference", "ch2", 0.2, nbytes=50)
    tr.record("get", "train", "ch2", 0.3)
    g = tr.graph()
    assert set(g.edges()) == {("rollout", "inference"),
                              ("inference", "train")}


def test_condense_collapses_cycles():
    g = FlowGraph()
    for n in ("sim", "gen", "train"):
        g.add_worker(n)
    g.add_edge("sim", "gen")
    g.add_edge("gen", "sim")
    g.add_edge("gen", "train")
    dag, members = g.condense()
    assert len(dag.nodes) == 2
    cyc = [n for n in dag.nodes if n.startswith("cycle")][0]
    assert set(members[cyc]) == {"gen", "sim"}


def test_st_cuts_are_downsets():
    g = FlowGraph()
    for n in "abcd":
        g.add_worker(n)
    g.add_edge("a", "b")
    g.add_edge("b", "c")
    g.add_edge("b", "d")
    cuts = list(g.st_cuts())
    assert cuts
    for s, t in cuts:
        # no edge from t to s
        for (u, v) in g.edges():
            assert not (u in t and v in s), (s, t, u, v)
    # chain prefix {a}, {a,b}, and {a,b,c}/{a,b,d} must all appear
    ss = {tuple(sorted(s)) for s, _ in cuts}
    assert ("a",) in ss and ("a", "b") in ss
    assert ("a", "b", "c") in ss and ("a", "b", "d") in ss


# ---------------------------------------------------------------------------
# split/coalesce (elastic pipelining granularity)
# ---------------------------------------------------------------------------
def test_split_coalesce_roundtrip():
    batch = {"x": np.arange(24).reshape(12, 2), "y": np.ones(12)}
    chunks = split_batch(batch, 4)
    assert len(chunks) == 3
    back = coalesce(chunks)
    np.testing.assert_array_equal(back["x"], batch["x"])
    np.testing.assert_array_equal(back["y"], batch["y"])


def test_coalesce_sums_scalar_counters():
    """Regression: integral counters (e.g. SimulatorWorker's `successes`)
    used to keep only the LAST chunk's value — undercounted under any
    pipelined plan.  Integer scalars must sum; float statistics (means,
    ratios, losses) and dicts/metrics keep last-chunk semantics."""
    chunks = [
        {"x": np.ones((2, 3)), "successes": 3, "rate": 0.25,
         "count0d": np.int64(2), "metrics": {"loss": 1.0}, "tag": "a",
         "flag": True},
        {"x": np.zeros((2, 3)), "successes": 4, "rate": 0.5,
         "count0d": np.int64(5), "metrics": {"loss": 2.0}, "tag": "b",
         "flag": False},
    ]
    out = coalesce(chunks)
    assert out["successes"] == 7          # int counter: summed
    assert out["count0d"] == 7            # 0-d integer array: summed
    assert out["rate"] == 0.5             # float statistic: NOT summed
    assert out["metrics"] == {"loss": 2.0}  # dict: keep last
    assert out["tag"] == "b"              # string: keep last
    assert out["flag"] is False           # bool is not a counter
    assert out["x"].shape == (4, 3)


def test_coalesce_single_chunk_passthrough():
    out = coalesce([{"successes": 5, "m": {"a": 1}}])
    assert out["successes"] == 5 and out["m"] == {"a": 1}


# ---------------------------------------------------------------------------
# Cluster: exclusive allocation (regression — the flag must persist)
# ---------------------------------------------------------------------------
def test_exclusive_allocation_blocks_later_nonexclusive_overlap():
    c = Cluster(num_nodes=1, devices_per_node=4)
    c.allocate("trainer", 2, device_ids=[0, 1], exclusive=True)
    # regression: a later NON-exclusive pin on an exclusively-held device
    # must be rejected (previously the exclusive flag was never recorded)
    with pytest.raises(ValueError, match="exclusively held"):
        c.allocate("rollout", 1, device_ids=[1])


def test_exclusive_allocation_rejects_occupied_devices():
    c = Cluster(num_nodes=1, devices_per_node=4)
    c.allocate("rollout", 2, device_ids=[0, 1])  # non-exclusive
    with pytest.raises(ValueError, match="occupied"):
        c.allocate("trainer", 1, device_ids=[0], exclusive=True)


def test_auto_allocation_skips_exclusive_devices():
    c = Cluster(num_nodes=1, devices_per_node=4)
    c.allocate("trainer", 2, exclusive=True)  # takes 0, 1
    ids = c.allocate("rollout", 2)  # auto: must avoid 0 and 1
    assert set(ids) == {2, 3}
    # exhaustion: a further exclusive request cannot be satisfied
    with pytest.raises(ValueError, match="cannot allocate"):
        c.allocate("infer", 1, exclusive=True)


def test_free_releases_exclusivity():
    c = Cluster(num_nodes=1, devices_per_node=2)
    c.allocate("trainer", 1, device_ids=[0], exclusive=True)
    c.free("trainer")
    ids = c.allocate("rollout", 1, device_ids=[0])  # now legal again
    assert ids == [0]


def test_nonexclusive_overlap_still_allowed():
    """Temporal multiplexing (two workers on one device) must survive."""
    c = Cluster(num_nodes=1, devices_per_node=2)
    c.allocate("a", 1, device_ids=[0])
    c.allocate("b", 1, device_ids=[0])
    assert c.collocated("a", "b")


# ---------------------------------------------------------------------------
# Router.broadcast: pack once, share leaves, account per destination
# ---------------------------------------------------------------------------
def test_broadcast_shares_leaves_and_counts_bytes_per_destination():
    r = Router()
    for name in ("src", "d1", "d2", "d3"):
        r.register(name, devices=[0])
    payload = {"w": np.arange(6, dtype=np.float32)}
    r.broadcast("src", ["d1", "d2", "d3"], payload)
    got = [r.recv(d, "src") for d in ("d1", "d2", "d3")]
    for g in got:
        np.testing.assert_array_equal(g["w"], payload["w"])
    # zero-copy fan-out: every destination sees the SAME leaf buffer
    assert got[0]["w"] is got[1]["w"] is got[2]["w"]
    st = r.stats()
    for d in ("d1", "d2", "d3"):
        assert st[f"src->{d}"]["messages"] == 1
        assert st[f"src->{d}"]["bytes"] == 24  # 6 x float32 each


def test_broadcast_cross_device_hosts_leaves_once():
    import jax.numpy as jnp

    r = Router()
    r.register("src", devices=[0])
    r.register("same", devices=[0])
    r.register("far1", devices=[1])
    r.register("far2", devices=[2])
    obj = {"w": jnp.ones(4)}
    r.broadcast("src", ["same", "far1", "far2"], obj)
    same = r.recv("same", "src")
    far1 = r.recv("far1", "src")
    far2 = r.recv("far2", "src")
    assert isinstance(same["w"], type(obj["w"]))  # zero-copy reference
    assert isinstance(far1["w"], np.ndarray)      # host transfer
    # the host copy is made once and shared across far destinations
    assert far1["w"] is far2["w"]
    st = r.stats()
    assert st["src->far1"]["bytes"] == st["src->far2"]["bytes"] == 16


# ---------------------------------------------------------------------------
# teardown hygiene (satellites): reset_all closes live channels, and the
# executor's thread-leak check catches wedged threads by name
# ---------------------------------------------------------------------------
def test_reset_all_closes_live_channels_and_wakes_getters():
    ch = Channel.create("orphaned")
    outcome = []

    def getter():
        try:
            ch.get(timeout=30.0)
            outcome.append("item")
        except ChannelClosed:
            outcome.append("closed")

    th = threading.Thread(target=getter)
    th.start()
    time.sleep(0.05)  # let the getter park on the empty channel
    Channel.reset_all()
    th.join(timeout=5.0)
    assert not th.is_alive(), "reset_all left a getter blocked"
    assert outcome == ["closed"]
    assert ch.closed
    with pytest.raises(KeyError):
        Channel.get_channel("orphaned")


def test_assert_no_leaked_threads_passes_when_clean():
    from repro.core.pipeline import assert_no_leaked_threads

    assert_no_leaked_threads(grace=0.01)


def test_assert_no_leaked_threads_flags_wedged_executor_thread():
    from repro.core.pipeline import ThreadLeakError, assert_no_leaked_threads

    stop = threading.Event()
    th = threading.Thread(target=stop.wait, name="pipe-prod-leaktest",
                          daemon=True)
    th.start()
    try:
        with pytest.raises(ThreadLeakError) as ei:
            assert_no_leaked_threads(grace=0.05)
        assert ei.value.thread_names == ["pipe-prod-leaktest"]
    finally:
        stop.set()
        th.join(timeout=5.0)
    assert_no_leaked_threads(grace=0.5)  # clean again once it exited


def test_runner_teardown_runs_leak_check(tmp_path):
    from repro.core.pipeline import ThreadLeakError
    from repro.rl.runner import WorkflowRunner

    stop = threading.Event()
    th = threading.Thread(target=stop.wait, name="cycle-member-leaktest",
                          daemon=True)
    th.start()
    try:
        import types

        runner = WorkflowRunner.__new__(WorkflowRunner)
        runner.workers = {}
        runner.cluster = Cluster(num_nodes=1, devices_per_node=2)
        runner.controller = types.SimpleNamespace(
            placement_manager=types.SimpleNamespace(
                release_all=lambda: None),
            _switcher=None, profiles={},
            reset_failures=lambda: None)
        with pytest.raises(ThreadLeakError):
            runner.teardown()
    finally:
        stop.set()
        th.join(timeout=5.0)
