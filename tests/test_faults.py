"""Fault injection, typed failure propagation, heartbeat detection, and
kill-and-recover determinism (scale-out fault tolerance).

The recovery invariant under test: a run that loses a worker (or a whole
simulated host) mid-iteration must detect the death as a typed
WorkerFailure, re-place onto surviving devices, and resume from the last
checkpoint such that its post-recovery trajectory EQUALS a fresh runner
resumed from the same checkpoint — exactly for a deterministic toy
workflow, within tolerance for the three real workflow families.
"""
import os
import shutil

import numpy as np
import pytest

from repro.comm.primitives import global_router, reset_router
from repro.core import (
    CycleSpec,
    ExecutionFlowManager,
    FaultInjector,
    FaultSpec,
    FlowGraph,
    HeartbeatMonitor,
    InjectedFault,
    SchedulerConfig,
    Worker,
    WorkerFailure,
    cycle_node_name,
)
from repro.core.scheduler import Leaf, Pipelined
from repro.launch.cluster import SimulatedCluster, cluster_from_env
from repro.rl.runner import WorkflowRunner


# ---------------------------------------------------------------------------
# SimulatedCluster: liveness semantics
# ---------------------------------------------------------------------------
def test_simulated_cluster_host_failure_and_restore():
    sc = SimulatedCluster(num_nodes=3, devices_per_node=4)
    assert sc.num_devices == 12
    assert sc.available_devices() == list(range(12))
    sc.allocate("w", 4, device_ids=[2, 3, 4, 5])
    touched = sc.fail_host(1)
    assert touched == ["w"]  # w straddles the dead host
    assert sc.available_devices() == [0, 1, 2, 3, 8, 9, 10, 11]
    assert not sc.device_alive(5) and sc.device_alive(3)
    # new allocations skip the dead host's devices
    ids = sc.allocate("fresh", 6)
    assert all(sc.device_alive(i) for i in ids)
    # pinning onto a dead device is an explicit error
    with pytest.raises(ValueError, match="failed host"):
        sc.allocate("bad", 1, device_ids=[6])
    sc.restore_host(1)
    assert len(sc.available_devices()) == 12


def test_cluster_from_env_reads_dryrun_knobs(monkeypatch):
    monkeypatch.setenv("REPRO_DRYRUN_HOSTS", "3")
    monkeypatch.setenv("REPRO_DRYRUN_DEVICES", "2")
    sc = cluster_from_env()
    assert (sc.num_hosts, sc.devices_per_node) == (3, 2)
    # explicit args beat the env
    sc = cluster_from_env(hosts=2, devices_per_host=4)
    assert (sc.num_hosts, sc.devices_per_node) == (2, 4)


# ---------------------------------------------------------------------------
# FaultInjector: fire-once-at-the-configured-point semantics
# ---------------------------------------------------------------------------
class _StubWorker:
    def __init__(self, name, devices=()):
        self.name = name
        self.devices = tuple(devices)
        self.offloaded = False


def test_injector_fires_at_iteration_and_invocation():
    inj = FaultInjector(FaultSpec("gen", iteration=2, invocation=1))
    fns = inj.arm({"gen": lambda w, c: c, "train": lambda w, c: c})
    w = _StubWorker("gen/0")
    inj.set_iteration(1)
    fns["gen"](w, {})
    fns["gen"](w, {})  # wrong iteration: never fires
    inj.set_iteration(2)
    fns["gen"](w, {})  # invocation 0 survives
    with pytest.raises(InjectedFault):
        fns["gen"](w, {})  # invocation 1 dies
    assert inj.fired and w._injected_dead
    # the dead instance stays dead...
    with pytest.raises(InjectedFault):
        fns["gen"](w, {})
    # ...but a rebuilt worker of the same name is clean (one-shot)
    assert fns["gen"](_StubWorker("gen/0"), {"ok": 1}) == {"ok": 1}
    inj.set_iteration(2)
    assert fns["gen"](_StubWorker("gen/0"), {"ok": 1}) == {"ok": 1}


def test_injector_kill_host_takes_devices_down():
    sc = SimulatedCluster(num_nodes=2, devices_per_node=4)
    inj = FaultInjector(FaultSpec("gen", iteration=0, kill_host=True),
                        cluster=sc)
    fns = inj.arm({"gen": lambda w, c: c})
    inj.set_iteration(0)
    with pytest.raises(InjectedFault, match="host down"):
        fns["gen"](_StubWorker("gen/0", devices=(5,)), {})
    assert sc.available_devices() == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# HeartbeatMonitor: silent-hang detection with an injected clock
# ---------------------------------------------------------------------------
def test_heartbeat_monitor_flags_silent_workers():
    t = [0.0]
    hb = HeartbeatMonitor(timeout=5.0, clock=lambda: t[0])
    hb.beat("a")
    hb.beat("b")
    t[0] = 3.0
    hb.beat("a")
    assert hb.silent() == []
    t[0] = 8.0  # a beat 5s ago (boundary), b beat 8s ago
    assert hb.silent() == ["b"]
    with pytest.raises(TimeoutError, match="b"):
        hb.check()
    hb.beat("b")
    hb.check()  # recovered
    hb.reset()
    assert hb.silent() == []


def test_heartbeat_straggler_suspects_use_own_cadence():
    t = [0.0]
    hb = HeartbeatMonitor(timeout=1000.0, clock=lambda: t[0])
    # a and b both beat once per second...
    for i in range(6):
        t[0] = float(i)
        hb.beat("a")
        hb.beat("b")
    assert hb.intervals("a") == [1.0] * 5
    assert hb.suspects() == []
    # ...then a falls silent while b keeps its cadence
    for i in range(6, 12):
        t[0] = float(i)
        hb.beat("b")
    assert hb.suspects() == ["a"]  # 6s silence vs a ~1s cadence
    assert hb.silent() == []       # still far under the hard timeout
    hb.reset()
    assert hb.intervals("a") == []
    assert hb.suspects() == []


def test_heartbeat_straggler_needs_history_and_tolerates_slow_beats():
    t = [0.0]
    hb = HeartbeatMonitor(timeout=1000.0, clock=lambda: t[0])
    hb.beat("young")
    t[0] = 500.0
    # one beat = no recorded intervals: no cadence to compare against
    assert hb.suspects() == []
    # a worker whose cadence includes occasional slow beats: the
    # percentile absorbs them instead of flagging every pause
    for dt in (1.0, 1.0, 1.0, 1.0, 9.0):
        t[0] += dt
        hb.beat("bursty")
    last = t[0]
    t[0] = last + 10.0   # within 3 * p95 (= 27s) of its own history
    assert "bursty" not in hb.suspects()
    t[0] = last + 28.0   # beyond it
    assert hb.suspects() == ["bursty"]


def test_executor_beats_heartbeat_around_tasks():
    hb = HeartbeatMonitor(timeout=60.0)
    mgr = ExecutionFlowManager({"a": _StubWorker("a")},
                               {"a": lambda w, c: dict(c)}, heartbeat=hb)
    mgr.run(Leaf("a", 1, 4), {"x": np.zeros((4, 1))})
    assert hb.last_beat("a") is not None


# ---------------------------------------------------------------------------
# typed WorkerFailure propagation (satellite fix): Pipelined + cycle
# threads must surface death as WorkerFailure(worker, step), and
# coalesce must never run over partial payloads
# ---------------------------------------------------------------------------
def test_pipelined_consumer_death_is_typed_with_step():
    boom = ValueError("boom")
    seen = []

    def bad(w, c):
        seen.append(1)
        if len(seen) == 2:
            raise boom
        return dict(c)

    reported = []
    mgr = ExecutionFlowManager(
        {"a": _StubWorker("a"), "b": _StubWorker("b")},
        {"a": lambda w, c: dict(c), "b": bad},
        on_failure=reported.append)
    sched = Pipelined(Leaf("a", 1, 8), Leaf("b", 1, 8), 2, 1, 1)
    with pytest.raises(WorkerFailure) as ei:
        mgr.run(sched, {"x": np.arange(8.0).reshape(8, 1)})
    f = ei.value
    assert f.worker == "b"
    assert f.original is boom
    assert f.step == 1  # died on the second chunk
    assert reported and reported[0] is f


def test_pipelined_producer_death_never_reaches_coalesce():
    def bad(w, c):
        raise RuntimeError("producer died")

    mgr = ExecutionFlowManager(
        {"a": _StubWorker("a"), "b": _StubWorker("b")},
        {"a": bad, "b": lambda w, c: dict(c)})
    sched = Pipelined(Leaf("a", 1, 8), Leaf("b", 1, 8), 4, 1, 1)
    with pytest.raises(WorkerFailure) as ei:
        mgr.run(sched, {"x": np.arange(8.0).reshape(8, 1)})
    assert ei.value.worker == "a"
    assert ei.value.step == 0


@pytest.mark.parametrize("mode,member_devices", [
    ("collocated", None), ("hybrid", (1, 1))])
def test_cycle_member_death_is_typed(mode, member_devices):
    node = cycle_node_name(("gen", "sim"))

    def sim(w, c):
        if c["cycle_step"] == 1:
            raise RuntimeError("sim segfault")
        return dict(c)

    mgr = ExecutionFlowManager(
        {"gen": _StubWorker("gen"), "sim": _StubWorker("sim")},
        {"gen": lambda w, c: dict(c), "sim": sim},
        members={node: ("gen", "sim")},
        cycle_specs={node: CycleSpec(order=("gen", "sim"), steps=3,
                                     chunks=2)})
    leaf = Leaf(node, 2, 4, cycle_mode=mode, member_devices=member_devices,
                cycle_chunks=2)
    with pytest.raises(WorkerFailure) as ei:
        mgr.run(leaf, {"obs": np.zeros((4, 2))})
    assert ei.value.worker == "sim"
    assert ei.value.step is not None and ei.value.step >= 1


# ---------------------------------------------------------------------------
# deterministic toy workflow: recovery == fresh-resume, bit-exact
# ---------------------------------------------------------------------------
class ToyTrainer(Worker):
    def __init__(self, name, devices=()):
        super().__init__(name, devices=devices)
        self.register_state("params", np.zeros(4, np.float64))
        self.register_state("opt", np.zeros(1, np.float64))

    def params(self):
        return self.get_state("params")

    def train(self, chunk):
        p = np.asarray(self.get_state("params"), np.float64)
        o = np.asarray(self.get_state("opt"), np.float64)
        p = p + 0.01 * np.asarray(chunk["x"], np.float64).mean(axis=0)
        o = o + 1.0
        self.set_state("params", p)
        self.set_state("opt", o)
        out = dict(chunk)
        out["metric"] = float(p.sum())
        return out


class ToyRollout(Worker):
    def __init__(self, name, devices=()):
        super().__init__(name, devices=devices)
        self._wsum = 0.0

    def update_weights(self, params, version=None):
        import jax
        leaves = jax.tree_util.tree_leaves(params)
        self._wsum = float(sum(np.asarray(l).sum() for l in leaves))

    def gen(self, chunk):
        out = dict(chunk)
        out["x"] = np.asarray(chunk["x"], np.float64) + self._wsum
        return out


class ToyRunner(WorkflowRunner):
    weight_sync_workers = ("rollout",)
    versioned_sync_worker = None

    def __init__(self, **kw):
        self._count = 0
        kw.setdefault("iterations", 4)
        kw.setdefault("batch_size", 8)
        kw.setdefault("mode", "collocated")
        kw.setdefault("profile_batches", (4,))
        kw.setdefault("cluster",
                      SimulatedCluster(num_nodes=2, devices_per_node=2))
        super().__init__(**kw)

    def build_workers(self):
        self.actor = ToyTrainer(
            "trainer/0", devices=self.cluster.allocate("trainer", 2))
        self.rollout = ToyRollout(
            "rollout/0", devices=self.cluster.allocate("rollout", 2))
        return {"rollout": self.rollout, "trainer": self.actor}

    def build_task_fns(self):
        return {"rollout": lambda w, c: w.gen(c),
                "trainer": lambda w, c: w.train(c)}

    def build_graph(self):
        g = FlowGraph()
        g.add_worker("rollout")
        g.add_worker("trainer")
        g.add_edge("rollout", "trainer")
        return g

    def make_batch(self):
        self._count += 1
        base = np.linspace(0.0, 1.0, self.batch_size * 4).reshape(
            self.batch_size, 4)
        return {"x": base * self._count}

    def reset_stream(self):
        self._count = 0

    def scheduler_config(self):
        return SchedulerConfig(total_batch=self.batch_size,
                               granularity_divisors=(1, 2))

    def _record_stats(self, it, wall, out):
        st = (it, float(out["metric"]))
        self.stats.append(st)
        return st

    def log_iteration(self, st):
        pass


def _toy_three_stage(tmp_path, role, mode, k=2, total=5, kill_host=False):
    """Stage 1 advances a run to a checkpoint at iteration k; stage 2
    resumes with a kill at (k, invocation 0) and recovers; stage 3 is the
    uninterrupted baseline resumed from a copy of the same checkpoint.
    Returns (faulted_runner, baseline_runner)."""
    ck = str(tmp_path / f"ck-{role}-{mode}")
    ck_base = ck + "-baseline"
    # batch 2 pins the disaggregated granularity sweep to a single
    # candidate (only divisor 2 divides), so the chunking — and hence the
    # exact float sequence — cannot drift with measured profile noise
    batch = 2 if mode == "disaggregated" else 8

    reset_router()
    warm = ToyRunner(iterations=k, mode=mode, batch_size=batch,
                     checkpoint_dir=ck, checkpoint_every=1)
    warm.run(verbose=False)
    shutil.copytree(ck, ck_base)

    reset_router()
    cluster = SimulatedCluster(num_nodes=2, devices_per_node=2)
    inj = FaultInjector(FaultSpec(role, iteration=k, invocation=0,
                                  kill_host=kill_host), cluster=cluster)
    faulted = ToyRunner(iterations=total, mode=mode, batch_size=batch,
                        checkpoint_dir=ck, checkpoint_every=1,
                        fault_injector=inj, cluster=cluster)
    faulted.run(verbose=False)
    assert inj.fired
    assert faulted.recoveries == 1
    assert faulted.recovery_log[0].worker == role

    reset_router()
    baseline = ToyRunner(iterations=total, mode=mode, batch_size=batch,
                         checkpoint_dir=ck_base, checkpoint_every=1)
    baseline.run(verbose=False)
    assert baseline.recoveries == 0
    return faulted, baseline


@pytest.mark.parametrize("role", ["rollout", "trainer"])
@pytest.mark.parametrize("mode", ["collocated", "disaggregated"])
def test_toy_recovery_is_bit_exact(tmp_path, role, mode):
    faulted, baseline = _toy_three_stage(tmp_path, role, mode)
    # post-recovery trajectory identical to the fresh-resume baseline
    assert faulted.stats == baseline.stats
    np.testing.assert_array_equal(faulted.actor.params(),
                                  baseline.actor.params())
    np.testing.assert_array_equal(
        np.asarray(faulted.actor.get_state("opt")),
        np.asarray(baseline.actor.get_state("opt")))


def test_toy_recovery_after_host_death_uses_survivors(tmp_path):
    faulted, baseline = _toy_three_stage(tmp_path, "trainer", "collocated",
                                         kill_host=True)
    alive = set(faulted.cluster.available_devices())
    assert len(alive) == 2  # one of the two hosts died
    for name, devs in faulted.plan.placement.items():
        assert set(devs) <= alive, (name, devs)
    # the metric trajectory still matches (device count does not change
    # the toy math)
    assert faulted.stats == baseline.stats


def test_no_stale_allocations_or_registrations_after_recovery(tmp_path):
    faulted, _ = _toy_three_stage(tmp_path, "rollout", "collocated", k=1,
                                  total=3)
    cluster = faulted.cluster
    # every cluster allocation is exactly a plan placement (no leftovers
    # from the dead incarnation or from construction-time allocation)
    planned = {n: sorted(d) for n, d in faulted.plan.placement.items() if d}
    assert {n: sorted(d) for n, d in cluster._allocations.items()} == planned
    # router knows exactly the live workers, bound to their live devices
    router = global_router()
    assert set(router._workers) == {w.name
                                    for w in faulted.workers.values()}
    for w in faulted.workers.values():
        assert router.placement(w.name)["devices"] == list(w.devices)


def test_unhandled_failure_raises_when_not_fault_tolerant(tmp_path):
    reset_router()
    inj = FaultInjector(FaultSpec("rollout", iteration=1))
    runner = ToyRunner(iterations=3, fault_injector=inj,
                       fault_tolerant=False,
                       checkpoint_dir=str(tmp_path / "nt"),
                       checkpoint_every=1)
    with pytest.raises(WorkerFailure) as ei:
        runner.run(verbose=False)
    assert ei.value.worker == "rollout"
    assert isinstance(ei.value.original, InjectedFault)
    assert runner.recoveries == 0


def test_max_recoveries_bounds_the_loop(tmp_path):
    reset_router()

    class EveryIterationInjector(FaultInjector):
        def set_iteration(self, it):
            # re-target: this chaos monkey kills rollout EVERY iteration
            object.__setattr__(self, "spec",
                               FaultSpec("rollout", iteration=it))
            self.fired = False
            super().set_iteration(it)

    inj = EveryIterationInjector(FaultSpec("rollout", iteration=0))
    runner = ToyRunner(iterations=3, fault_injector=inj, max_recoveries=2,
                       checkpoint_dir=str(tmp_path / "mr"),
                       checkpoint_every=1)
    with pytest.raises(WorkerFailure):
        runner.run(verbose=False)
    assert runner.recoveries == 2


# ---------------------------------------------------------------------------
# e2e kill-and-recover for the three real workflow families on a
# 2-host simulated topology (acceptance criterion)
# ---------------------------------------------------------------------------
def _grpo_runner(ck, iterations, injector=None, cluster=None):
    from repro.configs import get_config
    from repro.rl import GRPOConfig, GRPORunner
    from repro.train import TrainHParams
    from repro.train.optimizer import AdamWConfig

    cfg = get_config("yi-9b").reduced().replace(
        vocab_size=32, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128)
    rl = GRPOConfig(batch_size=8, group_size=4, iterations=iterations,
                    max_new_tokens=4, mode="auto", seed=0,
                    profile_batches=(4, 8))
    return GRPORunner(
        cfg, rl, TrainHParams(optimizer=AdamWConfig(lr=1e-3)),
        cluster=cluster or SimulatedCluster(num_nodes=2, devices_per_node=4),
        checkpoint_dir=ck, checkpoint_every=1, fault_injector=injector)


def _rlhf_runner(ck, iterations, injector=None, cluster=None):
    from repro.configs import get_config
    from repro.rl import PPOConfig, RLHFRunner

    cfg = get_config("stablelm-12b").reduced().replace(
        vocab_size=32, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128)
    return RLHFRunner(
        cfg, PPOConfig(batch_size=8, iterations=iterations,
                       max_new_tokens=3, seed=0, profile_batches=(4, 8)),
        cluster=cluster or SimulatedCluster(num_nodes=2, devices_per_node=4),
        checkpoint_dir=ck, checkpoint_every=1, fault_injector=injector)


def _embodied_runner(ck, iterations, injector=None, cluster=None):
    from repro.rl import EmbodiedPPOConfig, EmbodiedPPORunner

    rl = EmbodiedPPOConfig(num_envs=8, horizon=4, iterations=iterations,
                           mode="collocated", seed=0, max_steps=8,
                           profile_batches=(4, 8), checkpoint_dir=ck,
                           checkpoint_every=1)
    return EmbodiedPPORunner(
        rl,
        cluster=cluster or SimulatedCluster(num_nodes=2, devices_per_node=4),
        fault_injector=injector)


_FAMILIES = {
    # family -> (builder, role to kill, invocation, stat fields compared)
    "grpo": (_grpo_runner, "rollout", 0, ("mean_reward", "accuracy")),
    "rlhf": (_rlhf_runner, "actor", 0, ("mean_reward", "value_loss")),
    # invocation 2 = the simulator's third cycle step: a mid-loop
    # phase-boundary kill inside the collapsed cycle node
    "embodied": (_embodied_runner, "simulator", 2,
                 ("mean_reward", "success_rate")),
}


@pytest.mark.parametrize("family", sorted(_FAMILIES))
def test_kill_and_recover_e2e_matches_fresh_resume(tmp_path, family):
    make, role, invocation, fields = _FAMILIES[family]
    k, total = 1, 3
    ck = str(tmp_path / f"{family}-ck")
    ck_base = ck + "-baseline"

    reset_router()
    make(ck, iterations=k).run(verbose=False)
    shutil.copytree(ck, ck_base)

    reset_router()
    cluster = SimulatedCluster(num_nodes=2, devices_per_node=4)
    inj = FaultInjector(FaultSpec(role, iteration=k, invocation=invocation),
                        cluster=cluster)
    faulted = make(ck, iterations=total, injector=inj, cluster=cluster)
    faulted.run(verbose=False)
    assert inj.fired
    assert faulted.recoveries == 1
    assert faulted.recovery_log[0].worker == role

    reset_router()
    baseline = make(ck_base, iterations=total)
    baseline.run(verbose=False)

    got = [s for s in faulted.stats if s.iteration >= k]
    want = [s for s in baseline.stats if s.iteration >= k]
    assert [s.iteration for s in got] == [s.iteration for s in want]
    for g, w in zip(got, want):
        for f in fields:
            assert np.isfinite(getattr(g, f))
            np.testing.assert_allclose(getattr(g, f), getattr(w, f),
                                       rtol=1e-4, atol=1e-5, err_msg=f)
    # re-placement left no stale cluster allocations behind
    planned = {n: sorted(d) for n, d in faulted.plan.placement.items() if d}
    current = {n: sorted(d)
               for n, d in faulted.cluster._allocations.items()}
    assert current == planned


def test_grpo_recovers_onto_surviving_host(tmp_path):
    """Host death: the run must finish on the surviving host's devices."""
    ck = str(tmp_path / "hostkill-ck")
    reset_router()
    cluster = SimulatedCluster(num_nodes=2, devices_per_node=4)
    inj = FaultInjector(FaultSpec("actor", iteration=1, kill_host=True),
                        cluster=cluster)
    runner = _grpo_runner(ck, iterations=3, injector=inj, cluster=cluster)
    stats = runner.run(verbose=False)
    assert runner.recoveries == 1
    assert len(cluster.available_devices()) == 4
    for name, devs in runner.plan.placement.items():
        assert all(cluster.device_alive(i) for i in devs), (name, devs)
    assert all(np.isfinite(s.mean_reward) for s in stats)
