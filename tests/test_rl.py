"""RL layer: advantages (property-based), reward, env, engine, e2e runner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import init_model
from repro.rl import (
    EnvConfig,
    GRPOConfig,
    GRPORunner,
    VecReachEnv,
    gae_advantages,
    grpo_advantages,
    math_reward,
)
from repro.serve import Engine
from repro.train import TrainHParams, make_prefill_step
from repro.train.data import EOS, PromptDataset, encode_digits
from repro.train.optimizer import AdamWConfig


# ---------------------------------------------------------------------------
# advantages
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    n_groups=st.integers(1, 8),
    group=st.integers(2, 8),
    seed=st.integers(0, 100),
)
def test_grpo_advantages_group_properties(n_groups, group, seed):
    rng = np.random.default_rng(seed)
    r = rng.normal(size=n_groups * group).astype(np.float32)
    adv = grpo_advantages(r, group)
    g = adv.reshape(n_groups, group)
    # zero mean and ~unit std per group (unless the group was constant)
    np.testing.assert_allclose(g.mean(axis=1), 0.0, atol=1e-5)
    for i in range(n_groups):
        if r.reshape(n_groups, group)[i].std() > 1e-4:
            assert abs(g[i].std() - 1.0) < 1e-2


def test_gae_known_case():
    # single env, 2 steps, gamma=1, lam=1, zero values:
    # adv = reward-to-go
    rewards = np.array([[1.0], [2.0]], np.float32)
    values = np.zeros((3, 1), np.float32)
    dones = np.zeros((2, 1), np.float32)
    adv, ret = gae_advantages(rewards, values, dones, gamma=1.0, lam=1.0)
    np.testing.assert_allclose(adv[:, 0], [3.0, 2.0])
    np.testing.assert_allclose(ret, adv)  # values are zero


def test_gae_resets_at_done():
    rewards = np.array([[1.0], [5.0]], np.float32)
    values = np.zeros((3, 1), np.float32)
    dones = np.array([[1.0], [0.0]], np.float32)  # episode ends at t=0
    adv, _ = gae_advantages(rewards, values, dones, gamma=1.0, lam=1.0)
    assert adv[0, 0] == pytest.approx(1.0)  # no bleed from t=1


# ---------------------------------------------------------------------------
# reward
# ---------------------------------------------------------------------------
def test_math_reward_exact_match():
    plen = 4
    B, S = 2, 10
    toks = np.zeros((B, S), np.int32)
    answers = np.array([12, 7], np.int32)
    # correct: digits of 12 then EOS
    toks[0, plen:plen + 3] = encode_digits(12) + [EOS]
    # wrong: digits of 9
    toks[1, plen:plen + 2] = encode_digits(9) + [EOS]
    r = math_reward(toks, answers, plen)
    assert r[0] == 5.0 and r[1] == -5.0


# ---------------------------------------------------------------------------
# env
# ---------------------------------------------------------------------------
def test_env_progress_reward_sign():
    env = VecReachEnv(EnvConfig(num_envs=4, max_steps=100), seed=0)
    obs = env.observe()
    # greedy action toward the goal must give positive progress
    d = env.goal - env.pos
    from repro.rl.env import _DIRS
    best = np.argmax(d @ _DIRS[1:].T, axis=1) + 1
    _, r, _, _ = env.step(best)
    assert (r > 0).all()


def test_env_oracle_policy_succeeds():
    env = VecReachEnv(EnvConfig(num_envs=16, max_steps=64), seed=1)
    from repro.rl.env import _DIRS
    succ = 0
    for _ in range(64):
        d = env.goal - env.pos
        a = np.argmax(d @ _DIRS[1:].T, axis=1) + 1
        _, _, _, info = env.step(a)
        succ += int(info["success"].sum())
    assert succ >= 16  # oracle reaches goals quickly


# ---------------------------------------------------------------------------
# engine behaviour logprobs
# ---------------------------------------------------------------------------
def test_engine_logprobs_match_prefill_recompute():
    """Behaviour logprobs from generation must equal the inference worker's
    recompute — the correctness contract between rollout and training."""
    cfg = get_config("yi-9b").reduced().replace(
        vocab_size=32, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128)
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, max_new_tokens=6, temperature=1.0)
    ds = PromptDataset(4, prompt_len=6, seed=0)
    b = ds.next_batch()
    res = eng.generate(params, jnp.asarray(b["prompt_tokens"]),
                       key=jax.random.PRNGKey(5))
    pf = jax.jit(make_prefill_step(cfg))
    recomputed = pf(params, {"tokens": jnp.asarray(res.tokens)})
    S = b["prompt_tokens"].shape[1]
    gen_lp = np.asarray(res.logprobs)[:, S:]
    rec_lp = np.asarray(recomputed)[:, S:]
    mask = np.asarray(res.tokens)[:, S:] != 0
    np.testing.assert_allclose(gen_lp[mask], rec_lp[mask], atol=2e-3)


# ---------------------------------------------------------------------------
# end-to-end M2Flow runner in all three modes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["collocated", "disaggregated", "auto"])
def test_grpo_runner_modes(mode):
    cfg = get_config("yi-9b").reduced().replace(
        vocab_size=32, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128)
    rl = GRPOConfig(batch_size=8, group_size=4, iterations=2,
                    max_new_tokens=4, mode=mode, seed=0,
                    profile_batches=(4, 8))
    runner = GRPORunner(cfg, rl, TrainHParams(optimizer=AdamWConfig(lr=1e-3)))
    stats = runner.run(verbose=False)
    assert len(stats) == 2
    assert all(np.isfinite(s.mean_reward) for s in stats)
    assert runner.throughput() > 0


def test_grpo_runner_learns_on_tiny_task():
    """80 iterations must lift train accuracy well above random on
    single-digit addition — the end-to-end learning check (recipe
    validated in EXPERIMENTS.md §E8: 0.08 -> ~0.4)."""
    cfg = get_config("yi-9b").reduced().replace(
        vocab_size=32, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256)
    rl = GRPOConfig(batch_size=32, group_size=8, iterations=80,
                    max_new_tokens=3, mode="collocated", seed=0,
                    profile_batches=(8,))
    runner = GRPORunner(
        cfg, rl, TrainHParams(optimizer=AdamWConfig(lr=1e-3, clip_norm=1.0),
                              entropy_coef=0.02))
    runner.data.max_operand = 3  # single-digit-answer curriculum
    runner.data.add_only = True
    stats = runner.run(verbose=False)
    first = np.mean([s.accuracy for s in stats[:10]])
    last = np.mean([s.accuracy for s in stats[-10:]])
    assert last > first + 0.1, (first, last)


def test_async_offpolicy_mode_learns_and_ratios_drift():
    """AReaL-style 1-step-stale rollouts: the PPO ratios must move off 1
    (staleness is real) yet training still improves accuracy."""
    cfg = get_config("yi-9b").reduced().replace(
        vocab_size=32, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256)
    rl = GRPOConfig(batch_size=32, group_size=8, iterations=50,
                    max_new_tokens=3, mode="collocated", seed=0,
                    profile_batches=(8,), async_offpolicy=True)
    runner = GRPORunner(
        cfg, rl, TrainHParams(optimizer=AdamWConfig(lr=1e-3, clip_norm=1.0),
                              entropy_coef=0.02))
    runner.data.max_operand = 3
    runner.data.add_only = True
    stats = runner.run(verbose=False)
    kls = [s.metrics.get("approx_kl", 0.0) for s in stats[2:] if s.metrics]
    assert max(kls) > 1e-5  # off-policy: ratios genuinely drift
    first = np.mean([s.accuracy for s in stats[:10]])
    last = np.mean([s.accuracy for s in stats[-10:]])
    assert last > first, (first, last)


def test_rlhf_ppo_four_model_workflow():
    """Full paper-Fig.-1 RLHF: actor+critic+reference+reward through the
    runtime; critic learns (value loss drops) and the KL anchor is live."""
    from repro.rl import PPOConfig, RLHFRunner

    cfg = get_config("stablelm-12b").reduced().replace(
        vocab_size=32, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256)
    runner = RLHFRunner(cfg, PPOConfig(batch_size=16, iterations=12,
                                       max_new_tokens=3))
    stats = runner.run(verbose=False)
    assert len(stats) == 12
    assert all(np.isfinite(s.value_loss) for s in stats)
    # critic fits the +-5 reward scale: early loss ~ 25, must drop
    assert np.mean([s.value_loss for s in stats[-4:]]) < stats[0].value_loss
    # the reference-KL penalty is actually wired into the actor loss
    assert "kl_ref" in stats[-1].metrics
    # the 6-node workflow graph is schedulable
    from repro.core import Scheduler, SchedulerConfig
    from repro.core.profiler import paper_like_profiles
    prof = paper_like_profiles()
    prof["reference"] = prof["critic_v"] = prof["inference"]
    prof["actor"] = prof["training"]
    t, s = Scheduler(prof, SchedulerConfig(
        total_batch=64, device_quantum=8)).schedule(runner.graph(), 32, 64)
    assert np.isfinite(t) and s is not None


def test_grpo_plan_chunks_never_split_groups():
    """Regression: a slow rollout profile (e.g. the paged engine on tiny
    models) used to push the auto planner to pipeline chunks smaller than
    group_size; the reward worker then fell back to groups of 1, whose
    group-relative advantages are identically zero — training silently
    stopped learning.  Every planned chunk must be a group multiple."""
    from repro.core.scheduler import leaves

    cfg = get_config("yi-9b").reduced().replace(
        vocab_size=32, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128)
    rl = GRPOConfig(batch_size=32, group_size=8, iterations=1,
                    max_new_tokens=3, mode="auto", seed=0,
                    profile_batches=(8,))
    runner = GRPORunner(cfg, rl, TrainHParams(optimizer=AdamWConfig(lr=1e-3)))
    runner.profile()
    runner.plan_execution()
    assert runner.controller.scheduler_cfg.chunk_multiple == rl.group_size
    for lf in leaves(runner.plan.schedule):
        assert lf.batch % rl.group_size == 0, (lf.worker, lf.batch)
